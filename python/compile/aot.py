"""AOT lowering: JAX train/eval/pack entrypoints -> HLO text artifacts.

This is the only place Python touches the pipeline; it runs at `make
artifacts` time and never again. Each entrypoint is jitted, lowered, and
written as HLO *text* (NOT a serialized HloModuleProto: jax >= 0.5 emits
64-bit instruction ids that the Rust side's xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md).

Outputs, under --outdir (default ../artifacts):
  train_step_<cfg>.hlo.txt   fwd+bwd+fused-Adam (Pallas kernels inlined)
  eval_loss_<cfg>.hlo.txt    loss-only step
  pack_fp16_<cfg>.hlo.txt    checkpoint fp16 pack kernel
  fused_adam_unit.hlo.txt    standalone Adam kernel (runtime unit tests)
  ffn_unit.hlo.txt           standalone FFN kernel (runtime unit tests)
  manifest.json              shapes/dtypes/tensor-table for the Rust side

Usage: python -m compile.aot [--outdir DIR] [--configs tiny,small,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ffn as ffn_mod
from .kernels import fused_adam as adam_mod

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def _write(outdir, fname, text):
    path = os.path.join(outdir, fname)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return fname


def lower_config(cfg: model.ModelConfig, outdir: str) -> dict:
    """Lower all entrypoints for one model config; return manifest entry."""
    n = model.padded_params(cfg)
    B, T = cfg.batch, cfg.seq
    f32v = jax.ShapeDtypeStruct((n,), jnp.float32)
    stepv = jax.ShapeDtypeStruct((1,), jnp.float32)
    toks = jax.ShapeDtypeStruct((B, T + 1), jnp.int32)

    train = jax.jit(lambda t, m, v, s, x: model.train_step(t, m, v, s, x, cfg))
    ev = jax.jit(lambda t, x: model.eval_loss(t, x, cfg))
    pack = jax.jit(lambda t: model.pack_step(t, cfg))
    grad = jax.jit(lambda t, x: model.grad_step(t, x, cfg))
    adam = jax.jit(lambda t, g, m, v, s: model.adam_step(t, g, m, v, s, cfg))

    files = {
        "train_step": _write(
            outdir, f"train_step_{cfg.name}.hlo.txt",
            to_hlo_text(train.lower(f32v, f32v, f32v, stepv, toks))),
        "eval_loss": _write(
            outdir, f"eval_loss_{cfg.name}.hlo.txt",
            to_hlo_text(ev.lower(f32v, toks))),
        "pack_fp16": _write(
            outdir, f"pack_fp16_{cfg.name}.hlo.txt",
            to_hlo_text(pack.lower(f32v))),
        "grad_step": _write(
            outdir, f"grad_step_{cfg.name}.hlo.txt",
            to_hlo_text(grad.lower(f32v, toks))),
        "adam_step": _write(
            outdir, f"adam_step_{cfg.name}.hlo.txt",
            to_hlo_text(adam.lower(f32v, f32v, f32v, f32v, stepv))),
    }

    tensors, off = [], 0
    for name, shape in model.tensor_table(cfg):
        size = 1
        for s in shape:
            size *= s
        tensors.append({"name": name, "shape": list(shape),
                        "offset": off, "size": size})
        off += size

    return {
        "model": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layer": cfg.n_layer, "n_head": cfg.n_head, "seq": cfg.seq,
            "batch": cfg.batch, "d_ff": cfg.d_ff,
        },
        "n_params": model.num_params(cfg),
        "n_padded": n,
        "tensors": tensors,
        "entrypoints": {
            "train_step": {
                "file": files["train_step"],
                "inputs": [
                    _spec("theta", "f32", (n,)), _spec("m", "f32", (n,)),
                    _spec("v", "f32", (n,)), _spec("step", "f32", (1,)),
                    _spec("tokens", "i32", (B, T + 1)),
                ],
                "outputs": [
                    _spec("theta", "f32", (n,)), _spec("m", "f32", (n,)),
                    _spec("v", "f32", (n,)), _spec("loss", "f32", ()),
                ],
            },
            "eval_loss": {
                "file": files["eval_loss"],
                "inputs": [_spec("theta", "f32", (n,)),
                           _spec("tokens", "i32", (B, T + 1))],
                "outputs": [_spec("loss", "f32", ())],
            },
            "pack_fp16": {
                "file": files["pack_fp16"],
                "inputs": [_spec("theta", "f32", (n,))],
                "outputs": [_spec("theta_fp16", "f16", (n,))],
            },
            "grad_step": {
                "file": files["grad_step"],
                "inputs": [_spec("theta", "f32", (n,)),
                           _spec("tokens", "i32", (B, T + 1))],
                "outputs": [_spec("grads", "f32", (n,)),
                            _spec("loss", "f32", ())],
            },
            "adam_step": {
                "file": files["adam_step"],
                "inputs": [
                    _spec("theta", "f32", (n,)), _spec("g", "f32", (n,)),
                    _spec("m", "f32", (n,)), _spec("v", "f32", (n,)),
                    _spec("step", "f32", (1,)),
                ],
                "outputs": [
                    _spec("theta", "f32", (n,)), _spec("m", "f32", (n,)),
                    _spec("v", "f32", (n,)),
                ],
            },
        },
    }


def lower_unit_kernels(outdir: str) -> dict:
    """Standalone kernel HLOs for Rust runtime unit tests."""
    n = adam_mod.BLOCK * 2
    f32v = jax.ShapeDtypeStruct((n,), jnp.float32)
    stepv = jax.ShapeDtypeStruct((), jnp.float32)
    adam = jax.jit(lambda t, g, m, v, s: adam_mod.fused_adam(t, g, m, v, s))
    adam_file = _write(outdir, "fused_adam_unit.hlo.txt",
                       to_hlo_text(adam.lower(f32v, f32v, f32v, f32v, stepv)))

    m_dim, d, h = 256, 64, 256
    x = jax.ShapeDtypeStruct((m_dim, d), jnp.float32)
    w1 = jax.ShapeDtypeStruct((d, h), jnp.float32)
    w2 = jax.ShapeDtypeStruct((h, d), jnp.float32)
    ffn_jit = jax.jit(lambda a, b, c: (ffn_mod.ffn(a, b, c),))
    ffn_file = _write(outdir, "ffn_unit.hlo.txt",
                      to_hlo_text(ffn_jit.lower(x, w1, w2)))
    return {
        "fused_adam_unit": {
            "file": adam_file, "n": n,
            "inputs": [_spec("theta", "f32", (n,)), _spec("g", "f32", (n,)),
                       _spec("m", "f32", (n,)), _spec("v", "f32", (n,)),
                       _spec("step", "f32", ())],
            "outputs": [_spec("theta", "f32", (n,)), _spec("m", "f32", (n,)),
                        _spec("v", "f32", (n,))],
        },
        "ffn_unit": {
            "file": ffn_file, "m": m_dim, "d": d, "h": h,
            "inputs": [_spec("x", "f32", (m_dim, d)),
                       _spec("w1", "f32", (d, h)),
                       _spec("w2", "f32", (h, d))],
            "outputs": [_spec("y", "f32", (m_dim, d))],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,gpt20m,gpt100m",
                    help="comma-separated model.CONFIGS names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {
        "version": MANIFEST_VERSION,
        "param_align": model.PARAM_ALIGN,
        "adam": {"lr": adam_mod.LR, "beta1": adam_mod.BETA1,
                 "beta2": adam_mod.BETA2, "eps": adam_mod.EPS},
        "configs": {},
        "units": lower_unit_kernels(args.outdir),
    }
    for name in args.configs.split(","):
        name = name.strip()
        if not name:
            continue
        cfg = model.CONFIGS[name]
        print(f"lowering {name} (params={model.num_params(cfg):,})...",
              flush=True)
        manifest["configs"][name] = lower_config(cfg, args.outdir)

    path = os.path.join(args.outdir, "manifest.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2)
    os.replace(tmp, path)
    print(f"wrote {path} ({len(manifest['configs'])} configs)")


if __name__ == "__main__":
    main()
