"""Checkpoint pack kernel (L1): fp32 master params -> fp16 serialization.

The paper's checkpoint state for mixed-precision training is ~14 bytes per
parameter: 2 B fp16 model weights + 12 B fp32 optimizer state (fp32 master
copy + Adam m + v) [§2.1.3]. The fp32 side is persisted as-is; the fp16
side must be *produced* from the fp32 master copy at checkpoint time. This
kernel is that producer: the accelerator-resident half of the write path,
whose output is what the D2H copy into the pinned IO buffer reads.

TPU mapping: 1-D grid over BLOCK tiles; per step 1 f32 in-block + 1 f16
out-block = 48 KiB VMEM. Pure dtype-convert (VPU), HBM-bandwidth bound —
which is the point: pack must run faster than the NVMe drain so it never
becomes the checkpoint bottleneck.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _pack_kernel(theta_ref, out_ref):
    out_ref[...] = theta_ref[...].astype(jnp.float16)


def pack_fp16(theta, block=None):
    """Cast the flat f32[N] master parameters to f16[N].

    N must be a multiple of `block` (default BLOCK; the L2 model passes
    a larger block for the CPU-interpret path — see fused_adam's note).
    """
    block = block or BLOCK
    n = theta.shape[0]
    if n % block != 0:
        raise ValueError(f"pack_fp16 requires N % {block} == 0, got {n}")
    return pl.pallas_call(
        _pack_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float16),
        interpret=True,
    )(theta)
