"""Fused Adam optimizer step as a Pallas kernel (L1).

This is the optimizer (`O`) pass of the paper's Fig. 3 dependency graph:
the op whose *output* the checkpoint persists, and the op the pipelined
checkpoint executor synchronizes against. Fusing the whole Adam update
(moment updates + bias correction + parameter update) into one kernel
gives a single, clean O -> C data-dependency edge and avoids materializing
mhat/vhat intermediates in HBM.

TPU mapping (see DESIGN.md §Hardware-Adaptation): a 1-D grid over
`BLOCK`-sized tiles of the flat parameter vector. Per grid step the kernel
holds 7 VMEM-resident blocks (theta, g, m, v in; theta', m', v' out) of
BLOCK f32 elements: 7 * 8192 * 4 B = 224 KiB, far under the ~16 MiB VMEM
budget, leaving room for the implicit HBM<->VMEM double buffering the
Pallas pipeline emitter inserts between grid steps. The kernel is purely
elementwise (VPU-bound); its roofline is HBM bandwidth.

Executed with interpret=True everywhere in this repo (CPU PJRT cannot run
Mosaic custom-calls); correctness is pinned to kernels.ref.adam_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size of the flat parameter vector. The model pads its flat
# parameter count up to a multiple of this (see model.PARAM_ALIGN).
BLOCK = 8192

# Default hyperparameters (match ref.adam_ref and the Rust manifest).
LR = 1e-3
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def _adam_kernel(bc_ref, theta_ref, g_ref, m_ref, v_ref,
                 out_theta_ref, out_m_ref, out_v_ref,
                 *, lr, b1, b2, eps):
    """One BLOCK-sized tile of the fused Adam update.

    bc_ref holds the two step-dependent bias-correction denominators
    (1 - b1**step, 1 - b2**step); they are computed once outside the
    kernel so the kernel body stays elementwise.
    """
    bc1 = bc_ref[0]
    bc2 = bc_ref[1]
    g = g_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    out_theta_ref[...] = theta_ref[...] - lr * mhat / (jnp.sqrt(vhat) + eps)
    out_m_ref[...] = m
    out_v_ref[...] = v


def fused_adam(theta, g, m, v, step, lr=LR, b1=BETA1, b2=BETA2, eps=EPS,
               block=None):
    """Apply one fused Adam step over the flat parameter vector.

    Args:
      theta, g, m, v: f32[N] with N a multiple of `block` (default BLOCK).
      step: 1-based step number (scalar, traced ok) for bias correction.
      block: tile size override. On a real TPU the default (8192) keeps
        the working set deep inside VMEM; for the CPU-interpret AOT path
        the L2 model passes a larger block (see model.adam_block) because
        XLA-CPU executes each grid step as a full-buffer
        dynamic-update-slice — O(N) copy per step — making many small
        steps catastrophically slow (measured 105 s/iter for 12M params
        at block=8192; see EXPERIMENTS.md §Perf).
    Returns:
      (theta', m', v'): updated f32[N] triple.
    """
    block = block or BLOCK
    n = theta.shape[0]
    if n % block != 0:
        raise ValueError(f"fused_adam requires N % {block} == 0, got {n}")
    step = jnp.asarray(step, dtype=theta.dtype)
    bc = jnp.stack([1.0 - b1**step, 1.0 - b2**step])

    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps),
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),  # bias corrections, broadcast
            vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), theta.dtype),
            jax.ShapeDtypeStruct((n,), theta.dtype),
            jax.ShapeDtypeStruct((n,), theta.dtype),
        ],
        interpret=True,
    )(bc, theta, g, m, v)
    return tuple(out)
