"""Fused transformer FFN block as a Pallas kernel (L1).

The FFN (two matmuls around a GELU) is the FLOPs hot-spot of the GPT-3
architecture's forward/backward passes — the `F`/`B` phases whose latency
the paper's Eq. 1 bandwidth bound (B_C >= S_C / (T_F + T_B)) is computed
from, and the work the pipelined checkpointer overlaps with.

TPU mapping (DESIGN.md §Hardware-Adaptation): the forward kernel tiles the
token dimension M into TILE_M-row blocks; each grid step keeps one
(TILE_M, D) activation tile plus both weight matrices VMEM-resident and
drives the MXU with (TILE_M, D) @ (D, H) and (TILE_M, H) @ (H, D)
contractions, accumulating in f32. For the repo's largest lowered config
(D=768, H=3072) the VMEM footprint at bf16 weights is ~2*D*H*2B = 9.4 MiB
+ 3 activation tiles — inside the 16 MiB budget; larger D would tile H as
well. GELU is fused between the matmuls so the (M, H) intermediate never
round-trips to HBM (the paper-era memory-bound gap Pallas-class kernels
close).

The backward pass is provided as a second Pallas kernel (single grid
step, whole-array blocks — interpret mode; a TPU build would tile it like
the forward) wired up through jax.custom_vjp so that jax.grad through the
L2 model lowers *both* directions into the exported HLO.

Correctness: kernels.ref.ffn_ref / ffn_bwd_ref.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gelu, gelu_grad

# Token-dimension tile. M (=B*T) must be a multiple of this; model configs
# guarantee it (all use B*T >= 128 and powers of two).
TILE_M = 128


def _ffn_fwd_kernel(x_ref, w1_ref, w2_ref, o_ref):
    h = gelu(x_ref[...] @ w1_ref[...])
    o_ref[...] = h @ w2_ref[...]


def _ffn_fwd_pallas(x, w1, w2):
    m, d = x.shape
    dh = w1.shape[1]
    tile_m = TILE_M if m % TILE_M == 0 else m
    return pl.pallas_call(
        _ffn_fwd_kernel,
        grid=(m // tile_m,),
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d, dh), lambda i: (0, 0)),
            pl.BlockSpec((dh, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=True,
    )(x, w1, w2)


def _ffn_bwd_kernel(x_ref, w1_ref, w2_ref, dy_ref, dx_ref, dw1_ref, dw2_ref):
    x = x_ref[...]
    w1 = w1_ref[...]
    w2 = w2_ref[...]
    dy = dy_ref[...]
    a = x @ w1
    h = gelu(a)
    dh = (dy @ w2.T) * gelu_grad(a)
    dx_ref[...] = dh @ w1.T
    dw1_ref[...] = x.T @ dh
    dw2_ref[...] = h.T @ dy


def _ffn_bwd_pallas(x, w1, w2, dy):
    m, d = x.shape
    dh = w1.shape[1]
    whole = lambda shape: pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))
    return pl.pallas_call(
        _ffn_bwd_kernel,
        in_specs=[whole((m, d)), whole((d, dh)), whole((dh, d)), whole((m, d))],
        out_specs=[whole((m, d)), whole((d, dh)), whole((dh, d))],
        out_shape=[
            jax.ShapeDtypeStruct((m, d), x.dtype),
            jax.ShapeDtypeStruct((d, dh), w1.dtype),
            jax.ShapeDtypeStruct((dh, d), w2.dtype),
        ],
        interpret=True,
    )(x, w1, w2, dy)


@jax.custom_vjp
def ffn(x, w1, w2):
    """Fused FFN block: gelu(x @ w1) @ w2, forward+backward in Pallas.

    Args:
      x: f32[M, D] activations (M = batch * seq, M % TILE_M == 0 for the
         tiled path; other M fall back to a single whole-array tile).
      w1: f32[D, H], w2: f32[H, D].
    """
    return _ffn_fwd_pallas(x, w1, w2)


def _ffn_vjp_fwd(x, w1, w2):
    return _ffn_fwd_pallas(x, w1, w2), (x, w1, w2)


def _ffn_vjp_bwd(res, dy):
    x, w1, w2 = res
    return _ffn_bwd_pallas(x, w1, w2, dy)


ffn.defvjp(_ffn_vjp_fwd, _ffn_vjp_bwd)
