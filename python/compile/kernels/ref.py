"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain jnp ops only. pytest (python/tests/test_kernels.py)
sweeps shapes/dtypes with hypothesis and asserts allclose between kernel
and oracle. The oracles are also what the L2 model would compute if the
Pallas kernels were swapped out, so they double as the semantic spec.
"""

import jax.numpy as jnp

# tanh-approximate GELU, written out explicitly so the Pallas kernels and
# the oracle share the exact same formula (jax.nn.gelu's internals may
# change between releases).
_GELU_C = 0.7978845608028654  # sqrt(2/pi)
_GELU_K = 0.044715


def gelu(x):
    """tanh-approximate GELU: 0.5*x*(1 + tanh(c*(x + k*x^3)))."""
    return 0.5 * x * (1.0 + jnp.tanh(_GELU_C * (x + _GELU_K * x * x * x)))


def gelu_grad(x):
    """Analytic derivative of `gelu` (used by the FFN backward kernel)."""
    inner = _GELU_C * (x + _GELU_K * x * x * x)
    t = jnp.tanh(inner)
    dinner = _GELU_C * (1.0 + 3.0 * _GELU_K * x * x)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * dinner


def adam_ref(theta, g, m, v, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """Reference fused Adam update (bias-corrected, no weight decay).

    `step` is the 1-based step number (float or 0-d array). Returns the
    updated (theta, m, v) triple, mirroring kernels.fused_adam.
    """
    step = jnp.asarray(step, dtype=theta.dtype)
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    mhat = m2 / bc1
    vhat = v2 / bc2
    theta2 = theta - lr * mhat / (jnp.sqrt(vhat) + eps)
    return theta2, m2, v2


def ffn_ref(x, w1, w2):
    """Reference fused FFN block: gelu(x @ w1) @ w2."""
    return gelu(x @ w1) @ w2


def ffn_bwd_ref(x, w1, w2, dy):
    """Reference backward pass of `ffn_ref` -> (dx, dw1, dw2)."""
    a = x @ w1
    h = gelu(a)
    dh = (dy @ w2.T) * gelu_grad(a)
    dx = dh @ w1.T
    dw1 = x.T @ dh
    dw2 = h.T @ dy
    return dx, dw1, dw2


def pack_fp16_ref(theta):
    """Reference checkpoint-pack: cast the flat fp32 master parameters to
    the fp16 serialization dtype (the paper's 2-byte model-parameter half
    of the 14-bytes-per-parameter checkpoint state)."""
    return theta.astype(jnp.float16)
