"""L2: GPT-3-architecture transformer fwd/bwd/optimize in JAX.

The model state is a single flat f32 parameter vector `theta` (plus Adam
moments `m`, `v` of the same shape), which is exactly the view the
checkpoint system wants: the Rust coordinator treats model state as flat
byte streams to partition among DP writers at byte granularity (§4.2 of
the paper), and the manifest's tensor table (name, offset, shape) supplies
the serialized-tensor metadata that torch.save-style checkpoints carry.

`train_step(theta, m, v, step, tokens)` performs forward + backward +
fused-Adam update and returns (theta', m', v', loss). It is lowered ONCE
to HLO text by aot.py and executed from Rust via PJRT; Python never runs
at training time.

Pallas kernels used (lowered into the same HLO):
  - kernels.ffn.ffn          fused FFN block, fwd + bwd (custom_vjp)
  - kernels.fused_adam       fused Adam update over the flat vector
  - kernels.pack.pack_fp16   fp16 packing for the checkpoint write path
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.ffn import ffn
from .kernels.fused_adam import BLOCK as ADAM_BLOCK
from .kernels.fused_adam import BETA1, BETA2, EPS, LR, fused_adam
from .kernels.pack import pack_fp16

# Flat parameter vectors are padded to a multiple of this so the 1-D
# Pallas grids divide evenly. Padding slots receive zero grads and stay 0.
PARAM_ALIGN = ADAM_BLOCK


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """GPT-style decoder configuration (pre-LN, learned positions, tied
    embedding/output projection, no biases except LayerNorm)."""

    name: str
    vocab: int
    d_model: int
    n_layer: int
    n_head: int
    seq: int        # training sequence length T (tokens input is [B, T+1])
    batch: int      # per-rank micro-batch B
    d_ff: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_head


# Model zoo lowered by aot.py. `tiny`/`small` are for tests and CI-speed
# examples; `gpt20m`/`gpt100m` are the end-to-end training configs
# (EXPERIMENTS.md records the real runs).
CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        ModelConfig("tiny", vocab=256, d_model=64, n_layer=2, n_head=2,
                    seq=32, batch=4, d_ff=256),
        ModelConfig("small", vocab=512, d_model=128, n_layer=2, n_head=4,
                    seq=64, batch=4, d_ff=512),
        ModelConfig("gpt20m", vocab=4096, d_model=384, n_layer=6, n_head=6,
                    seq=128, batch=8, d_ff=1536),
        ModelConfig("gpt100m", vocab=8192, d_model=768, n_layer=12, n_head=12,
                    seq=256, batch=8, d_ff=3072),
    ]
}


def tensor_table(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) table of the logical tensors inside the flat
    parameter vector. The order defines the byte layout the checkpoint
    serializer records; layers are stacked on a leading axis."""
    L, D, V, T, H = cfg.n_layer, cfg.d_model, cfg.vocab, cfg.seq, cfg.d_ff
    return [
        ("embed.weight", (V, D)),
        ("pos_embed.weight", (T, D)),
        ("blocks.ln1.scale", (L, D)),
        ("blocks.ln1.bias", (L, D)),
        ("blocks.attn.wqkv", (L, D, 3 * D)),
        ("blocks.attn.wo", (L, D, D)),
        ("blocks.ln2.scale", (L, D)),
        ("blocks.ln2.bias", (L, D)),
        ("blocks.ffn.w1", (L, D, H)),
        ("blocks.ffn.w2", (L, H, D)),
        ("final_ln.scale", (D,)),
        ("final_ln.bias", (D,)),
    ]


def num_params(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in tensor_table(cfg):
        size = 1
        for s in shape:
            size *= s
        total += size
    return total


# Maximum 1-D Pallas grid steps for the optimizer/pack kernels in the
# CPU-interpret AOT build. XLA-CPU lowers each grid step to a
# full-output dynamic-update-slice (an O(N) copy), so many small steps
# are catastrophic off-TPU: gpt20m at block=8192 (1496 steps) measured
# 105 s per optimizer call vs ~0.5 s at 8 steps (EXPERIMENTS.md §Perf).
# A real-TPU build would keep block=8192 and let the Mosaic pipeline
# double-buffer HBM<->VMEM instead (DESIGN.md §Hardware-Adaptation).
MAX_FLAT_GRID = 1


def adam_block(cfg: ModelConfig) -> int:
    """Tile size for the flat-vector kernels of this config: the
    smallest PARAM_ALIGN multiple that caps the grid at MAX_FLAT_GRID."""
    n = num_params(cfg)
    per = -(-n // MAX_FLAT_GRID)  # ceil
    return -(-per // PARAM_ALIGN) * PARAM_ALIGN


def padded_params(cfg: ModelConfig) -> int:
    """Flat length: num_params padded up to a whole number of blocks."""
    n = num_params(cfg)
    block = adam_block(cfg)
    return -(-n // block) * block


def _offsets(cfg: ModelConfig) -> Dict[str, Tuple[int, Tuple[int, ...]]]:
    out, off = {}, 0
    for name, shape in tensor_table(cfg):
        size = 1
        for s in shape:
            size *= s
        out[name] = (off, shape)
        off += size
    return out


def unflatten(theta: jnp.ndarray, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Slice the flat vector into the named parameter tree (static offsets,
    so XLA fuses these slices away)."""
    params = {}
    for name, (off, shape) in _offsets(cfg).items():
        size = 1
        for s in shape:
            size *= s
        params[name] = jax.lax.slice(theta, (off,), (off + size,)).reshape(shape)
    return params


def init_theta(cfg: ModelConfig, seed: int = 0) -> jnp.ndarray:
    """GPT-2-style init, flattened and padded to PARAM_ALIGN."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in tensor_table(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".bias"):
            t = jnp.zeros(shape, jnp.float32)
        elif name.endswith(".scale"):
            t = jnp.ones(shape, jnp.float32)
        else:
            scale = 0.02
            # residual-path projections get the 1/sqrt(2L) shrink
            if name.endswith("attn.wo") or name.endswith("ffn.w2"):
                scale = 0.02 / float(jnp.sqrt(2.0 * cfg.n_layer))
            t = scale * jax.random.normal(sub, shape, jnp.float32)
        parts.append(t.reshape(-1))
    flat = jnp.concatenate(parts)
    pad = padded_params(cfg) - flat.shape[0]
    return jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(x, wqkv, wo, n_head):
    """Causal multi-head self-attention. x: [B, T, D]."""
    B, T, D = x.shape
    hd = D // n_head
    qkv = x @ wqkv  # [B, T, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(B, T, n_head, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask, att, jnp.float32(-1e9))
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, D)
    return y @ wo


def forward(theta: jnp.ndarray, tokens: jnp.ndarray, cfg: ModelConfig):
    """Next-token logits. tokens: i32[B, T] (inputs only)."""
    p = unflatten(theta, cfg)
    B, T = tokens.shape
    x = p["embed.weight"][tokens] + p["pos_embed.weight"][:T][None, :, :]
    for l in range(cfg.n_layer):
        h = _layer_norm(x, p["blocks.ln1.scale"][l], p["blocks.ln1.bias"][l])
        x = x + _attention(h, p["blocks.attn.wqkv"][l], p["blocks.attn.wo"][l],
                           cfg.n_head)
        h = _layer_norm(x, p["blocks.ln2.scale"][l], p["blocks.ln2.bias"][l])
        # Fused Pallas FFN over the flattened token dimension.
        hf = h.reshape(B * T, cfg.d_model)
        f = ffn(hf, p["blocks.ffn.w1"][l], p["blocks.ffn.w2"][l])
        x = x + f.reshape(B, T, cfg.d_model)
    x = _layer_norm(x, p["final_ln.scale"], p["final_ln.bias"])
    return x @ p["embed.weight"].T  # tied output projection


def loss_fn(theta: jnp.ndarray, tokens: jnp.ndarray, cfg: ModelConfig):
    """Mean next-token cross-entropy. tokens: i32[B, T+1]."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(theta, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def train_step(theta, m, v, step, tokens, cfg: ModelConfig):
    """One training iteration: fwd + bwd + fused Adam.

    Args:
      theta, m, v: f32[N_pad] flat state.
      step: f32[1] 1-based step number (bias correction).
      tokens: i32[B, T+1] token batch (inputs ++ shifted targets).
    Returns:
      (theta', m', v', loss) — loss is f32 scalar.
    """
    loss, grads = jax.value_and_grad(loss_fn)(theta, tokens, cfg)
    theta2, m2, v2 = fused_adam(theta, grads, m, v, step[0],
                                lr=LR, b1=BETA1, b2=BETA2, eps=EPS,
                                block=adam_block(cfg))
    return theta2, m2, v2, loss


def grad_step(theta, tokens, cfg: ModelConfig):
    """Forward + backward only: returns (grads, loss).

    Split out from `train_step` so the Rust coordinator can overlap the
    checkpoint write of iteration i with F/B of iteration i+1 and
    synchronize exactly at the optimizer boundary (paper Fig. 3/§4.3).
    """
    loss, grads = jax.value_and_grad(loss_fn)(theta, tokens, cfg)
    return grads, loss


def adam_step(theta, g, m, v, step, cfg: ModelConfig):
    """Optimizer pass only: fused Adam over the flat state (Pallas)."""
    return fused_adam(theta, g, m, v, step[0], lr=LR, b1=BETA1, b2=BETA2,
                      eps=EPS, block=adam_block(cfg))


def pack_step(theta, cfg: ModelConfig):
    """Checkpoint pack: flat f32 master params -> f16 for serialization
    (the accelerator-side producer of the checkpoint's 2-byte weights)."""
    return (pack_fp16(theta, block=adam_block(cfg)),)


def eval_loss(theta, tokens, cfg: ModelConfig):
    """Loss-only evaluation step (no state update)."""
    return (loss_fn(theta, tokens, cfg),)
