"""L2 correctness: model shapes, flat-parameter layout, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.fused_adam import BLOCK

TINY = model.CONFIGS["tiny"]
SMALL = model.CONFIGS["small"]


def _tokens(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (cfg.batch, cfg.seq + 1), 0, cfg.vocab,
                              jnp.int32)


# ------------------------------------------------------------------ layout


def test_param_count_formula():
    """Tensor-table total matches the analytic GPT param count."""
    for cfg in (TINY, SMALL):
        L, D, V, T, H = (cfg.n_layer, cfg.d_model, cfg.vocab, cfg.seq,
                         cfg.d_ff)
        expect = V * D + T * D + L * (4 * D + 3 * D * D + D * D + 2 * D * H) \
            + 2 * D
        assert model.num_params(cfg) == expect


def test_padded_alignment():
    for cfg in model.CONFIGS.values():
        n = model.padded_params(cfg)
        assert n % model.PARAM_ALIGN == 0
        assert 0 <= n - model.num_params(cfg) < model.PARAM_ALIGN


def test_tensor_table_offsets_are_contiguous():
    off = 0
    for name, shape in model.tensor_table(TINY):
        size = int(np.prod(shape))
        assert size > 0, name
        off += size
    assert off == model.num_params(TINY)


def test_unflatten_roundtrip():
    theta = model.init_theta(TINY, seed=3)
    p = model.unflatten(theta, TINY)
    flat = jnp.concatenate([p[name].reshape(-1)
                            for name, _ in model.tensor_table(TINY)])
    np.testing.assert_array_equal(np.asarray(flat),
                                  np.asarray(theta[: flat.shape[0]]))


def test_init_padding_is_zero():
    theta = model.init_theta(TINY)
    n = model.num_params(TINY)
    np.testing.assert_array_equal(np.asarray(theta[n:]),
                                  np.zeros(theta.shape[0] - n, np.float32))


def test_init_deterministic():
    a = model.init_theta(TINY, seed=1)
    b = model.init_theta(TINY, seed=1)
    c = model.init_theta(TINY, seed=2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


# ----------------------------------------------------------------- forward


def test_forward_shapes():
    theta = model.init_theta(TINY)
    toks = _tokens(TINY)[:, :-1]
    logits = model.forward(theta, toks, TINY)
    assert logits.shape == (TINY.batch, TINY.seq, TINY.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    """Fresh init should predict ~uniformly: loss ~ ln(vocab)."""
    theta = model.init_theta(TINY)
    loss = model.loss_fn(theta, _tokens(TINY), TINY)
    assert abs(float(loss) - np.log(TINY.vocab)) < 0.5


def test_forward_is_causal():
    """Changing a future token must not affect earlier logits."""
    theta = model.init_theta(TINY)
    toks = _tokens(TINY)[:, :-1]
    base = model.forward(theta, toks, TINY)
    mod = toks.at[:, -1].set((toks[:, -1] + 1) % TINY.vocab)
    out = model.forward(theta, mod, TINY)
    np.testing.assert_allclose(np.asarray(base[:, :-1]),
                               np.asarray(out[:, :-1]), rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(base[:, -1]), np.asarray(out[:, -1]))


# -------------------------------------------------------------- train_step


def test_train_step_shapes_and_finiteness():
    cfg = TINY
    theta = model.init_theta(cfg)
    z = jnp.zeros_like(theta)
    t2, m2, v2, loss = model.train_step(theta, z, z, jnp.ones((1,)),
                                        _tokens(cfg), cfg)
    assert t2.shape == theta.shape and m2.shape == theta.shape
    assert v2.shape == theta.shape and loss.shape == ()
    for arr in (t2, m2, v2, loss):
        assert bool(jnp.all(jnp.isfinite(arr)))


def test_train_step_padding_stays_zero():
    cfg = TINY
    theta = model.init_theta(cfg)
    z = jnp.zeros_like(theta)
    n = model.num_params(cfg)
    t, m, v = theta, z, z
    for step in range(1, 4):
        t, m, v, _ = model.train_step(t, m, v,
                                      jnp.array([float(step)], jnp.float32),
                                      _tokens(cfg, step), cfg)
    pad = np.asarray(t[n:])
    np.testing.assert_array_equal(pad, np.zeros_like(pad))


def test_loss_decreases_on_fixed_batch():
    """Memorization sanity: repeated steps on one batch reduce loss."""
    cfg = TINY
    theta = model.init_theta(cfg)
    z = jnp.zeros_like(theta)
    toks = _tokens(cfg, 42)
    step_fn = jax.jit(
        lambda t, m, v, s: model.train_step(t, m, v, s, toks, cfg))
    t, m, v = theta, z, z
    losses = []
    for step in range(1, 21):
        t, m, v, loss = step_fn(t, m, v,
                                jnp.array([float(step)], jnp.float32))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_train_step_matches_manual_composition():
    """train_step == value_and_grad + adam_ref composed by hand."""
    from compile.kernels import ref

    cfg = TINY
    theta = model.init_theta(cfg)
    z = jnp.zeros_like(theta)
    toks = _tokens(cfg, 7)
    t2, m2, v2, loss = model.train_step(theta, z, z, jnp.ones((1,)), toks,
                                        cfg)
    want_loss, grads = jax.value_and_grad(model.loss_fn)(theta, toks, cfg)
    wt, wm, wv = ref.adam_ref(theta, grads, z, z, 1.0)
    np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(wt), rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(wm), rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(wv), rtol=1e-5,
                               atol=1e-7)


def test_pack_step_roundtrip():
    theta = model.init_theta(TINY)
    (packed,) = model.pack_step(theta, TINY)
    assert packed.dtype == jnp.float16
    np.testing.assert_allclose(np.asarray(packed).astype(np.float32),
                               np.asarray(theta), atol=2e-3, rtol=2e-3)


def test_eval_loss_matches_loss_fn():
    theta = model.init_theta(TINY)
    toks = _tokens(TINY)
    (l1,) = model.eval_loss(theta, toks, TINY)
    l2 = model.loss_fn(theta, toks, TINY)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


# --------------------------------------------------------- checkpoint sizes


def test_checkpoint_state_is_14ish_bytes_per_param():
    """Paper §2.1.3: fp16 weights + fp32 master + m + v = 14 B/param."""
    cfg = TINY
    n = model.padded_params(cfg)
    fp16_bytes = 2 * n
    fp32_state_bytes = 3 * 4 * n  # master + m + v
    total = fp16_bytes + fp32_state_bytes
    assert total == 14 * n
