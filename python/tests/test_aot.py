"""AOT pipeline: HLO text emission + manifest consistency.

Lowers the tiny config in-process (fast) and checks the artifacts the Rust
side depends on. Also validates an existing artifacts/ dir if present.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrips_simple_fn():
    f = jax.jit(lambda x: (x * 2.0 + 1.0,))
    text = aot.to_hlo_text(f.lower(jax.ShapeDtypeStruct((4,), jnp.float32)))
    assert "ENTRY" in text and "f32[4]" in text


def test_lower_config_tiny(tmp_path):
    cfg = model.CONFIGS["tiny"]
    entry = aot.lower_config(cfg, str(tmp_path))
    # entrypoint files exist and look like HLO text
    for ep in entry["entrypoints"].values():
        path = tmp_path / ep["file"]
        assert path.exists()
        head = path.read_text()[:4000]
        assert "HloModule" in head
    # tensor table covers exactly n_params
    total = sum(t["size"] for t in entry["tensors"])
    assert total == entry["n_params"]
    offs = [t["offset"] for t in entry["tensors"]]
    assert offs == sorted(offs) and offs[0] == 0
    for a, b in zip(entry["tensors"], entry["tensors"][1:]):
        assert a["offset"] + a["size"] == b["offset"]
    # shapes in the train_step signature agree with padded size
    n = entry["n_padded"]
    ins = entry["entrypoints"]["train_step"]["inputs"]
    assert ins[0]["shape"] == [n] and ins[3]["shape"] == [1]
    assert ins[4]["shape"] == [cfg.batch, cfg.seq + 1]


def test_unit_kernel_manifest(tmp_path):
    units = aot.lower_unit_kernels(str(tmp_path))
    assert (tmp_path / units["fused_adam_unit"]["file"]).exists()
    assert (tmp_path / units["ffn_unit"]["file"]).exists()
    assert units["fused_adam_unit"]["n"] % model.PARAM_ALIGN == 0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts/ not built (run `make artifacts`)",
)
def test_existing_artifacts_manifest_consistent():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == aot.MANIFEST_VERSION
    assert manifest["param_align"] == model.PARAM_ALIGN
    for name, entry in manifest["configs"].items():
        cfg = model.CONFIGS[name]
        assert entry["n_params"] == model.num_params(cfg)
        assert entry["n_padded"] == model.padded_params(cfg)
        for ep in entry["entrypoints"].values():
            assert os.path.exists(os.path.join(ARTIFACTS, ep["file"])), \
                ep["file"]
    for unit in manifest["units"].values():
        assert os.path.exists(os.path.join(ARTIFACTS, unit["file"]))
