"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps).

These are the core correctness signal for the kernels that get lowered
into the exported HLO. Shapes/dtypes/values are swept with hypothesis;
interpret-mode Pallas is slow, so example counts are kept moderate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.ffn import TILE_M, ffn
from compile.kernels.fused_adam import BLOCK, fused_adam
from compile.kernels.pack import pack_fp16

SETTINGS = dict(max_examples=20, deadline=None)


def _randn(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape,
                                     jnp.float32)


# ---------------------------------------------------------------- fused_adam


@settings(**SETTINGS)
@given(
    nblocks=st.integers(min_value=1, max_value=3),
    step=st.integers(min_value=1, max_value=1000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_adam_matches_ref(nblocks, step, seed):
    n = nblocks * BLOCK
    theta = _randn(seed, (n,))
    g = _randn(seed + 1, (n,))
    m = _randn(seed + 2, (n,), 0.1)
    v = jnp.abs(_randn(seed + 3, (n,), 0.1))
    got = fused_adam(theta, g, m, v, float(step))
    want = ref.adam_ref(theta, g, m, v, float(step))
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(
    lr=st.floats(min_value=1e-5, max_value=1e-1),
    b1=st.floats(min_value=0.5, max_value=0.99),
    b2=st.floats(min_value=0.9, max_value=0.9999),
)
def test_adam_hyperparams(lr, b1, b2):
    n = BLOCK
    theta, g = _randn(0, (n,)), _randn(1, (n,))
    m, v = jnp.zeros((n,)), jnp.zeros((n,))
    got = fused_adam(theta, g, m, v, 1.0, lr=lr, b1=b1, b2=b2)
    want = ref.adam_ref(theta, g, m, v, 1.0, lr=lr, b1=b1, b2=b2)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_adam_zero_grad_keeps_theta():
    """Padding slots (zero grad, zero moments) must not drift."""
    n = BLOCK
    theta = _randn(0, (n,))
    z = jnp.zeros((n,))
    t2, m2, v2 = fused_adam(theta, z, z, z, 1.0)
    np.testing.assert_allclose(t2, theta, atol=0.0)
    np.testing.assert_allclose(m2, z, atol=0.0)
    np.testing.assert_allclose(v2, z, atol=0.0)


def test_adam_first_step_bias_correction():
    """At step 1 with zero moments, update must equal -lr * sign-ish form:
    mhat = g, vhat = g^2 => theta - lr * g / (|g| + eps)."""
    n = BLOCK
    g = _randn(1, (n,))
    theta = jnp.zeros((n,))
    z = jnp.zeros((n,))
    t2, _, _ = fused_adam(theta, g, z, z, 1.0, lr=0.01)
    expect = -0.01 * g / (jnp.abs(g) + 1e-8)
    np.testing.assert_allclose(t2, expect, rtol=1e-4, atol=1e-6)


def test_adam_rejects_unaligned():
    n = BLOCK + 1
    z = jnp.zeros((n,))
    with pytest.raises(ValueError):
        fused_adam(z, z, z, z, 1.0)


def test_adam_under_jit():
    n = BLOCK
    theta, g = _randn(0, (n,)), _randn(1, (n,))
    z = jnp.zeros((n,))
    f = jax.jit(lambda t, g, m, v, s: fused_adam(t, g, m, v, s))
    got = f(theta, g, z, z, 7.0)
    want = ref.adam_ref(theta, g, z, z, 7.0)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------- pack_fp16


@settings(**SETTINGS)
@given(nblocks=st.integers(min_value=1, max_value=4),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_pack_matches_ref(nblocks, seed):
    theta = _randn(seed, (nblocks * BLOCK,), 3.0)
    got = pack_fp16(theta)
    assert got.dtype == jnp.float16
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.pack_fp16_ref(theta)))


def test_pack_handles_extremes():
    x = jnp.zeros((BLOCK,), jnp.float32)
    x = x.at[0].set(1e30).at[1].set(-1e30).at[2].set(1e-30).at[3].set(jnp.nan)
    got = np.asarray(pack_fp16(x))
    assert np.isposinf(got[0]) and np.isneginf(got[1])
    assert got[2] == 0.0 and np.isnan(got[3])


def test_pack_rejects_unaligned():
    with pytest.raises(ValueError):
        pack_fp16(jnp.zeros((BLOCK - 1,)))


# ---------------------------------------------------------------------- ffn


@settings(**SETTINGS)
@given(
    mtiles=st.integers(min_value=1, max_value=3),
    d=st.sampled_from([16, 64]),
    h=st.sampled_from([32, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ffn_forward_matches_ref(mtiles, d, h, seed):
    m = mtiles * TILE_M
    x = _randn(seed, (m, d))
    w1 = _randn(seed + 1, (d, h), 0.2)
    w2 = _randn(seed + 2, (h, d), 0.2)
    np.testing.assert_allclose(ffn(x, w1, w2), ref.ffn_ref(x, w1, w2),
                               rtol=1e-5, atol=1e-5)


def test_ffn_nontile_m_falls_back():
    """M not divisible by TILE_M uses a whole-array tile; same numerics."""
    x = _randn(0, (96, 32))
    w1 = _randn(1, (32, 64), 0.2)
    w2 = _randn(2, (64, 32), 0.2)
    np.testing.assert_allclose(ffn(x, w1, w2), ref.ffn_ref(x, w1, w2),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_ffn_grads_match_ref(seed):
    x = _randn(seed, (TILE_M, 32))
    w1 = _randn(seed + 1, (32, 64), 0.2)
    w2 = _randn(seed + 2, (64, 32), 0.2)

    def f(fn):
        return lambda *a: jnp.sum(jnp.sin(fn(*a)))

    got = jax.grad(f(ffn), argnums=(0, 1, 2))(x, w1, w2)
    want = jax.grad(f(ref.ffn_ref), argnums=(0, 1, 2))(x, w1, w2)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_ffn_bwd_kernel_matches_bwd_ref():
    from compile.kernels.ffn import _ffn_bwd_pallas

    x = _randn(0, (64, 16))
    w1 = _randn(1, (16, 32), 0.3)
    w2 = _randn(2, (32, 16), 0.3)
    dy = _randn(3, (64, 16))
    got = _ffn_bwd_pallas(x, w1, w2, dy)
    want = ref.ffn_bwd_ref(x, w1, w2, dy)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- gelu


@settings(**SETTINGS)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_gelu_matches_jax_nn(seed):
    x = _randn(seed, (512,), 4.0)
    np.testing.assert_allclose(ref.gelu(x),
                               jax.nn.gelu(x, approximate=True),
                               rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_gelu_grad_matches_autodiff(seed):
    x = _randn(seed, (256,), 4.0)
    auto = jax.vmap(jax.grad(ref.gelu))(x)
    np.testing.assert_allclose(ref.gelu_grad(x), auto, rtol=1e-5, atol=1e-6)
