//! Recovery drill — the paper's §3.3 motivation made concrete.
//!
//! 1. Trains a tiny GPT with per-iteration FastPersist checkpointing.
//! 2. Simulates a failure (training state dropped mid-run).
//! 3. Resumes from the latest durable checkpoint and verifies the
//!    resumed trajectory is bit-identical to an uninterrupted run.
//! 4. Prints the Eq. 2 recovery-cost table: expected GPU-time lost per
//!    interruption for checkpoint intervals n ∈ {1, 10, 100}.

use fastpersist::model::gpt3::find;
use fastpersist::runtime::artifacts::ArtifactManifest;
use fastpersist::io::engine::scratch_dir;
use fastpersist::training::looper::{CkptRunMode, Trainer, TrainerConfig};
use fastpersist::util::table::Table;

fn main() -> fastpersist::Result<()> {
    let manifest = ArtifactManifest::load(&ArtifactManifest::default_dir())?;
    let dir = scratch_dir("recovery")?;

    // --- uninterrupted reference: 12 steps ---------------------------
    let mut cfg = TrainerConfig::quick("tiny", dir.join("ref"));
    cfg.steps = 12;
    cfg.mode = CkptRunMode::Pipelined;
    cfg.keep_last = 0;
    let mut reference = Trainer::new(&manifest, cfg.clone())?;
    reference.run()?;
    println!("reference run: 12 steps, final step {}", reference.state.step);

    // --- failing run: crashes after step 8 ---------------------------
    let mut cfg_fail = cfg.clone();
    cfg_fail.ckpt_dir = dir.join("victim");
    cfg_fail.steps = 8;
    let mut victim = Trainer::new(&manifest, cfg_fail.clone())?;
    victim.run()?;
    drop(victim); // power loss: all volatile state gone
    println!("victim run: crashed after step 8 (in-memory state dropped)");

    // --- recovery: resume from latest durable checkpoint -------------
    let mut cfg_resume = cfg_fail;
    cfg_resume.steps = 4; // finish the remaining 12-8 steps
    let mut resumed = Trainer::resume(&manifest, cfg_resume)?;
    println!("resumed from step {} (latest durable checkpoint)", resumed.state.step);
    assert_eq!(resumed.state.step, 8, "per-iteration ckpt → zero lost steps");
    resumed.run()?;

    assert_eq!(resumed.state.step, reference.state.step);
    assert_eq!(
        resumed.state.theta, reference.state.theta,
        "resumed trajectory diverged from uninterrupted run"
    );
    println!("resumed trajectory is bit-identical to the uninterrupted run ✓\n");

    // --- Eq. 2: expected recovery cost table --------------------------
    println!("=== Eq. 2: expected GPU-seconds lost per interruption ===");
    println!("(n/2 · m · t — gpt3-13b, m = 2048 GPUs, t = iteration seconds)\n");
    let m13 = find("gpt3-13b").unwrap();
    let iter_s = m13.iter_time(128, 1).total();
    let mut t = Table::new(vec![
        "ckpt interval n", "expected loss (GPU-hours)", "note",
    ]);
    for (n, note) in [
        (1u64, "FastPersist: per-iteration, <2% overhead"),
        (10, "typical compromise"),
        (100, "baseline: ckpt cost forces infrequency"),
    ] {
        let cost = m13.recovery_cost_gpu_secs(n, 2048, iter_s) / 3600.0;
        t.row(vec![n.to_string(), format!("{cost:.1}"), note.to_string()]);
    }
    println!("{}", t.render());
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
