//! Quickstart: train a tiny GPT via the AOT-compiled PJRT artifacts and
//! checkpoint **every iteration** three ways — torch.save-style
//! baseline, FastPersist synchronous, and FastPersist pipelined —
//! then print the per-iteration cost of each.
//!
//!     make artifacts && cargo run --release --example quickstart

use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::runtime::artifacts::ArtifactManifest;
use fastpersist::training::looper::{CkptRunMode, Trainer, TrainerConfig};
use fastpersist::util::table::Table;

fn main() -> fastpersist::Result<()> {
    let manifest = ArtifactManifest::load(&ArtifactManifest::default_dir())?;
    let base_dir = scratch_dir("quickstart")?;
    println!("FastPersist quickstart: model `tiny`, 20 steps, checkpoint every iteration\n");

    let mut table = Table::new(vec![
        "mode", "final loss", "iter p50 (ms)", "ckpt stall total (ms)", "ckpts",
    ]);
    for (label, mode) in [
        ("baseline (torch.save)", CkptRunMode::Baseline),
        ("fastpersist sync", CkptRunMode::Sync),
        ("fastpersist pipelined", CkptRunMode::Pipelined),
    ] {
        let cfg = TrainerConfig {
            model: "tiny".into(),
            steps: 20,
            ckpt_every: 1,
            ckpt_dir: base_dir.join(label.replace(' ', "-")),
            mode,
            strategy: WriterStrategy::AllReplicas,
            ckpt_strategy: fastpersist::checkpoint::delta::CheckpointStrategy::Full,
            segment_bytes: 64 << 20,
            ckpt_codec: fastpersist::checkpoint::codec::CodecKind::None,
            io: IoConfig::fastpersist().microbench(),
            devices: fastpersist::io::device::DeviceMap::single(),
            dp_writers: 2,
            grad_accum: 1,
            seed: 0,
            keep_last: 2,
            lazy_staging_bytes: 256 << 20,
            lazy_max_generations: 2,
            gc_occupancy: 0.5,
            serve_cache_bytes: 0,
            log_every: 0,
        };
        let mut trainer = Trainer::new(&manifest, cfg)?;
        let loss = trainer.run()?;
        table.row(vec![
            label.to_string(),
            format!("{loss:.4}"),
            format!("{:.1}", trainer.recorder.summary("iter_s").p50 * 1e3),
            format!("{:.1}", trainer.total_stall() * 1e3),
            trainer.recorder.counter("ckpts").to_string(),
        ]);
    }
    println!("{}", table.render());
    println!("note: identical training trajectory in all three modes; only the");
    println!("checkpoint write path differs. Pipelined mode hides the write behind");
    println!("the next iteration's forward/backward (paper §4.3).");
    let _ = std::fs::remove_dir_all(&base_dir);
    Ok(())
}
