//! End-to-end driver: train a real multi-million-parameter GPT through
//! the full three-layer stack — Pallas kernels (fused Adam, fused FFN)
//! lowered into HLO, executed via PJRT from Rust, with FastPersist
//! per-iteration checkpointing — and log the loss curve.
//!
//!     cargo run --release --example train_e2e               # gpt20m, 300 steps
//!     cargo run --release --example train_e2e gpt100m 60    # 91M params
//!
//! See ARCHITECTURE.md for the substitution table behind the numbers.

use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::io::engine::{scratch_dir, IoConfig};
use fastpersist::runtime::artifacts::ArtifactManifest;
use fastpersist::training::looper::{CkptRunMode, Trainer, TrainerConfig};
use fastpersist::util::bytes::human;

fn main() -> fastpersist::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().cloned().unwrap_or_else(|| "gpt20m".to_string());
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    let manifest = ArtifactManifest::load(&ArtifactManifest::default_dir())?;
    let ckpt_dir = scratch_dir("train-e2e")?;
    let cfg = TrainerConfig {
        model: model.clone(),
        steps,
        ckpt_every: 1,
        ckpt_dir: ckpt_dir.clone(),
        mode: CkptRunMode::Pipelined,
        strategy: WriterStrategy::AllReplicas,
        ckpt_strategy: fastpersist::checkpoint::delta::CheckpointStrategy::Full,
        segment_bytes: 64 << 20,
        ckpt_codec: fastpersist::checkpoint::codec::CodecKind::None,
        io: IoConfig::fastpersist().microbench(),
        devices: fastpersist::io::device::DeviceMap::single(),
        dp_writers: 2,
        grad_accum: 1,
        seed: 0,
        keep_last: 2,
        lazy_staging_bytes: 256 << 20,
        lazy_max_generations: 2,
        gc_occupancy: 0.5,
        serve_cache_bytes: 0,
        log_every: 10,
    };
    let mut trainer = Trainer::new(&manifest, cfg)?;
    let art = trainer.state.artifact.clone();
    println!(
        "=== end-to-end: {} ({} params = {:.1}M, ckpt {} per iteration, pipelined) ===",
        model,
        art.n_params,
        art.n_params as f64 / 1e6,
        human(trainer.state.checkpoint_bytes()),
    );
    println!(
        "batch {} x seq {} | vocab {} | {} layers x d={}\n",
        art.batch, art.seq, art.vocab, art.n_layer, art.d_model
    );

    let t0 = std::time::Instant::now();
    let final_loss = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    let r = &trainer.recorder;
    let losses = r.samples("loss");
    println!("\n=== loss curve (every {} steps) ===", (steps / 20).max(1));
    for (i, chunk) in losses.chunks((steps as usize / 20).max(1)).enumerate() {
        let step = i * (steps as usize / 20).max(1) + chunk.len();
        let mean: f64 = chunk.iter().sum::<f64>() / chunk.len() as f64;
        println!("step {step:>6}  loss {mean:.4}");
    }
    println!("\n=== results ===");
    println!("initial loss      {:.4} (uniform = ln(vocab) = {:.4})",
        losses[0], (art.vocab as f64).ln());
    println!("final loss        {final_loss:.4}");
    println!("wall time         {wall:.1} s ({:.1} ms/iter)", wall / steps as f64 * 1e3);
    println!("fb p50            {:.1} ms", r.summary("fb_s").p50 * 1e3);
    println!("opt p50           {:.1} ms", r.summary("opt_s").p50 * 1e3);
    println!("ckpt stall total  {:.3} s ({:.2}% of wall)",
        trainer.total_stall(), trainer.total_stall() / wall * 100.0);
    println!("checkpoints       {} ({} each)",
        r.counter("ckpts"), human(trainer.state.checkpoint_bytes()));
    assert!(
        final_loss < losses[0] - 0.5,
        "loss did not improve: {} -> {final_loss}", losses[0]
    );
    println!("\nloss decreased {:.2} nats with per-iteration checkpointing — OK",
        losses[0] - final_loss);
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    Ok(())
}
