//! Sparse-model (MoE) checkpointing scenario — paper §5.5 / Fig. 10.
//!
//! An MoE model with expert parallelism EP=16 has 16 model slices, each
//! checkpointed by its own DP group; sparse models carry *more*
//! checkpoint state per active parameter, which amplifies FastPersist's
//! advantage. This example:
//!
//! 1. builds a 16-slice expert-sharded state on disk (real parallel
//!    writers, one directory per slice);
//! 2. compares baseline (rank-0 per slice) vs FastPersist (all-replica)
//!    write latency for real (note: this container has a single vCPU,
//!    so concurrent writers cannot win wall-clock here — the comparison
//!    demonstrates the protocol and byte-exactness; the paper-scale
//!    gains appear in the simulation below);
//! 3. prints the paper-scale Fig. 10 simulation alongside.

use std::collections::BTreeMap;
use std::sync::Arc;

use fastpersist::checkpoint::engine::CheckpointEngine;
use fastpersist::checkpoint::load::load_checkpoint;
use fastpersist::checkpoint::strategy::WriterStrategy;
use fastpersist::cluster::topology::RankPlacement;
use fastpersist::io::engine::{scratch_dir, EngineKind, IoConfig};
use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
use fastpersist::tensor::{DType, Tensor, TensorStore};
use fastpersist::util::bytes::human;
use fastpersist::util::json::Json;
use fastpersist::util::rng::Rng;
use fastpersist::util::table::Table;

const SLICES: usize = 16; // EP degree
const EXPERT_BYTES: usize = 6 << 20; // per-slice expert state (scaled down)
const DP: usize = 2;

fn expert_slice_store(slice: usize) -> TensorStore {
    let mut rng = Rng::new(slice as u64);
    let mut store = TensorStore::new();
    // expert FFN weights dominate MoE checkpoints
    let mut w = vec![0u8; EXPERT_BYTES];
    rng.fill_bytes(&mut w[..4096]);
    store
        .push(Tensor::new(&format!("experts.{slice}.ffn"), DType::U8, vec![EXPERT_BYTES], w)
            .unwrap())
        .unwrap();
    // shared trunk share (replicated, small)
    store
        .push(Tensor::new(&format!("trunk.shard{slice}"), DType::U8, vec![1 << 20],
            vec![slice as u8; 1 << 20]).unwrap())
        .unwrap();
    store
}

fn dp_group() -> Vec<RankPlacement> {
    (0..DP)
        .map(|r| RankPlacement { rank: r, node: 0, socket: r % 2, local_gpu: r })
        .collect()
}

fn write_all_slices(engine: &CheckpointEngine, base: &std::path::Path) -> f64 {
    // all slices checkpoint simultaneously (their own DP groups) — one
    // writer-thread team per slice, matching §2.1.1.
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for slice in 0..SLICES {
            let dir = base.join(format!("slice-{slice:02}"));
            scope.spawn(move || {
                let store = expert_slice_store(slice);
                let mut extra = BTreeMap::new();
                extra.insert("step".to_string(), Json::Int(1));
                extra.insert("slice".to_string(), Json::Int(slice as i64));
                engine.write(&store, extra, &dir, &dp_group()).expect("slice write");
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

fn main() -> fastpersist::Result<()> {
    let base = scratch_dir("moe-ckpt")?;
    let total_bytes = (SLICES * (EXPERT_BYTES + (1 << 20))) as u64;
    println!("=== MoE checkpointing: {SLICES} expert slices, {} total, DP={DP} ===\n",
        human(total_bytes));

    let mut table = Table::new(vec!["engine", "writers/slice", "latency (ms)", "GB/s"]);
    // ONE persistent I/O runtime serves all 16 slices' concurrent
    // checkpoints AND both engine flavors: the slices interleave through
    // the shared writer pool and recycle the same staging buffers.
    // Both engines in microbench mode (no fsync) so the comparison is
    // software-path vs software-path, not device-bound (see fig7 notes).
    let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
        io: IoConfig::fastpersist().microbench(),
        ..IoRuntimeConfig::default()
    }));
    for (label, engine, writers) in [
        (
            "baseline",
            CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::Rank0)
                .with_kind(EngineKind::Buffered),
            1usize,
        ),
        (
            "fastpersist",
            CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas),
            DP,
        ),
    ] {
        // median of 3
        let mut times: Vec<f64> = (0..3)
            .map(|i| write_all_slices(&engine, &base.join(format!("{label}-{i}"))))
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let t = times[1];
        table.row(vec![
            label.to_string(),
            writers.to_string(),
            format!("{:.1}", t * 1e3),
            format!("{:.2}", total_bytes as f64 / 1e9 / t),
        ]);
    }
    println!("{}", table.render());

    // verify one slice reloads exactly
    let (store, header, _) = load_checkpoint(&base.join("fastpersist-0/slice-07"), &runtime)?;
    assert!(store.content_eq(&expert_slice_store(7)));
    assert_eq!(header.extra["slice"], Json::Int(7));
    println!("slice 07 reload + allgather verified byte-exact");
    println!(
        "staging pool: {} buffers allocated total, {} checkouts across all slices/reps\n",
        runtime.staging().allocations(),
        runtime.staging().acquires()
    );

    // paper-scale simulation (Fig. 10)
    println!("=== paper-scale simulation (gpt3-1.8B-MoE, 67 GB checkpoints) ===");
    fastpersist::figures::fig10::run()?;
    let _ = std::fs::remove_dir_all(&base);
    Ok(())
}
