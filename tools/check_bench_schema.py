#!/usr/bin/env python3
"""Validate the schema of a benchkit JSON file (default: BENCH_fig11.json).

CI runs this after each bench smoke to guarantee the artifacts the
trajectory tooling consumes keep their shape. Common rules for every
``BENCH_<tag>.json``:

  * top-level object with bench == <tag> (inferred from the filename)
    and a non-empty "groups" list
  * every group has a title (benchkit emits "title"; legacy "name" is
    accepted) and a non-empty "results" list
  * every result row has name plus numeric n, p50_s, mean_s, min_s,
    max_s, rsd

Tag-specific rules:

  * fig8 — the submission-backend sweep must emit one row per backend
    (sync, ring, auto), each row name carrying resolved= plus the ring
    counters (batched_submissions=, sqes_max=, reaped=); on tmpfs CI
    ring/auto resolve to sync with zero counters, but the rows must
    still be present so trajectories stay comparable
  * fig11 — every lazy-path row (name contains "lazy") carries numeric
    stall_s and drain_s extras, and at least one lazy row exists (the
    synthetic section must always run, artifacts or not)
  * serve — every row carries a numeric p99_s extra (tail latency is
    the serving-layer acceptance metric), and both cold and warm rows
    exist so the cache effect is actually measured
  * codec — every row carries numeric bytes_raw, bytes_encoded,
    encode_s and decode_s extras; rows exist for all three codecs
    (codec=none, codec=lz4, codec=qdelta) so the sweep stays
    comparable; codec=none rows store exactly their raw bytes
    (bytes_encoded == bytes_raw); and at least one non-none codec
    halves the stored bytes on a delta-chain row (the headline
    acceptance ratio)

Exits non-zero with a one-line reason on the first violation.
"""

import json
import os
import re
import sys

REQUIRED_NUMERIC = ("n", "p50_s", "mean_s", "min_s", "max_s", "rsd")


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_fig11(results):
    lazy_rows = 0
    for r in results:
        if "lazy" in r["name"]:
            lazy_rows += 1
            for key in ("stall_s", "drain_s"):
                if not is_num(r.get(key)):
                    fail(
                        f"lazy result {r['name']!r} must report numeric {key}, "
                        f"got {r.get(key)!r}"
                    )
    if lazy_rows == 0:
        fail("no lazy-path rows found — the synthetic lazy section must always run")
    return f"{lazy_rows} lazy rows"


def check_serve(results):
    cold = warm = 0
    for r in results:
        if not is_num(r.get("p99_s")):
            fail(
                f"serve result {r['name']!r} must report numeric p99_s, "
                f"got {r.get('p99_s')!r}"
            )
        if "cold" in r["name"]:
            cold += 1
        if "warm" in r["name"]:
            warm += 1
    if cold == 0 or warm == 0:
        fail(f"serve bench must report both cold and warm rows (cold={cold}, warm={warm})")
    return f"{cold} cold / {warm} warm rows"


def check_fig8(results):
    backends = {}
    for r in results:
        m = re.search(r"\bbackend=(\w+)", r["name"])
        if not m:
            continue
        backends[m.group(1)] = r["name"]
        for key in ("resolved=", "batched_submissions=", "sqes_max=", "reaped="):
            if key not in r["name"]:
                fail(f"backend row {r['name']!r} must carry {key} in its name")
    for want in ("sync", "ring", "auto"):
        if want not in backends:
            fail(
                f"backend sweep must emit a backend={want} row "
                f"(got {sorted(backends)})"
            )
    sync_row = backends["sync"]
    if "batched_submissions=0" not in sync_row:
        fail(f"sync backend row must report batched_submissions=0, got {sync_row!r}")
    return f"backend rows: {', '.join(sorted(backends))}"


def check_codec(results):
    codecs = set()
    best_delta = None
    for r in results:
        m = re.search(r"\bcodec=(\w+)", r["name"])
        if not m:
            fail(f"codec result {r['name']!r} must carry codec=<name> in its name")
        codec = m.group(1)
        codecs.add(codec)
        for key in ("bytes_raw", "bytes_encoded", "encode_s", "decode_s"):
            if not is_num(r.get(key)):
                fail(
                    f"codec result {r['name']!r} must report numeric {key}, "
                    f"got {r.get(key)!r}"
                )
        if codec == "none" and r["bytes_encoded"] != r["bytes_raw"]:
            fail(
                f"codec=none row {r['name']!r} must store raw bytes exactly "
                f"(bytes_encoded={r['bytes_encoded']}, bytes_raw={r['bytes_raw']})"
            )
        if codec != "none" and "delta" in r["name"] and r["bytes_raw"] > 0:
            ratio = r["bytes_encoded"] / r["bytes_raw"]
            if best_delta is None or ratio < best_delta:
                best_delta = ratio
    for want in ("none", "lz4", "qdelta"):
        if want not in codecs:
            fail(f"codec sweep must emit codec={want} rows (got {sorted(codecs)})")
    if best_delta is None:
        fail("codec sweep must include non-none delta-chain rows")
    if best_delta > 0.5:
        fail(
            f"no non-none codec reached bytes_encoded/bytes_raw <= 0.5 on a "
            f"delta-chain row (best {best_delta:.3f})"
        )
    return f"codecs: {', '.join(sorted(codecs))}, best delta ratio {best_delta:.3f}"


TAG_CHECKS = {
    "fig8": check_fig8,
    "fig11": check_fig11,
    "serve": check_serve,
    "codec": check_codec,
}


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_fig11.json"
    m = re.fullmatch(r"BENCH_(\w+)\.json", os.path.basename(path))
    if not m:
        fail(f"{path}: file name must look like BENCH_<tag>.json")
    tag = m.group(1)
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if not isinstance(doc, dict):
        fail("top level must be an object")
    if doc.get("bench") != tag:
        fail(f"bench must be {tag!r}, got {doc.get('bench')!r}")
    groups = doc.get("groups")
    if not isinstance(groups, list) or not groups:
        fail("'groups' must be a non-empty list")

    results = []
    for i, g in enumerate(groups):
        title = g.get("title", g.get("name")) if isinstance(g, dict) else None
        if not isinstance(title, str):
            fail(f"group {i} must be an object with a string 'title'")
        rows = g.get("results")
        if not isinstance(rows, list) or not rows:
            fail(f"group {title!r} must have a non-empty 'results' list")
        results.extend(rows)

    for r in results:
        if not isinstance(r, dict) or not isinstance(r.get("name"), str):
            fail("every result must be an object with a string 'name'")
        for key in REQUIRED_NUMERIC:
            if not is_num(r.get(key)):
                fail(f"result {r['name']!r}: {key} must be numeric, got {r.get(key)!r}")

    detail = ""
    if tag in TAG_CHECKS:
        detail = ", " + TAG_CHECKS[tag](results)
    print(f"OK: {path}: {len(groups)} groups, {len(results)} results{detail}")


if __name__ == "__main__":
    main()
