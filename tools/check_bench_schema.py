#!/usr/bin/env python3
"""Validate the schema of a benchkit JSON file (default: BENCH_fig11.json).

CI runs this after the fig11 bench smoke to guarantee the artifact the
trajectory tooling consumes keeps its shape:

  * top-level object with bench == "fig11" and a non-empty "groups" list
  * every group has a name and a non-empty "results" list
  * every result row has name plus numeric n, p50_s, mean_s, min_s,
    max_s, rsd
  * every lazy-path row (name contains "lazy") carries numeric stall_s
    and drain_s extras — the whole point of the lazy bench is reporting
    those two separately
  * at least one lazy row exists (the synthetic section must always run,
    artifacts or not)

Exits non-zero with a one-line reason on the first violation.
"""

import json
import sys

REQUIRED_NUMERIC = ("n", "p50_s", "mean_s", "min_s", "max_s", "rsd")


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_fig11.json"
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if not isinstance(doc, dict):
        fail("top level must be an object")
    if doc.get("bench") != "fig11":
        fail(f"bench must be 'fig11', got {doc.get('bench')!r}")
    groups = doc.get("groups")
    if not isinstance(groups, list) or not groups:
        fail("'groups' must be a non-empty list")

    results = []
    for i, g in enumerate(groups):
        if not isinstance(g, dict) or not isinstance(g.get("name"), str):
            fail(f"group {i} must be an object with a string 'name'")
        rows = g.get("results")
        if not isinstance(rows, list) or not rows:
            fail(f"group {g['name']!r} must have a non-empty 'results' list")
        results.extend(rows)

    lazy_rows = 0
    for r in results:
        if not isinstance(r, dict) or not isinstance(r.get("name"), str):
            fail("every result must be an object with a string 'name'")
        for key in REQUIRED_NUMERIC:
            if not is_num(r.get(key)):
                fail(f"result {r['name']!r}: {key} must be numeric, got {r.get(key)!r}")
        if "lazy" in r["name"]:
            lazy_rows += 1
            for key in ("stall_s", "drain_s"):
                if not is_num(r.get(key)):
                    fail(
                        f"lazy result {r['name']!r} must report numeric {key}, "
                        f"got {r.get(key)!r}"
                    )
    if lazy_rows == 0:
        fail("no lazy-path rows found — the synthetic lazy section must always run")

    print(f"OK: {path}: {len(groups)} groups, {len(results)} results, {lazy_rows} lazy rows")


if __name__ == "__main__":
    main()
