//! Checkpoint-write simulation at cluster scale.
//!
//! Couples the real planner/strategy code (the same
//! [`WriterStrategy::select`] and [`WritePlan::balanced`] that drive
//! actual disk writes) to the calibrated bandwidth model: every model
//! slice's DP group selects its writers, every writer gets its byte
//! partition, and all writers across all slices hit the storage model
//! simultaneously — the communication-free parallel write of §4.2.

use crate::checkpoint::plan::WritePlan;
use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::bandwidth::{simulate_write, SimWrite, WritePath, WriterLoad};
use crate::cluster::{ClusterSpec, Topology};
use crate::model::GptModel;
use crate::Result;

/// Simulated checkpoint write of one model on one cluster.
#[derive(Debug, Clone)]
pub struct CkptSim {
    /// Storage-model outcome (latency, throughput, peak fraction).
    pub result: SimWrite,
    /// Writers participating across all slices.
    pub writers: usize,
    /// Bytes per writer (max partition).
    pub max_partition: u64,
}

/// Simulate checkpointing `model` at data parallelism `dp` with the given
/// writer strategy and I/O path.
pub fn simulate_model_checkpoint(
    spec: &ClusterSpec,
    model: &GptModel,
    dp: usize,
    strategy: WriterStrategy,
    path: WritePath,
) -> Result<CkptSim> {
    let topo = Topology::new(spec.clone(), model.parallelism(dp))?;
    let slices = topo.slices();
    // Each slice checkpoints its share of the state (§2.1.1: one file
    // per slice); shares are near-equal for transformer stacks.
    let slice_bytes = model.ckpt_bytes / slices as u64;
    let mut loads: Vec<WriterLoad> = Vec::new();
    let mut writers = 0usize;
    let mut max_partition = 0u64;
    for s in 0..slices {
        let group = topo.dp_group(s);
        let selected = strategy.select(&group, spec.sockets_per_node)?;
        let ranks: Vec<usize> = selected.iter().map(|p| p.rank).collect();
        let plan = WritePlan::balanced(slice_bytes, &ranks)?;
        writers += selected.len();
        max_partition = max_partition.max(plan.max_partition());
        for (placement, part) in selected.iter().zip(&plan.partitions) {
            loads.push(WriterLoad::from_placement(placement, part.len()));
        }
    }
    Ok(CkptSim { result: simulate_write(spec, path, &loads), writers, max_partition })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt3::find;

    fn spec() -> ClusterSpec {
        ClusterSpec::dgx2(8)
    }

    #[test]
    fn fig9a_speedup_range_at_128_gpus() {
        // Paper Fig. 9(a): checkpoint speedups on 128 GPUs range from
        // ~28x (gpt3-13b, DP=8) to ~116x (gpt3-0.7b, DP=128).
        let s = spec();
        let m07 = find("gpt3-0.7b").unwrap();
        let m13 = find("gpt3-13b").unwrap();
        let base07 =
            simulate_model_checkpoint(&s, m07, 128, WriterStrategy::Rank0, WritePath::Baseline)
                .unwrap();
        let fp07 = simulate_model_checkpoint(
            &s, m07, 128, WriterStrategy::AllReplicas, WritePath::FastPersist,
        )
        .unwrap();
        let speedup07 = base07.result.latency_s / fp07.result.latency_s;
        assert!(speedup07 > 50.0 && speedup07 < 250.0, "0.7b speedup={speedup07}");

        let base13 =
            simulate_model_checkpoint(&s, m13, 8, WriterStrategy::Rank0, WritePath::Baseline)
                .unwrap();
        let fp13 = simulate_model_checkpoint(
            &s, m13, 8, WriterStrategy::AllReplicas, WritePath::FastPersist,
        )
        .unwrap();
        let speedup13 = base13.result.latency_s / fp13.result.latency_s;
        assert!(speedup13 > 10.0 && speedup13 < 60.0, "13b speedup={speedup13}");
        // smaller model at higher DP enjoys the larger speedup
        assert!(speedup07 > speedup13);
    }

    #[test]
    fn fig9b_throughput_scales_with_dp() {
        let s = spec();
        let m = find("gpt3-6.7b").unwrap();
        let mut last = 0.0;
        for dp in [2, 4, 8, 16] {
            let sim = simulate_model_checkpoint(
                &s, m, dp, WriterStrategy::AllReplicas, WritePath::FastPersist,
            )
            .unwrap();
            assert!(sim.result.agg_gbps > last, "dp={dp}");
            last = sim.result.agg_gbps;
        }
        // peak approaches a large fraction of the 198.4 GB/s cluster peak
        assert!(last > 0.5 * s.cluster_write_gbps(), "agg={last}");
    }

    #[test]
    fn writer_counts_match_strategy() {
        let s = spec();
        let m = find("gpt3-13b").unwrap(); // mp=16 → 16 slices
        let all = simulate_model_checkpoint(
            &s, m, 8, WriterStrategy::AllReplicas, WritePath::FastPersist,
        )
        .unwrap();
        assert_eq!(all.writers, 16 * 8);
        let r0 =
            simulate_model_checkpoint(&s, m, 8, WriterStrategy::Rank0, WritePath::FastPersist)
                .unwrap();
        assert_eq!(r0.writers, 16);
    }

    #[test]
    fn moe_baseline_is_slow_fig10() {
        // Paper Fig. 10(b): baseline ~4 GB/s for the MoE model.
        let s = spec();
        let m = find("gpt3-1.8b-moe").unwrap();
        let base =
            simulate_model_checkpoint(&s, m, 8, WriterStrategy::Rank0, WritePath::Baseline)
                .unwrap();
        assert!(base.result.agg_gbps < 8.0, "agg={}", base.result.agg_gbps);
        let fp = simulate_model_checkpoint(
            &s, m, 8, WriterStrategy::AllReplicas, WritePath::FastPersist,
        )
        .unwrap();
        let speedup = base.result.latency_s / fp.result.latency_s;
        assert!(speedup > 15.0, "moe speedup={speedup}");
    }

    #[test]
    fn invalid_dp_errors() {
        let s = ClusterSpec::dgx2(1);
        let m = find("gpt3-13b").unwrap();
        assert!(simulate_model_checkpoint(
            &s, m, 8, WriterStrategy::AllReplicas, WritePath::FastPersist
        )
        .is_err()); // 128 ranks > 16 GPUs
    }
}
