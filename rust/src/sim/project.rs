//! Projection to DP degrees beyond the physical cluster (paper §5.7,
//! Fig. 12): scale the simulated cluster with DP (nodes = world/16) and
//! compare baseline vs. FastPersist end-to-end iteration time, plus the
//! **restart model**: how long recovery from the latest checkpoint
//! takes. Recovery is read-bound, not write-bound — the projection
//! accepts a *measured* per-node restore throughput (a real
//! [`crate::io::ReadStats`]-derived GB/s from the ReadRuntime, see
//! [`crate::figures::fig12`]) and falls back to the write-path
//! bandwidth model only when no measurement is available.

use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::bandwidth::WritePath;
use crate::cluster::ClusterSpec;
use crate::model::gpt3::{find, gpt3_13b_full_tp};
use crate::model::GptModel;
use crate::sim::ckpt_sim::simulate_model_checkpoint;
use crate::sim::trainsim::{simulate_training, CkptMode};
use crate::Result;

/// One projected data point.
#[derive(Debug, Clone)]
pub struct Projection {
    /// Model name.
    pub model: String,
    /// Projected data-parallel degree.
    pub dp: usize,
    /// Cluster size needed for that DP.
    pub nodes: usize,
    /// Baseline per-iteration seconds.
    pub baseline_iter: f64,
    /// FastPersist per-iteration seconds.
    pub fastpersist_iter: f64,
    /// Baseline / FastPersist iteration-time ratio.
    pub speedup: f64,
    /// FastPersist checkpoint overhead vs. compute-only training.
    pub fp_overhead: f64,
    /// Restart-from-checkpoint time in seconds: checkpoint bytes over
    /// the aggregate **read** bandwidth (measured per-node restore
    /// throughput × nodes when available; the write-path model
    /// otherwise — see [`project_with_read`]).
    pub recovery_s: f64,
    /// True when `recovery_s` used a measured read throughput instead
    /// of the write-bound assumption.
    pub recovery_measured: bool,
}

/// Project `model` to the given DP degree on a cluster sized to fit,
/// with the write-bound recovery fallback (no measured read
/// throughput).
pub fn project(model: &GptModel, dp: usize) -> Result<Projection> {
    project_with_read(model, dp, None)
}

/// Like [`project`], with recovery modeled from `read_gbps` — a
/// **measured** per-node restore throughput (e.g.
/// [`crate::checkpoint::load::LoadedCheckpoint::gbps`] of a real
/// restore through the ReadRuntime). Parallel per-node reads (§4.2's
/// two-step load) scale the aggregate with the node count. `None`
/// keeps the historical write-bound assumption: recovery at the
/// simulated FastPersist *write* bandwidth.
pub fn project_with_read(
    model: &GptModel,
    dp: usize,
    read_gbps: Option<f64>,
) -> Result<Projection> {
    let world = dp * model.mp();
    let nodes = world.div_ceil(16);
    let spec = ClusterSpec::dgx2(nodes);
    let strat = WriterStrategy::PerSocket;
    let base = simulate_training(&spec, model, dp, 1, CkptMode::Baseline)?;
    let fp = simulate_training(&spec, model, dp, 1, CkptMode::Pipelined(strat))?;
    let agg_read_gbps = match read_gbps {
        Some(g) if g > 0.0 => g * nodes as f64,
        _ => {
            // write-bound fallback: assume restore runs at the simulated
            // FastPersist write bandwidth (the pre-ReadRuntime model)
            simulate_model_checkpoint(&spec, model, dp, strat, WritePath::FastPersist)?
                .result
                .agg_gbps
        }
    };
    let recovery_s = model.ckpt_bytes as f64 / (agg_read_gbps.max(1e-9) * 1e9);
    Ok(Projection {
        model: model.name.to_string(),
        dp,
        nodes,
        baseline_iter: base.iter,
        fastpersist_iter: fp.iter,
        speedup: base.iter / fp.iter,
        fp_overhead: fp.slowdown - 1.0,
        recovery_s,
        recovery_measured: matches!(read_gbps, Some(g) if g > 0.0),
    })
}

/// The paper's Fig. 12 sweep: 6.7B and 13B (TP+PP), and 13B full-TP,
/// projected to DP ∈ {16, 32, 64, 128}, write-bound recovery model.
pub fn fig12_sweep() -> Result<Vec<Projection>> {
    fig12_sweep_with_read(None)
}

/// [`fig12_sweep`] with the restart model fed by a measured per-node
/// restore throughput (see [`project_with_read`]).
pub fn fig12_sweep_with_read(read_gbps: Option<f64>) -> Result<Vec<Projection>> {
    let mut out = Vec::new();
    let dps = [16usize, 32, 64, 128];
    for dp in dps {
        out.push(project_with_read(find("gpt3-6.7b").unwrap(), dp, read_gbps)?);
    }
    for dp in dps {
        out.push(project_with_read(find("gpt3-13b").unwrap(), dp, read_gbps)?);
    }
    let full_tp = gpt3_13b_full_tp();
    for dp in dps {
        let mut p = project_with_read(&full_tp, dp, read_gbps)?;
        p.model = "gpt3-13b-fulltp".into();
        out.push(p);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grows_with_dp() {
        // Fig. 12: baseline overhead grows with DP while FastPersist
        // stays ~flat, so the projected speedup increases.
        let m = find("gpt3-6.7b").unwrap();
        let s16 = project(m, 16).unwrap().speedup;
        let s128 = project(m, 128).unwrap().speedup;
        assert!(s128 > s16 * 2.0, "s16={s16} s128={s128}");
    }

    #[test]
    fn fp_overhead_stays_negligible() {
        // Paper: FastPersist keeps checkpoint overhead < 2% out to
        // thousands of GPUs.
        for p in fig12_sweep().unwrap() {
            assert!(p.fp_overhead < 0.02, "{} dp={}: {}", p.model, p.dp, p.fp_overhead);
        }
    }

    #[test]
    fn speedups_in_paper_range_at_dp128() {
        // Paper: up to 10.2x (6.7B), 3.6x (13B), 11.3x (13B full TP).
        let sweep = fig12_sweep().unwrap();
        let at = |name: &str| {
            sweep
                .iter()
                .find(|p| p.model == name && p.dp == 128)
                .unwrap()
                .speedup
        };
        let s67 = at("gpt3-6.7b");
        let s13 = at("gpt3-13b");
        let s13ftp = at("gpt3-13b-fulltp");
        assert!(s67 > 3.0 && s67 < 30.0, "6.7b={s67}");
        assert!(s13 > 1.5 && s13 < 12.0, "13b={s13}");
        // full-TP removes the PP bubble → bigger speedup than TP+PP
        assert!(s13ftp > s13, "fulltp={s13ftp} vs {s13}");
    }

    #[test]
    fn nodes_scale_with_world() {
        let m = find("gpt3-13b").unwrap();
        let p = project(m, 128).unwrap();
        assert_eq!(p.nodes, 128 * 16 / 16);
    }

    #[test]
    fn recovery_uses_measured_read_throughput_when_given() {
        let m = find("gpt3-6.7b").unwrap();
        let fallback = project_with_read(m, 16, None).unwrap();
        assert!(fallback.recovery_s > 0.0);
        assert!(!fallback.recovery_measured, "no measurement -> write-bound assumption");
        let measured = project_with_read(m, 16, Some(2.0)).unwrap();
        assert!(measured.recovery_measured);
        // 16 nodes x 2 GB/s aggregate read bandwidth
        let expect = m.ckpt_bytes as f64 / (2.0 * 16.0 * 1e9);
        assert!((measured.recovery_s - expect).abs() < 1e-9, "{}", measured.recovery_s);
        // faster measured reads shrink recovery
        let faster = project_with_read(m, 16, Some(8.0)).unwrap();
        assert!(faster.recovery_s < measured.recovery_s);
        // non-positive measurements fall back instead of dividing by zero
        let degenerate = project_with_read(m, 16, Some(0.0)).unwrap();
        assert!(!degenerate.recovery_measured);
        assert!((degenerate.recovery_s - fallback.recovery_s).abs() < 1e-9);
    }
}
