//! Discrete-event-style training/checkpoint simulator for paper-scale
//! experiments (the multi-node figures run here; single-writer effects
//! are measured for real in [`crate::io`]).

pub mod ckpt_sim;
pub mod project;
pub mod trainsim;

pub use ckpt_sim::{simulate_model_checkpoint, CkptSim};
pub use trainsim::{simulate_training, CkptMode, TrainSim};
