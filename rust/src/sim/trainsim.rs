//! End-to-end training simulation with per-iteration checkpointing
//! (Fig. 4's four timelines).
//!
//! Steady-state per-iteration accounting:
//!
//! * `None`        — T = F+B + O (no checkpoint).
//! * `Baseline`    — T = F+B + O + C_base: rank 0 writes synchronously,
//!   all other ranks stall (Fig. 4a).
//! * `Sync`        — T = F+B + O + C_fp: NVMe+parallel write, still
//!   synchronous (Fig. 4b/c).
//! * `Pipelined`   — C_i overlaps F+B of iteration i+1; the next
//!   optimizer stalls only for max(0, C_fp − (F+B)) (Fig. 4d).
//!
//! Checkpoint latencies come from [`crate::sim::ckpt_sim`]; compute
//! times from the analytic model in [`crate::model`].

use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::bandwidth::WritePath;
use crate::cluster::ClusterSpec;
use crate::model::GptModel;
use crate::sim::ckpt_sim::simulate_model_checkpoint;
use crate::Result;

/// Checkpointing mode for the simulated run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CkptMode {
    /// No checkpointing.
    None,
    /// torch.save: single writer per slice, buffered, synchronous.
    Baseline,
    /// FastPersist write path, but synchronous (no pipelining).
    Sync(WriterStrategy),
    /// Full FastPersist: parallel writes + pipelining.
    Pipelined(WriterStrategy),
}

/// Steady-state per-iteration simulation result.
#[derive(Debug, Clone, Copy)]
pub struct TrainSim {
    /// Forward+backward seconds.
    pub fb: f64,
    /// Optimizer seconds.
    pub opt: f64,
    /// Checkpoint write latency (0 when mode == None).
    pub ckpt_latency: f64,
    /// Per-iteration training stall caused by checkpointing.
    pub stall: f64,
    /// Effective iteration seconds.
    pub iter: f64,
    /// Slowdown vs. checkpoint-free training (1.0 = free).
    pub slowdown: f64,
}

/// Simulate steady-state training of `model` at `dp`/`ga` with
/// checkpointing every iteration under `mode`.
pub fn simulate_training(
    spec: &ClusterSpec,
    model: &GptModel,
    dp: usize,
    ga: u64,
    mode: CkptMode,
) -> Result<TrainSim> {
    let it = model.iter_time(dp, ga);
    let compute = it.total();
    let (ckpt_latency, stall) = match mode {
        CkptMode::None => (0.0, 0.0),
        CkptMode::Baseline => {
            let c = simulate_model_checkpoint(
                spec, model, dp, WriterStrategy::Rank0, WritePath::Baseline,
            )?
            .result
            .latency_s;
            (c, c)
        }
        CkptMode::Sync(strategy) => {
            let c = simulate_model_checkpoint(spec, model, dp, strategy, WritePath::FastPersist)?
                .result
                .latency_s;
            (c, c)
        }
        CkptMode::Pipelined(strategy) => {
            let c = simulate_model_checkpoint(spec, model, dp, strategy, WritePath::FastPersist)?
                .result
                .latency_s;
            // overlap with next iteration's F+B (§4.3)
            (c, (c - it.fb).max(0.0))
        }
    };
    let iter = compute + stall;
    Ok(TrainSim {
        fb: it.fb,
        opt: it.opt,
        ckpt_latency,
        stall,
        iter,
        slowdown: iter / compute,
    })
}

/// §5.6.1 GAS-sweep variant: fixed micro-batch `mb`, per-replica batch
/// mb·ga (compute grows with GAS while the checkpoint stays constant).
pub fn simulate_training_fixed_micro(
    spec: &ClusterSpec,
    model: &GptModel,
    dp: usize,
    mb: u64,
    ga: u64,
    mode: CkptMode,
) -> Result<TrainSim> {
    let fb = model.fb_time_fixed_micro(mb, ga);
    let opt = model.opt_time();
    let compute = fb + opt;
    let (ckpt_latency, stall) = match mode {
        CkptMode::None => (0.0, 0.0),
        CkptMode::Baseline => {
            let c = simulate_model_checkpoint(
                spec, model, dp, WriterStrategy::Rank0, WritePath::Baseline,
            )?
            .result
            .latency_s;
            (c, c)
        }
        CkptMode::Sync(strategy) => {
            let c = simulate_model_checkpoint(spec, model, dp, strategy, WritePath::FastPersist)?
                .result
                .latency_s;
            (c, c)
        }
        CkptMode::Pipelined(strategy) => {
            let c = simulate_model_checkpoint(spec, model, dp, strategy, WritePath::FastPersist)?
                .result
                .latency_s;
            (c, (c - fb).max(0.0))
        }
    };
    let iter = compute + stall;
    Ok(TrainSim { fb, opt, ckpt_latency, stall, iter, slowdown: iter / compute })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt3::find;

    fn spec() -> ClusterSpec {
        ClusterSpec::dgx2(8)
    }

    #[test]
    fn fig4_ordering_baseline_sync_pipelined() {
        let s = spec();
        let m = find("gpt3-2.7b").unwrap();
        let base = simulate_training(&s, m, 32, 1, CkptMode::Baseline).unwrap();
        let sync =
            simulate_training(&s, m, 32, 1, CkptMode::Sync(WriterStrategy::AllReplicas)).unwrap();
        let pipe =
            simulate_training(&s, m, 32, 1, CkptMode::Pipelined(WriterStrategy::AllReplicas))
                .unwrap();
        let none = simulate_training(&s, m, 32, 1, CkptMode::None).unwrap();
        assert!(base.iter > sync.iter, "NVMe+parallel must beat baseline");
        assert!(sync.iter >= pipe.iter, "pipelining must not hurt");
        assert!(pipe.iter >= none.iter, "checkpointing is never free-er than free");
    }

    #[test]
    fn fig11b_dense_models_under_5pct_overhead() {
        // Paper Fig. 11(b): on 8 nodes, 1.3b–13b models checkpoint every
        // iteration with < 5% slowdown under full FastPersist.
        let s = spec();
        for name in ["gpt3-1.3b", "gpt3-2.7b", "gpt3-6.7b", "gpt3-13b"] {
            let m = find(name).unwrap();
            let dp = 128 / m.mp();
            let sim =
                simulate_training(&s, m, dp, 8, CkptMode::Pipelined(WriterStrategy::PerSocket))
                    .unwrap();
            assert!(sim.slowdown < 1.05, "{name}: slowdown {}", sim.slowdown);
        }
    }

    #[test]
    fn fig11a_pipelining_helps_low_gas() {
        // Paper Fig. 11(a): gpt3-1.3b DP=1 — pipelining beats sync for
        // GAS < 64, converging at high GAS where compute dwarfs I/O.
        let s = ClusterSpec::dgx2(1);
        let m = find("gpt3-1.3b").unwrap();
        let strat = WriterStrategy::AllReplicas;
        let low_sync = simulate_training(&s, m, 1, 4, CkptMode::Sync(strat)).unwrap();
        let low_pipe = simulate_training(&s, m, 1, 4, CkptMode::Pipelined(strat)).unwrap();
        assert!(low_pipe.slowdown < low_sync.slowdown);
        let hi_sync = simulate_training(&s, m, 1, 512, CkptMode::Sync(strat)).unwrap();
        let hi_pipe = simulate_training(&s, m, 1, 512, CkptMode::Pipelined(strat)).unwrap();
        // at GAS=512 both are near-free and near-equal
        assert!(hi_sync.slowdown < 1.1 && hi_pipe.slowdown < 1.1);
        let gap = (hi_sync.slowdown - hi_pipe.slowdown).abs();
        assert!(gap < 0.05, "gap={gap}");
    }

    #[test]
    fn e2e_speedup_range_fig9c() {
        // Paper Fig. 9(c): E2E speedups at 128 GPUs from 1.6x (13b) to
        // 21.8x (0.7b). Check our simulation lands in range and ordering.
        let s = spec();
        let m07 = find("gpt3-0.7b").unwrap();
        let m13 = find("gpt3-13b").unwrap();
        let strat = WriterStrategy::PerSocket;
        let su07 = simulate_training(&s, m07, 128, 1, CkptMode::Baseline).unwrap().iter
            / simulate_training(&s, m07, 128, 1, CkptMode::Pipelined(strat)).unwrap().iter;
        let su13 = simulate_training(&s, m13, 8, 1, CkptMode::Baseline).unwrap().iter
            / simulate_training(&s, m13, 8, 1, CkptMode::Pipelined(strat)).unwrap().iter;
        assert!(su07 > 8.0 && su07 < 60.0, "0.7b e2e speedup={su07}");
        assert!(su13 > 1.05 && su13 < 3.0, "13b e2e speedup={su13}");
        assert!(su07 > su13);
    }

    #[test]
    fn stall_is_zero_when_fb_covers_write() {
        let s = spec();
        let m = find("gpt3-6.7b").unwrap();
        let sim = simulate_training(&s, m, 16, 16, CkptMode::Pipelined(WriterStrategy::PerSocket))
            .unwrap();
        assert_eq!(sim.stall, 0.0, "ckpt {} fb {}", sim.ckpt_latency, sim.fb);
        assert!((sim.slowdown - 1.0).abs() < 1e-9);
    }
}
