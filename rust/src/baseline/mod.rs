//! Baseline checkpointing — the `torch.save()` comparator (§3.1).

pub mod torch_save;

pub use torch_save::TorchSave;
