//! `torch.save()`-style baseline writer.
//!
//! Structure matched to the paper's description of the baseline (§2.1.3,
//! §3.1): the *first rank of each model slice* serializes the full
//! checkpoint state and writes it through the traditional buffered I/O
//! stack as a sequence of small writes — no alignment, no pinned
//! staging, no write parallelism, while the other DP ranks stall.
//! The serialization format is the same as FastPersist's (the paper
//! changes only the disk-write path, §5.1), so comparisons isolate the
//! I/O techniques.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::checkpoint::engine::{CheckpointEngine, CheckpointOutcome};
use crate::io::engine::IoConfig;
use crate::tensor::TensorStore;
use crate::util::json::Json;
use crate::Result;

/// Baseline single-writer checkpointing facade.
pub struct TorchSave {
    engine: CheckpointEngine,
}

impl Default for TorchSave {
    fn default() -> Self {
        Self::new()
    }
}

impl TorchSave {
    /// A baseline writer with default buffered configuration.
    pub fn new() -> TorchSave {
        TorchSave { engine: CheckpointEngine::baseline() }
    }

    /// With a custom buffered chunk size (for microbenchmarks).
    pub fn with_chunk(chunk: usize) -> TorchSave {
        let mut cfg = IoConfig::baseline();
        cfg.buffered_chunk = chunk;
        TorchSave { engine: CheckpointEngine::new(cfg, crate::checkpoint::WriterStrategy::Rank0) }
    }

    /// Save a checkpoint: rank 0 writes everything, buffered.
    pub fn save(
        &self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
    ) -> Result<CheckpointOutcome> {
        self.engine.write_single(store, extra, dir)
    }

    /// Save and report the latency training would observe: with the
    /// baseline, *all* ranks stall for the full write (Fig. 4a).
    pub fn save_blocking(
        &self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
    ) -> Result<(CheckpointOutcome, Duration)> {
        let t0 = Instant::now();
        let out = self.save(store, extra, dir)?;
        Ok((out, t0.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::load::load_checkpoint;
    use crate::io::engine::scratch_dir;
    use crate::tensor::{DType, Tensor};
    use crate::util::rng::Rng;

    fn store(n: usize) -> TensorStore {
        let mut s = TensorStore::new();
        let mut data = vec![0u8; n];
        Rng::new(4).fill_bytes(&mut data);
        s.push(Tensor::new("blob", DType::U8, vec![n], data).unwrap()).unwrap();
        s
    }

    #[test]
    fn baseline_roundtrip() {
        let dir = scratch_dir("torchsave").unwrap();
        let s = store(300_000);
        let out = TorchSave::new().save(&s, BTreeMap::new(), &dir).unwrap();
        assert_eq!(out.stats.len(), 1);
        assert!(!out.stats[0].o_direct); // traditional path
        let rt = crate::io::IoRuntime::shared(IoConfig::baseline().microbench());
        let (loaded, _, _) = load_checkpoint(&dir, &rt).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn many_small_writes_counted() {
        let dir = scratch_dir("torchsave-ops").unwrap();
        let s = store(5 << 20);
        let out = TorchSave::with_chunk(64 << 10).save(&s, BTreeMap::new(), &dir).unwrap();
        // 5 MiB at 64 KiB chunks → at least 80 write ops
        assert!(out.stats[0].write_ops >= 80, "ops={}", out.stats[0].write_ops);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn blocking_latency_covers_write() {
        let dir = scratch_dir("torchsave-lat").unwrap();
        let s = store(1 << 20);
        let (out, stall) = TorchSave::new().save_blocking(&s, BTreeMap::new(), &dir).unwrap();
        assert!(stall >= out.stats[0].elapsed);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
