//! # FastPersist — accelerating model checkpointing in deep learning
//!
//! A Rust + JAX + Pallas reproduction of *FastPersist: Accelerating Model
//! Checkpointing in Deep Learning* (Wang, Ruwase, Xie, He — Microsoft
//! DeepSpeed, 2024).
//!
//! The paper's contribution is an I/O + coordination system with three
//! composable techniques, all implemented here as a first-class library:
//!
//! 1. **NVMe-optimized checkpoint writes** ([`io`]): direct, aligned,
//!    asynchronous writes from a pinned staging-buffer pool, with
//!    double-buffering to overlap the accelerator→DRAM copy with the
//!    DRAM→SSD drain, and an aligned-prefix/unaligned-suffix file split.
//!    All I/O resources live in a persistent [`io::IoRuntime`]: one
//!    recycled staging pool, persistent writer/drain thread pools fed by
//!    a submission/completion ticket queue, and an [`io::DeviceMap`]
//!    striping checkpoint partitions across the available SSDs. The
//!    restore path is the mirror image ([`io::read`]): a persistent
//!    reader pool assembling coalesced positioned reads into one
//!    single-copy stream buffer, with verification folded into the
//!    read pass.
//! 2. **Parallel checkpoint writes across data-parallel ranks**
//!    ([`checkpoint::plan`], [`checkpoint::strategy`]): byte-granularity
//!    partitioning of the serialized checkpoint over DP replicas, with
//!    writer-subset selection (all replicas vs. one writer per CPU
//!    socket) to balance per-writer write size against I/O contention.
//! 3. **Pipelined checkpointing** ([`checkpoint::pipeline`]): a decoupled
//!    helper worker overlaps the checkpoint write of iteration *i* with
//!    the forward/backward passes of iteration *i+1*, synchronizing only
//!    at the optimizer step — directly to durable storage, with no
//!    volatile-snapshot data-loss window.
//!
//! The training computation being checkpointed is a GPT-3-architecture
//! transformer authored in JAX with Pallas kernels (fused Adam, fused
//! FFN, checkpoint pack), AOT-lowered to HLO text at build time and
//! executed from Rust via the PJRT C API ([`runtime`]). Python never
//! runs at training time.
//!
//! Paper-scale experiments (8× DGX-2, 128 V100s, 24.8 GB/s of NVMe per
//! node) run on a calibrated cluster/storage simulator ([`cluster`],
//! [`sim`]); single-writer I/O effects are measured for real on local
//! disk. See `ARCHITECTURE.md` (repo root) for the substitution table —
//! page-cache-as-NVMe, threads-as-ranks, `DeviceMap`-as-SSD-array —
//! and the PJRT stub arrangement.

#![warn(missing_docs)]

pub mod baseline;
pub mod benchkit;
pub mod checkpoint;
pub mod cluster;
pub mod error;
pub mod figures;
pub mod io;
pub mod metrics;
pub mod model;
pub mod prop;
pub mod runtime;
pub mod serialize;
pub mod sim;
pub mod tensor;
pub mod training;
pub mod util;

pub use error::{Error, Result};
