//! The persistent I/O runtime: shared staging buffers, a persistent
//! writer pool with submission/completion tickets, per-device drain
//! lanes, and multi-device partition routing.
//!
//! FastPersist's write-path speedups rest on two structural properties
//! (§4.1, §4.3): the pinned staging buffers are **allocated once and
//! recycled across checkpoints**, and the threads moving bytes are
//! **long-lived workers**, not per-checkpoint spawns. [`IoRuntime`]
//! owns both:
//!
//! * one aligned [`BufferPool`] (the pinned staging memory), created at
//!   runtime construction, checked out by sinks and returned on finish —
//!   [`BufferPool::allocations`] stays constant on the steady-state
//!   path while [`BufferPool::acquires`] climbs;
//! * one [`crate::io::write::DrainPool`] of **per-device submission
//!   queues** (at least one lane per configured device) servicing every
//!   sink's staged-extent drains (positioned, so order-free);
//! * one persistent **writer pool** consuming [`WriteJob`]s: a
//!   submission *plans* the job on the submitting thread (the job's
//!   [`crate::io::write::WritePlan`] — extents, op schedule, queue
//!   depth) and returns a [`Ticket`] immediately; a writer-pool thread
//!   then *executes* the plan through the unified
//!   [`crate::io::write::WritePipeline`], and `Ticket::wait` delivers
//!   the partition's [`WriteStats`];
//! * a [`DeviceMap`] striping checkpoint partitions across the SSDs of
//!   the training environment and caching each device's **O_DIRECT
//!   capability probe**;
//! * a persistent **reader pool** consuming [`crate::io::read::ReadJob`]s
//!   (`submit_read -> ReadTicket`), the restore-side mirror of the
//!   writer pool — see [`crate::io::read`] for the coalescing planner
//!   and the single-copy stream buffer it serves. Read jobs borrow the
//!   same staging pool for their O_DIRECT bounce buffers and consult
//!   the same capability cache.
//!
//! One runtime serves any number of concurrent checkpoints (pipelined
//! helper + direct writes interleave through the same queues).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::Arc;

use crate::io::buffer::BufferPool;
use crate::io::device::DeviceMap;
use crate::io::direct_engine::DirectEngine;
use crate::io::engine::{EngineKind, IoConfig, WriteEngine, WriteStats};
use crate::io::read::{ReadCtx, ReadJob, ReadStats, StreamBuffer};
use crate::io::sync_engine::BufferedEngine;
use crate::io::write::{resolve_ring_backend, DrainPool, LaneStats, WritePlan, WriteResources};
use crate::serialize::writer::SerializedCheckpoint;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// Construction-time knobs for the runtime.
#[derive(Debug, Clone)]
pub struct IoRuntimeConfig {
    /// Write-path tuning (engine kind, staging size, alignment, queue
    /// depth, durability) — normalized at construction.
    pub io: IoConfig,
    /// Persistent partition-writer threads (the simulated rank writers).
    pub writer_threads: usize,
    /// Persistent restore-reader threads (the parallel loaders of
    /// §4.2's two-step load), servicing [`IoRuntime::submit_read`].
    pub reader_threads: usize,
    /// Drain submission lanes. The runtime creates
    /// `max(drain_threads, devices.len(), 1)` lanes so every configured
    /// device owns its own ordered submission queue.
    pub drain_threads: usize,
    /// Staging buffers in the shared pool (each `io.io_buf_size` bytes).
    pub staging_buffers: usize,
    /// Split threshold for intra-partition restore parallelism: a
    /// single partition larger than this is read by several parallel
    /// [`ReadJob`]s instead of one, so one huge partition no longer
    /// serializes restore on a single reader. Default 256 MiB.
    pub read_split_bytes: u64,
    /// Mount points to stripe checkpoint partitions across.
    pub devices: DeviceMap,
}

impl Default for IoRuntimeConfig {
    fn default() -> Self {
        IoRuntimeConfig {
            io: IoConfig::default(),
            writer_threads: 4,
            reader_threads: 4,
            drain_threads: 2,
            staging_buffers: 4,
            read_split_bytes: 256 << 20,
            devices: DeviceMap::single(),
        }
    }
}

/// What a [`WriteJob`] writes.
pub enum WriteSource {
    /// Byte range `[start, end)` of a serialized checkpoint (a
    /// partition).
    Range { ser: Arc<SerializedCheckpoint>, start: u64, end: u64 },
    /// A segment store (see [`crate::checkpoint::delta`]): an encoded
    /// segment header followed by a set of stream byte ranges of one
    /// serialized checkpoint, packed back to back. This is how a base
    /// checkpoint's N dirty chunks become one large sequential write
    /// (one file, one fsync) instead of N small ones.
    Chunks {
        /// The serialized checkpoint the ranges index into.
        ser: Arc<SerializedCheckpoint>,
        /// Segment-header bytes written before the first chunk.
        prefix: Vec<u8>,
        /// Stream byte ranges `[start, end)`, written in order after
        /// `prefix`.
        ranges: Vec<(u64, u64)>,
    },
    /// A segment store whose payload mixes **raw** stream ranges with
    /// **codec-encoded** chunk images (see
    /// [`crate::checkpoint::codec`]): the parts are written back to
    /// back after `prefix`, in order. Raw parts stay zero-copy
    /// references into the serialized stream; encoded parts are owned
    /// buffers produced by the encode stage. The drain/fsync mechanics
    /// below this source are identical to [`WriteSource::Chunks`] —
    /// codecs change *what bytes* a segment holds, never *how* they
    /// reach the device.
    Parts {
        /// The serialized checkpoint the raw parts index into.
        ser: Arc<SerializedCheckpoint>,
        /// Segment-header bytes written before the first part.
        prefix: Vec<u8>,
        /// Payload pieces, written in order after `prefix`.
        parts: Vec<SegPart>,
    },
    /// A raw byte buffer (microbenchmarks, single-file helpers).
    Bytes(Arc<Vec<u8>>),
}

/// One payload piece of a [`WriteSource::Parts`] segment.
pub enum SegPart {
    /// Stream byte range `[start, end)` of the job's serialized
    /// checkpoint, written verbatim (an unencoded chunk, or a merged
    /// run of adjacent unencoded chunks).
    Raw { start: u64, end: u64 },
    /// Codec-encoded chunk bytes, owned by the job.
    Owned(Vec<u8>),
}

impl SegPart {
    /// Bytes this part contributes to the segment payload.
    pub fn len(&self) -> u64 {
        match self {
            SegPart::Raw { start, end } => end - start,
            SegPart::Owned(b) => b.len() as u64,
        }
    }

    /// True for zero-length parts.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl WriteSource {
    /// Number of bytes this source will write.
    pub fn len(&self) -> u64 {
        match self {
            WriteSource::Range { start, end, .. } => end - start,
            WriteSource::Chunks { prefix, ranges, .. } => {
                prefix.len() as u64 + ranges.iter().map(|(s, e)| e - s).sum::<u64>()
            }
            WriteSource::Parts { prefix, parts, .. } => {
                prefix.len() as u64 + parts.iter().map(SegPart::len).sum::<u64>()
            }
            WriteSource::Bytes(b) => b.len() as u64,
        }
    }

    /// True for zero-length sources.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn write_to(&self, sink: &mut dyn crate::io::engine::Sink) -> Result<()> {
        match self {
            WriteSource::Range { ser, start, end } => ser.write_range_to(*start, *end, sink),
            WriteSource::Chunks { ser, prefix, ranges } => {
                if !prefix.is_empty() {
                    sink.write(prefix)?;
                }
                ser.write_ranges_to(ranges, sink)
            }
            WriteSource::Parts { ser, prefix, parts } => {
                if !prefix.is_empty() {
                    sink.write(prefix)?;
                }
                for part in parts {
                    match part {
                        SegPart::Raw { start, end } => {
                            ser.write_range_to(*start, *end, sink)?;
                        }
                        SegPart::Owned(b) => {
                            if !b.is_empty() {
                                sink.write(b)?;
                            }
                        }
                    }
                }
                Ok(())
            }
            WriteSource::Bytes(b) => sink.write(b.as_slice()),
        }
    }
}

/// One unit of work for the writer pool: persist `source` to `path`.
pub struct WriteJob {
    /// What to write.
    pub source: WriteSource,
    /// Destination file path.
    pub path: PathBuf,
    /// Engine override; `None` uses the runtime's configured kind. Lets
    /// a baseline (buffered) and a FastPersist engine share one runtime.
    pub kind: Option<EngineKind>,
}

impl WriteJob {
    /// A partition-range job with the runtime's default engine kind.
    pub fn range(ser: Arc<SerializedCheckpoint>, start: u64, end: u64, path: PathBuf) -> WriteJob {
        WriteJob { source: WriteSource::Range { ser, start, end }, path, kind: None }
    }

    /// A raw-bytes job with the runtime's default engine kind.
    pub fn bytes(data: Arc<Vec<u8>>, path: PathBuf) -> WriteJob {
        WriteJob { source: WriteSource::Bytes(data), path, kind: None }
    }

    /// A segment-store job: `prefix` (segment header) followed by the
    /// given stream ranges of `ser`, with the runtime's default engine
    /// kind. One such job is one file and one fsync, however many
    /// chunks it packs.
    pub fn chunks(
        ser: Arc<SerializedCheckpoint>,
        prefix: Vec<u8>,
        ranges: Vec<(u64, u64)>,
        path: PathBuf,
    ) -> WriteJob {
        WriteJob { source: WriteSource::Chunks { ser, prefix, ranges }, path, kind: None }
    }

    /// A mixed segment-store job: `prefix` (segment header) followed by
    /// raw stream ranges and owned codec-encoded buffers, in part
    /// order. The encoded-chunk counterpart of [`WriteJob::chunks`] —
    /// still one file and one fsync per job.
    pub fn parts(
        ser: Arc<SerializedCheckpoint>,
        prefix: Vec<u8>,
        parts: Vec<SegPart>,
        path: PathBuf,
    ) -> WriteJob {
        WriteJob { source: WriteSource::Parts { ser, prefix, parts }, path, kind: None }
    }

    /// Override the engine kind for this job only.
    pub fn with_kind(mut self, kind: EngineKind) -> WriteJob {
        self.kind = Some(kind);
        self
    }
}

/// Completion handle for a submitted [`WriteJob`].
pub struct Ticket {
    rx: Receiver<Result<WriteStats>>,
}

impl Ticket {
    /// Block until the job is durable (per config); returns its stats.
    pub fn wait(self) -> Result<WriteStats> {
        self.rx
            .recv()
            .map_err(|_| Error::Internal("writer pool dropped the job".into()))?
    }

    /// Non-blocking completion poll.
    pub fn try_wait(&self) -> Option<Result<WriteStats>> {
        self.rx.try_recv().ok()
    }
}

/// Completion handle for a submitted [`ReadJob`] — the restore-side
/// [`Ticket`].
pub struct ReadTicket {
    rx: Receiver<Result<ReadStats>>,
}

impl ReadTicket {
    /// Block until the job's runs are read and its folded checks pass;
    /// returns the job's counters.
    pub fn wait(self) -> Result<ReadStats> {
        self.rx
            .recv()
            .map_err(|_| Error::Internal("reader pool dropped the job".into()))?
    }

    /// Non-blocking completion poll.
    pub fn try_wait(&self) -> Option<Result<ReadStats>> {
        self.rx.try_recv().ok()
    }
}

/// Engine set + shared resources; lives behind an `Arc` so writer
/// threads outlive any single submission site.
struct RuntimeCore {
    io: IoConfig,
    staging: BufferPool,
    devices: DeviceMap,
    read_split_bytes: u64,
    drain_lanes: usize,
    /// Whether the batched ring backend resolved at construction; the
    /// per-filesystem probe still decides per checkpoint directory.
    ring_enabled: bool,
    /// Shared drain-lane pool (same instance every engine drains
    /// through) — kept here so per-lane counters stay observable.
    drain: DrainPool,
    buffered: BufferedEngine,
    direct_single: DirectEngine,
    direct_double: DirectEngine,
    /// Stream-assembly buffers handed out by [`IoRuntime::alloc_stream`]
    /// (count, bytes) — the restore-side buffer accounting: a
    /// single-copy load allocates exactly one stream of `total_len`.
    stream_allocs: AtomicU64,
    stream_alloc_bytes: AtomicU64,
    /// Read jobs submitted but not yet completed — read-concurrency
    /// observability for the serve layer and tests.
    reads_inflight: AtomicU64,
}

impl RuntimeCore {
    fn engine_for(&self, kind: EngineKind) -> &dyn WriteEngine {
        match kind {
            EngineKind::Buffered => &self.buffered,
            EngineKind::DirectSingle => &self.direct_single,
            EngineKind::DirectDouble => &self.direct_double,
        }
    }

    /// Submission-time half: derive the job's op schedule.
    fn plan_for(&self, job: &WriteJob) -> WritePlan {
        self.engine_for(job.kind.unwrap_or(self.io.kind))
            .plan(Some(job.source.len()))
    }

    /// Writer-thread half: realize an already-constructed plan.
    fn execute_planned(&self, job: &WriteJob, plan: WritePlan) -> Result<WriteStats> {
        // A halted fault plan models process death: the runtime must not
        // create directories or truncate destination files for jobs that
        // were queued behind the fatal boundary.
        if let Some(f) = &self.io.fault {
            f.check_alive(crate::io::fault::FaultSite::Stage)?;
        }
        if let Some(parent) = job.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let engine = self.engine_for(job.kind.unwrap_or(self.io.kind));
        let mut sink = engine.create_planned(&job.path, plan, Some(job.source.len()))?;
        job.source.write_to(sink.as_mut())?;
        sink.finish()
    }
}

/// The long-lived I/O subsystem. Construct once (per trainer, per
/// process), share via `Arc`, submit forever.
pub struct IoRuntime {
    core: Arc<RuntimeCore>,
    writers: ThreadPool,
    readers: ThreadPool,
}

impl IoRuntime {
    /// Build the runtime: allocate-on-demand staging pool, persistent
    /// per-device drain lanes + writer pool, device map.
    pub fn new(cfg: IoRuntimeConfig) -> IoRuntime {
        let io = cfg.io.normalized();
        let staging =
            BufferPool::with_align(cfg.staging_buffers.max(1), io.io_buf_size, io.align);
        let lanes = cfg.drain_threads.max(cfg.devices.len()).max(1);
        let drain = DrainPool::new(lanes);
        // Backend selection happens once per runtime: resolving the ring
        // backend here is what registers the staging pool's buffers with
        // the ring path for the runtime's whole lifetime.
        let ring = resolve_ring_backend(&io, &staging);
        let ring_enabled = ring.is_some();
        let res = WriteResources {
            pool: staging.clone(),
            drain: drain.clone(),
            devices: cfg.devices.clone(),
            ring,
        };
        let core = Arc::new(RuntimeCore {
            buffered: BufferedEngine::with_resources(
                IoConfig { kind: EngineKind::Buffered, ..io.clone() },
                res.clone(),
            ),
            direct_single: DirectEngine::with_resources(
                IoConfig { kind: EngineKind::DirectSingle, ..io.clone() },
                res.clone(),
            ),
            direct_double: DirectEngine::with_resources(
                IoConfig { kind: EngineKind::DirectDouble, ..io.clone() },
                res,
            ),
            io,
            staging,
            devices: cfg.devices,
            read_split_bytes: cfg.read_split_bytes.max(1),
            drain_lanes: lanes,
            ring_enabled,
            drain,
            stream_allocs: AtomicU64::new(0),
            stream_alloc_bytes: AtomicU64::new(0),
            reads_inflight: AtomicU64::new(0),
        });
        let writers = ThreadPool::new(cfg.writer_threads.max(1), "ckpt-writer");
        let readers = ThreadPool::new(cfg.reader_threads.max(1), "ckpt-reader");
        IoRuntime { core, writers, readers }
    }

    /// Construct with defaults around an [`IoConfig`], wrapped for
    /// sharing.
    pub fn shared(io: IoConfig) -> Arc<IoRuntime> {
        Arc::new(IoRuntime::new(IoRuntimeConfig { io, ..IoRuntimeConfig::default() }))
    }

    /// The normalized write-path configuration this runtime serves.
    pub fn io_config(&self) -> &IoConfig {
        &self.core.io
    }

    /// The device map partitions are striped over.
    pub fn devices(&self) -> &DeviceMap {
        &self.core.devices
    }

    /// True when the batched ring backend resolved at construction
    /// (feature compiled in, backend selected, process-level setup OK).
    /// The per-filesystem probe still decides per directory.
    pub fn ring_enabled(&self) -> bool {
        self.core.ring_enabled
    }

    /// Name of the submission backend that will drain checkpoints
    /// written under `dir`: `"ring"` when the batched backend resolved
    /// AND the filesystem's cached capability probe accepts it,
    /// `"sync"` otherwise. This is the string stamped into checkpoint
    /// manifests (runtime info) and printed in the CLI summary.
    pub fn submit_backend_name(&self, dir: &std::path::Path) -> &'static str {
        if self.core.ring_enabled && self.core.devices.ring_capability_for(dir).is_supported() {
            "ring"
        } else {
            "sync"
        }
    }

    /// Shared staging pool (counters: `allocations()`, `acquires()`).
    pub fn staging(&self) -> &BufferPool {
        &self.core.staging
    }

    /// Persistent writer threads.
    pub fn writer_threads(&self) -> usize {
        self.writers.threads()
    }

    /// Persistent restore-reader threads.
    pub fn reader_threads(&self) -> usize {
        self.readers.threads()
    }

    /// Intra-partition restore split threshold in bytes (see
    /// [`IoRuntimeConfig::read_split_bytes`]).
    pub fn read_split_bytes(&self) -> u64 {
        self.core.read_split_bytes
    }

    /// Drain submission lanes — at least one per configured device.
    pub fn drain_lanes(&self) -> usize {
        self.core.drain_lanes
    }

    /// Point-in-time per-lane drain counters (submissions, cumulative
    /// busy time, queued-job high-water mark) for every lane in the
    /// shared [`DrainPool`].
    pub fn drain_lane_stats(&self) -> Vec<LaneStats> {
        self.core.drain.lane_stats()
    }

    /// The op schedule the runtime would execute for `job` — the
    /// submission-time plan (inspection/tests; [`IoRuntime::submit`]
    /// calls this internally).
    pub fn plan_job(&self, job: &WriteJob) -> WritePlan {
        self.core.plan_for(job)
    }

    /// Allocate the single stream-assembly buffer of one restore,
    /// counted by the runtime's stream-allocation accounting.
    pub fn alloc_stream(&self, len: usize) -> Arc<StreamBuffer> {
        self.core.stream_allocs.fetch_add(1, Ordering::Relaxed);
        self.core.stream_alloc_bytes.fetch_add(len as u64, Ordering::Relaxed);
        Arc::new(StreamBuffer::zeroed(len))
    }

    /// Stream-assembly buffers handed out so far as `(count, bytes)` —
    /// the buffer-accounting counters behind the single-allocation
    /// restore guarantee.
    pub fn stream_allocations(&self) -> (u64, u64) {
        (
            self.core.stream_allocs.load(Ordering::Relaxed),
            self.core.stream_alloc_bytes.load(Ordering::Relaxed),
        )
    }

    /// Submit a write job to the persistent writer pool; returns its
    /// completion ticket immediately. The job is **planned here**, on
    /// the submitting thread (policy dispatch + extent schedule); the
    /// writer thread only executes the plan.
    pub fn submit(&self, job: WriteJob) -> Ticket {
        let plan = self.core.plan_for(&job);
        let (tx, rx) = mpsc::channel();
        let core = Arc::clone(&self.core);
        self.writers.execute(move || {
            let result = core.execute_planned(&job, plan);
            let _ = tx.send(result);
        });
        Ticket { rx }
    }

    /// Convenience: write one raw buffer through the runtime and wait.
    pub fn write_bytes(&self, path: PathBuf, data: Arc<Vec<u8>>) -> Result<WriteStats> {
        self.submit(WriteJob::bytes(data, path)).wait()
    }

    /// Submit a read job to the persistent reader pool; returns its
    /// completion ticket immediately. The job's `Arc<StreamBuffer>` is
    /// released *before* the ticket completes, so a loader that has
    /// waited on every ticket holds the last reference.
    pub fn submit_read(&self, job: ReadJob) -> ReadTicket {
        let (tx, rx) = mpsc::channel();
        let core = Arc::clone(&self.core);
        core.reads_inflight.fetch_add(1, Ordering::Relaxed);
        self.readers.execute(move || {
            let ctx = ReadCtx { devices: &core.devices, staging: &core.staging };
            let result = job.execute(&core.io, &ctx);
            drop(job); // release the stream buffer before signaling
            core.reads_inflight.fetch_sub(1, Ordering::Relaxed);
            let _ = tx.send(result);
        });
        ReadTicket { rx }
    }

    /// Read jobs submitted to the reader pool whose results have not
    /// yet been delivered — how saturated the pool is right now. The
    /// serve layer ([`crate::checkpoint::serve`]) bounds its own
    /// dispatch at [`IoRuntime::reader_threads`]; this counter makes
    /// that concurrency observable.
    pub fn reads_inflight(&self) -> u64 {
        self.core.reads_inflight.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::util::rng::Rng;

    fn runtime_with(buffers: usize, buf_size: usize) -> IoRuntime {
        IoRuntime::new(IoRuntimeConfig {
            io: IoConfig { io_buf_size: buf_size, ..IoConfig::default() }.microbench(),
            writer_threads: 2,
            drain_threads: 1,
            staging_buffers: buffers,
            devices: DeviceMap::single(),
            ..IoRuntimeConfig::default()
        })
    }

    #[test]
    fn ticket_roundtrip_bytes() {
        let dir = scratch_dir("rt-bytes").unwrap();
        let rt = runtime_with(2, 64 << 10);
        let mut data = vec![0u8; 300_000 + 13];
        Rng::new(1).fill_bytes(&mut data);
        let data = Arc::new(data);
        let stats = rt.write_bytes(dir.join("a.bin"), Arc::clone(&data)).unwrap();
        assert_eq!(stats.total_bytes, data.len() as u64);
        assert_eq!(std::fs::read(dir.join("a.bin")).unwrap(), *data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn submission_plans_before_execution() {
        // Plan construction happens at submission: the plan the runtime
        // derives for a job tiles exactly the source bytes at the
        // engine's queue depth, before any writer thread touches it.
        let rt = runtime_with(2, 8 << 10);
        let job = WriteJob::bytes(Arc::new(vec![5u8; 20_000]), PathBuf::from("/unused"));
        let plan = rt.plan_job(&job);
        plan.validate(rt.io_config().align as u64).unwrap();
        assert_eq!(plan.planned_bytes(), 20_000);
        assert!(plan.queue_depth >= 2, "default kind is direct-double");
        let buffered = rt.plan_job(&job.with_kind(EngineKind::Buffered));
        assert!(buffered.streamed);
    }

    #[test]
    fn drain_lanes_cover_every_device() {
        let base = scratch_dir("rt-lanes").unwrap();
        let devices = DeviceMap::simulated(4, &base.join("ssds")).unwrap();
        let rt = IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::default().microbench(),
            drain_threads: 2,
            devices,
            ..IoRuntimeConfig::default()
        });
        // 4 devices > 2 drain_threads -> one lane per device
        assert_eq!(rt.drain_lanes(), 4);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn concurrent_submissions_share_one_pool_without_new_allocations() {
        let dir = scratch_dir("rt-conc").unwrap();
        let rt = runtime_with(2, 8 << 10);
        // deterministic warm-up: allocate the full pool up front
        rt.staging().prewarm();
        let baseline_allocs = rt.staging().allocations();
        assert_eq!(baseline_allocs, 2, "prewarm fills the pool to its cap");
        for round in 0..3usize {
            let tickets: Vec<Ticket> = (0..4usize)
                .map(|i| {
                    let mut data = vec![0u8; 100_000 + i * 1111];
                    Rng::new((round * 10 + i) as u64).fill_bytes(&mut data);
                    rt.submit(WriteJob::bytes(
                        Arc::new(data),
                        dir.join(format!("r{round}-f{i}.bin")),
                    ))
                })
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
        }
        assert_eq!(
            rt.staging().allocations(),
            baseline_allocs,
            "steady-state submissions must not allocate staging buffers"
        );
        assert!(rt.staging().acquires() > 0, "direct path must use the shared pool");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn chunks_source_writes_prefix_and_ranges() {
        use crate::serialize::writer::SerializedCheckpoint;
        use crate::tensor::{DType, Tensor, TensorStore};
        let dir = scratch_dir("rt-chunks").unwrap();
        let rt = runtime_with(2, 8 << 10);
        let mut s = TensorStore::new();
        let mut data = vec![0u8; 50_000];
        Rng::new(9).fill_bytes(&mut data);
        s.push(Tensor::new("w", DType::U8, vec![50_000], data).unwrap()).unwrap();
        let ser = Arc::new(SerializedCheckpoint::new(&s, Default::default()));
        let full = ser.to_bytes();
        let total = ser.total_len();
        let prefix = vec![7u8; 64];
        let ranges = vec![(0u64, 1000u64), (30_000, 35_000), (total - 11, total)];
        let stats = rt
            .submit(WriteJob::chunks(
                Arc::clone(&ser),
                prefix.clone(),
                ranges.clone(),
                dir.join("seg.bin"),
            ))
            .wait()
            .unwrap();
        let mut expect = prefix;
        for (s0, e0) in ranges {
            expect.extend_from_slice(&full[s0 as usize..e0 as usize]);
        }
        assert_eq!(stats.total_bytes, expect.len() as u64);
        assert_eq!(std::fs::read(dir.join("seg.bin")).unwrap(), expect);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn parts_source_interleaves_raw_ranges_and_owned_buffers() {
        use crate::serialize::writer::SerializedCheckpoint;
        use crate::tensor::{DType, Tensor, TensorStore};
        let dir = scratch_dir("rt-parts").unwrap();
        let rt = runtime_with(2, 8 << 10);
        let mut s = TensorStore::new();
        let mut data = vec![0u8; 40_000];
        Rng::new(11).fill_bytes(&mut data);
        s.push(Tensor::new("w", DType::U8, vec![40_000], data).unwrap()).unwrap();
        let ser = Arc::new(SerializedCheckpoint::new(&s, Default::default()));
        let full = ser.to_bytes();
        let total = ser.total_len();
        let prefix = vec![3u8; 32];
        let enc_a = vec![0xabu8; 777];
        let enc_b: Vec<u8> = (0..500u32).map(|i| (i % 251) as u8).collect();
        let parts = vec![
            SegPart::Raw { start: 0, end: 2000 },
            SegPart::Owned(enc_a.clone()),
            SegPart::Raw { start: 10_000, end: 12_345 },
            SegPart::Owned(Vec::new()), // gated-out encodings vanish
            SegPart::Owned(enc_b.clone()),
            SegPart::Raw { start: total - 7, end: total },
        ];
        let expect_len: u64 = prefix.len() as u64 + parts.iter().map(SegPart::len).sum::<u64>();
        let job = WriteJob::parts(Arc::clone(&ser), prefix.clone(), parts, dir.join("seg.bin"));
        assert_eq!(job.source.len(), expect_len);
        let stats = rt.submit(job).wait().unwrap();
        let mut expect = prefix;
        expect.extend_from_slice(&full[..2000]);
        expect.extend_from_slice(&enc_a);
        expect.extend_from_slice(&full[10_000..12_345]);
        expect.extend_from_slice(&enc_b);
        expect.extend_from_slice(&full[total as usize - 7..]);
        assert_eq!(stats.total_bytes, expect.len() as u64);
        assert_eq!(std::fs::read(dir.join("seg.bin")).unwrap(), expect);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn kind_override_selects_engine() {
        let dir = scratch_dir("rt-kind").unwrap();
        let rt = runtime_with(2, 8 << 10);
        let data = Arc::new(vec![9u8; 50_000]);
        let stats = rt
            .submit(
                WriteJob::bytes(Arc::clone(&data), dir.join("buffered.bin"))
                    .with_kind(EngineKind::Buffered),
            )
            .wait()
            .unwrap();
        // buffered path writes everything through the traditional path
        assert_eq!(stats.suffix_bytes, stats.total_bytes);
        assert_eq!(std::fs::read(dir.join("buffered.bin")).unwrap(), *data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn failed_job_reports_through_ticket() {
        let rt = runtime_with(1, 4096);
        // unwritable destination: parent creation fails (file in the way)
        let dir = scratch_dir("rt-fail").unwrap();
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, b"x").unwrap();
        let t = rt.submit(WriteJob::bytes(
            Arc::new(vec![1u8; 10]),
            blocker.join("sub").join("f.bin"),
        ));
        assert!(t.wait().is_err());
        // the runtime survives a failed job
        assert!(rt
            .write_bytes(dir.join("ok.bin"), Arc::new(vec![2u8; 10]))
            .is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
