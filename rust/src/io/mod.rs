//! NVMe-optimized write path (paper §4.1).
//!
//! The paper's first technique replaces the traditional buffered I/O
//! stack (what `torch.save` uses) with an NVMe-aware path. Since the
//! unified pipeline, that path is **plan-based**: every engine kind is
//! a planning policy producing a [`write::WritePlan`] (an op schedule
//! of Stage/Drain/Fsync over aligned extents), and ONE executor
//! ([`write::WritePipeline`]) realizes every plan:
//!
//! * **Aligned direct writes** ([`write`]): staged extents are drained
//!   in large, alignment-respecting positioned writes from DMA-able
//!   buffers — `O_DIRECT` where the destination device's cached
//!   capability probe allows ([`device::DeviceMap::direct_capability_for`]),
//!   aligned `pwrite` otherwise, with the sub-alignment tail routed
//!   through a zeroed bounce buffer so unaligned bytes never touch the
//!   direct descriptor.
//! * **Batched kernel submission** ([`write::SubmitBackend`]): the
//!   drain lanes speak to the kernel through a pluggable submission
//!   backend — per-extent positioned writes ([`write::SyncBackend`]),
//!   or, behind the `io-uring` feature on Linux, an io_uring ring that
//!   submits a whole queue-depth batch (plus a chained flush op) in ONE
//!   syscall against buffers registered once at pool creation
//!   (`io/uring.rs`). `--io-backend auto` probes per filesystem
//!   ([`device::DeviceMap::ring_capability_for`]) and falls back to
//!   sync with a logged reason.
//! * **Pinned staging buffers** ([`buffer`]): the accelerator→DRAM hop
//!   lands in page-locked, alignment-guaranteed buffers from a reusable
//!   pool (no allocation on the hot path).
//! * **Buffering depth as policy** ([`double_buffer`]): single
//!   buffering (Fig. 5a) and double buffering (Fig. 5b) are the *same
//!   plan* at submission-queue depth 1 vs ≥ 2 — the drain of extent *k*
//!   overlaps the staging of extent *k+1*, hiding the extra hop the
//!   missing GPU↔NVMe peer-DMA forces.
//! * **Pending-byte aggregation** ([`pending_queue`]): serialized-tensor
//!   writes of arbitrary sizes are queued and flushed only at alignment
//!   boundaries, preserving on-disk byte order exactly (§4.1 "data size
//!   restrictions").
//! * **Prefix/suffix split** ([`align`]): the largest aligned prefix goes
//!   through the fast path; the sub-alignment suffix is written with
//!   traditional I/O into the same file — no padding, no format change.
//!
//! All of the above is owned by the **persistent I/O runtime**
//! ([`runtime`]): one long-lived [`runtime::IoRuntime`] holds the
//! staging pool, the drain workers, and a persistent writer pool driven
//! by a submission/completion ticket queue (`submit(WriteJob) ->
//! Ticket`, `Ticket::wait() -> WriteStats`), plus a [`device::DeviceMap`]
//! striping checkpoint partitions across the SSDs of the training
//! environment. Engines borrow from the runtime; nothing on the
//! steady-state checkpoint path allocates staging memory or spawns
//! threads.
//!
//! The **restore path** is the mirror image ([`read`]): the same
//! runtime owns a persistent reader pool (`submit_read(ReadJob) ->
//! ReadTicket`), a coalescing planner merging byte-adjacent chunk reads
//! into large positioned preads, and a single-copy
//! [`read::StreamBuffer`] that every job assembles its range into
//! directly.

pub mod align;
pub mod buffer;
pub mod device;
pub mod direct_engine;
pub mod double_buffer;
pub mod engine;
pub mod fault;
pub mod pending_queue;
pub mod read;
pub mod runtime;
pub mod sync_engine;
#[cfg(all(target_os = "linux", feature = "io-uring"))]
pub mod uring;
pub mod write;

pub use buffer::{AlignedBuf, BufferPool};
pub use device::{DeviceMap, DirectCapability, RingCapability, RingProbe};
pub use engine::{EngineKind, IoBackend, IoConfig, Sink, WriteEngine, WriteStats};
pub use fault::{FaultKind, FaultPlan, FaultSite};
pub use read::{ChunkCheck, ReadJob, ReadPart, ReadStats, StreamBuffer};
pub use runtime::{IoRuntime, IoRuntimeConfig, ReadTicket, SegPart, Ticket, WriteJob, WriteSource};
pub use write::{
    BatchEntry, BatchReport, BatchStats, DrainDone, DrainJob, DrainPool, LaneStats, SubmitBackend,
    SyncBackend, WriteExtent, WriteOp, WritePipeline, WritePlan, WriteResources,
};
