//! Read-path runtime primitives: coalesced positioned reads with
//! single-copy stream assembly (paper §4.2 parallel load, inverted).
//!
//! PRs 1–3 gave the *write* path a persistent runtime — staging pool,
//! writer tickets, device striping, one fsync per segment. This module
//! is the symmetric half for *restore*: instead of throwaway threadpools
//! issuing one unbatched `pread` per chunk and copying the stream
//! through per-part `Vec`s, a restore is planned as [`ReadJob`]s over
//! the same [`crate::io::runtime::IoRuntime`]:
//!
//! * **Single-copy assembly** ([`StreamBuffer`]): the loader allocates
//!   *one* buffer of the manifest's `total_len` and every job reads its
//!   partition/chunk range directly into its own disjoint slice. There
//!   are no per-part vectors and no concatenation pass — file bytes land
//!   at their final stream offset in one copy.
//! * **Coalesced runs** ([`plan_runs`]): chunks that are byte-adjacent
//!   both in their segment file *and* in the assembled stream merge into
//!   one large positioned read. A v4 base whose dirty chunks were packed
//!   back-to-back restores with one `pread` per contiguous run, not one
//!   per chunk. Coalescing never crosses a file (plans are per job, one
//!   job per file) and never reorders bytes: a merge requires adjacency
//!   on **both** axes, so a single `pread` lands exactly where the
//!   chunks belong.
//! * **Folded verification** ([`ChunkCheck`]): per-chunk hash checks run
//!   inside the read job, immediately after the bytes arrive (cache-hot)
//!   — verification piggybacks on the read pass the way
//!   [`crate::serialize::format::ChunkedChecksum`] piggybacks grid
//!   hashing on the write-side serialization pass.
//! * **Engine-kind awareness**: mirroring the write engines, a
//!   [`EngineKind::Buffered`] job reads in `buffered_chunk`-sized steps
//!   (the torch.load-style small-read baseline) while the direct kinds
//!   read each run in `io_buf_size`-sized steps — one large positioned
//!   read per run at the default 32 MiB buffer.
//! * **O_DIRECT reads with aligned bounce buffers**: when the device's
//!   capability probe allows it (the same cache the write pipeline
//!   consults — [`crate::io::device::DeviceMap::direct_capability_for`]),
//!   a direct-kind job opens its payload descriptor with `O_DIRECT` and
//!   reads each run's **aligned enclosure** into an aligned staging
//!   buffer borrowed from the runtime pool, copying the covered range
//!   to its destination slice. The sub-alignment head/tail of every run
//!   exists only inside that bounce buffer ([`ReadStats::bounce_bytes`]);
//!   a probed fallback (tmpfs/CI) reads straight into the destination
//!   slice as before.
//! * **Readahead hints**: every opened payload file gets
//!   `posix_fadvise(SEQUENTIAL)` + `(WILLNEED)` before its planned runs
//!   execute (Linux only; a no-op elsewhere) — planned runs are large
//!   and forward-ordered, exactly what the kernel readahead window
//!   wants to know.
//!
//! [`ReadStats`] counts bytes, payload preads, planned runs, coalesced
//! merges, and folded chunk verifications, so coalescing is testable
//! with counters (and reported by the trainer's resume metrics and
//! `benches/load_restore.rs`).
//!
//! Submission mirrors the write side: `IoRuntime::submit_read(ReadJob)
//! -> ReadTicket`, `ReadTicket::wait() -> ReadStats`, serviced by the
//! runtime's persistent reader pool.

use std::fs::File;
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::checkpoint::codec::{decode_chunk_into, CodecKind};
use crate::io::align::{align_down, align_up};
use crate::io::buffer::{AlignedBuf, BufferPool};
use crate::io::device::{DeviceMap, O_DIRECT};
use crate::io::engine::{EngineKind, IoConfig};
use crate::io::runtime::{IoRuntime, ReadTicket};
use crate::serialize::format::checksum64_slice;
use crate::{Error, Result};

/// Read-side execution context a job borrows from its runtime: the
/// device map (per-device O_DIRECT capability cache) and the staging
/// pool (aligned bounce buffers for direct reads).
pub(crate) struct ReadCtx<'a> {
    /// Device map with the cached O_DIRECT capability probes.
    pub devices: &'a DeviceMap,
    /// Staging pool direct reads borrow their bounce buffers from.
    pub staging: &'a BufferPool,
}

/// Issue `posix_fadvise(SEQUENTIAL)` + `(WILLNEED)` readahead hints for
/// `file` — planned restore runs are large forward reads, exactly what
/// the kernel readahead window wants to know. Linux-gated; a no-op
/// elsewhere, and advisory (failures are ignored) everywhere.
#[cfg(target_os = "linux")]
fn fadvise_readahead(file: &File) {
    use std::os::unix::io::AsRawFd;
    extern "C" {
        fn posix_fadvise(fd: i32, offset: i64, len: i64, advice: i32) -> i32;
    }
    const POSIX_FADV_SEQUENTIAL: i32 = 2;
    const POSIX_FADV_WILLNEED: i32 = 3;
    let fd = file.as_raw_fd();
    // SAFETY: posix_fadvise is async-signal-safe, takes no pointers,
    // and only ever *advises*; any error is ignored by contract.
    unsafe {
        let _ = posix_fadvise(fd, 0, 0, POSIX_FADV_SEQUENTIAL);
        let _ = posix_fadvise(fd, 0, 0, POSIX_FADV_WILLNEED);
    }
}

/// Readahead hints are Linux-only; elsewhere this is a no-op.
#[cfg(not(target_os = "linux"))]
fn fadvise_readahead(_file: &File) {}

/// The single preallocated assembly buffer of one restore.
///
/// Concurrent [`ReadJob`]s write disjoint ranges of it directly (no
/// intermediate vectors); after every ticket completes the loader
/// unwraps it into the assembled stream via [`StreamBuffer::into_vec`].
/// Allocate through [`IoRuntime::alloc_stream`] so the runtime's
/// stream-allocation counters account for it (the buffer-accounting
/// acceptance check of the read path).
pub struct StreamBuffer {
    /// Raw base of the heap allocation. Kept as a pointer (never as a
    /// live `Box`/`&mut`) so handing out disjoint sub-slices to
    /// concurrent reader threads never materializes a reference to the
    /// whole buffer — each `slice_mut`/`slice` derives only its own
    /// range from the raw base.
    ptr: *mut u8,
    len: usize,
}

// SAFETY: disjoint-range discipline. Every writer obtains its range via
// `slice_mut` on ranges planned from a validated manifest (partition
// and chunk tables tile `[0, total_len)` exactly, so no two jobs touch
// the same byte), which is the only way the buffer is mutated while
// shared.
unsafe impl Send for StreamBuffer {}
unsafe impl Sync for StreamBuffer {}

impl StreamBuffer {
    /// A zero-filled buffer of `len` bytes. Prefer
    /// [`IoRuntime::alloc_stream`], which counts the allocation.
    pub fn zeroed(len: usize) -> StreamBuffer {
        // `vec![0u8; len]` has capacity exactly `len`, so the allocation
        // can be reconstituted by `Vec::from_raw_parts(ptr, len, len)`.
        let slice = Box::into_raw(vec![0u8; len].into_boxed_slice());
        StreamBuffer { ptr: slice as *mut u8, len }
    }

    /// Buffer length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True for the zero-length buffer.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `[start, start + len)`.
    ///
    /// # Safety
    ///
    /// The range must be in bounds and **disjoint** from every range any
    /// other thread concurrently reads or writes through this buffer.
    #[allow(clippy::mut_from_ref)] // disjoint-slice hand-out, see module docs
    pub(crate) unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [u8] {
        debug_assert!(start.checked_add(len).is_some_and(|e| e <= self.len));
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }

    /// Shared view of `[start, start + len)`.
    ///
    /// # Safety
    ///
    /// Same disjointness contract as [`StreamBuffer::slice_mut`]: no
    /// concurrent writer may overlap the range.
    pub(crate) unsafe fn slice(&self, start: usize, len: usize) -> &[u8] {
        debug_assert!(start.checked_add(len).is_some_and(|e| e <= self.len));
        std::slice::from_raw_parts(self.ptr.add(start), len)
    }

    /// Unwrap the (now exclusively owned) buffer into the assembled
    /// stream. Errors if a reference is still alive — the loader only
    /// calls this after every read ticket has completed.
    pub fn into_vec(this: Arc<StreamBuffer>) -> Result<Vec<u8>> {
        let buf = Arc::try_unwrap(this).map_err(|_| {
            Error::Internal("stream buffer still shared after reads completed".into())
        })?;
        // SAFETY: ptr/len came from a Vec of exactly this length and
        // capacity (see `zeroed`); ownership moves into the new Vec, so
        // the buffer must not also free it on drop.
        let stream = unsafe { Vec::from_raw_parts(buf.ptr, buf.len, buf.len) };
        std::mem::forget(buf);
        Ok(stream)
    }
}

impl Drop for StreamBuffer {
    fn drop(&mut self) {
        // SAFETY: ptr/len denote the boxed slice `zeroed` leaked;
        // `into_vec` forgets the buffer before ownership could double.
        unsafe {
            drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(self.ptr, self.len)));
        }
    }
}

/// One planned file→stream copy: `len` bytes at `file_off` in the
/// source file land at `dest_off` in the stream buffer. Both the
/// planner's input parts (one per chunk) and its output runs (merged)
/// use this shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadPart {
    /// Byte offset inside the source file.
    pub file_off: u64,
    /// Destination offset in the assembled stream.
    pub dest_off: u64,
    /// Length in bytes.
    pub len: u64,
}

/// Coalesce `parts` (each typically one chunk) into contiguous runs.
///
/// Parts are sorted by file offset; consecutive parts merge **only**
/// when byte-adjacent in the file *and* in the destination stream — a
/// single positioned read of a merged run lands every byte at its final
/// offset, so merging never reorders anything. Plans are built per
/// file, so runs never span segments. With `coalesce` off the sorted
/// parts are returned unmerged (the naive one-pread-per-chunk plan,
/// kept for the `BENCH_load` comparison).
pub fn plan_runs(mut parts: Vec<ReadPart>, coalesce: bool) -> Vec<ReadPart> {
    parts.retain(|p| p.len > 0);
    parts.sort_by_key(|p| p.file_off);
    if !coalesce {
        return parts;
    }
    let mut runs: Vec<ReadPart> = Vec::with_capacity(parts.len());
    for p in parts {
        match runs.last_mut() {
            // checked arithmetic: a corrupt manifest can carry offsets
            // near u64::MAX, which must fall through to "not adjacent"
            // (and fail later bounds checks), not overflow here
            Some(last)
                if last.file_off.checked_add(last.len) == Some(p.file_off)
                    && last.dest_off.checked_add(last.len) == Some(p.dest_off) =>
            {
                last.len += p.len
            }
            _ => runs.push(p),
        }
    }
    runs
}

/// A chunk-hash verification folded into a read job: after the job's
/// runs complete, stream bytes `[dest_off, dest_off + len)` must hash
/// to `hash`.
#[derive(Debug, Clone, Copy)]
pub struct ChunkCheck {
    /// Chunk index in the manifest table (error reporting).
    pub index: usize,
    /// Destination offset of the chunk in the assembled stream.
    pub dest_off: u64,
    /// Chunk length in bytes.
    pub len: u64,
    /// Expected content hash
    /// ([`crate::serialize::format::checksum64_slice`]).
    pub hash: u64,
}

/// One codec-encoded chunk a read job decodes after its raw runs land
/// (see [`crate::checkpoint::codec`]). The encoded image lives in the
/// job's source file; the decoded (raw) bytes land at `dest_off` in the
/// stream buffer, where the chunk's folded [`ChunkCheck`] — which
/// always records the **raw** hash — verifies them exactly like an
/// unencoded chunk's.
#[derive(Debug, Clone)]
pub struct DecodeSpec {
    /// Chunk index in the manifest table (error reporting).
    pub index: usize,
    /// Byte offset of the encoded image inside the job's source file.
    pub file_off: u64,
    /// Encoded (stored) length in bytes.
    pub enc_len: u64,
    /// Destination offset of the **decoded** chunk in the stream.
    pub dest_off: u64,
    /// Raw (decoded) chunk length in bytes.
    pub raw_len: u64,
    /// The codec that produced the image.
    pub codec: CodecKind,
    /// Base-chunk extent for delta codecs (`None` for self-contained
    /// codecs like LZ4).
    pub base: Option<DecodeBase>,
}

/// Resolved on-disk location of a delta codec's base chunk: always read
/// through a plain side descriptor, even when the owning job was served
/// from a cached segment image (the base lives in a *different*
/// segment, possibly a different checkpoint directory's).
#[derive(Debug, Clone)]
pub struct DecodeBase {
    /// Fully resolved segment file holding the raw base bytes.
    pub path: PathBuf,
    /// Byte offset of the base chunk inside that file.
    pub file_off: u64,
    /// Base length in bytes (equals the chunk's raw length).
    pub len: u64,
}

/// Validation of a fixed-size file prefix (e.g. the FPSG segment
/// header) before any payload run is read.
pub struct PrefixCheck {
    /// Prefix length to read from file offset 0.
    pub len: usize,
    /// Validator over the prefix bytes.
    pub check: fn(&[u8]) -> Result<()>,
}

/// One unit of restore work for the runtime's reader pool: positioned
/// reads from one file into disjoint ranges of a shared
/// [`StreamBuffer`], plus the verification folded into the pass.
pub struct ReadJob {
    /// Source file (fully resolved — device routing already applied).
    pub path: PathBuf,
    /// The restore's shared assembly buffer.
    pub dest: Arc<StreamBuffer>,
    /// Planned contiguous runs (see [`plan_runs`]), disjoint in `dest`.
    pub runs: Vec<ReadPart>,
    /// Codec-encoded chunks to decode after the runs complete, disjoint
    /// in `dest` from the runs and from each other (the manifest table
    /// tiles the stream).
    pub decodes: Vec<DecodeSpec>,
    /// Chunk hashes to verify after the runs and decodes complete.
    pub checks: Vec<ChunkCheck>,
    /// Parts merged away by coalescing (`parts - runs`), for
    /// [`ReadStats::coalesced`].
    pub coalesced: u64,
    /// Exact file length the manifest promises (`None` skips the
    /// check — segment files hold more than one checkpoint's chunks).
    pub expect_file_len: Option<u64>,
    /// Optional container-header validation before the payload reads.
    pub prefix_check: Option<PrefixCheck>,
    /// Engine override; `None` uses the runtime's configured kind.
    pub kind: Option<EngineKind>,
    /// What the file is, for error messages (`"partition"`, `"segment"`,
    /// `"chunk"`).
    pub label: &'static str,
}

impl ReadJob {
    /// Total **raw** payload bytes this job lands in the stream buffer
    /// (decoded chunks count at their raw length).
    pub fn len(&self) -> u64 {
        self.runs.iter().map(|r| r.len).sum::<u64>()
            + self.decodes.iter().map(|d| d.raw_len).sum::<u64>()
    }

    /// True when the job has no payload runs or decodes.
    pub fn is_empty(&self) -> bool {
        self.runs.is_empty() && self.decodes.is_empty()
    }

    fn fail(&self, detail: impl std::fmt::Display) -> Error {
        Error::Format(format!("{} {}: {detail}", self.label, self.path.display()))
    }

    /// Execute on a reader thread: open (O_DIRECT when the device's
    /// probe allows and the kind is direct), hint readahead, validate,
    /// read runs into the destination slices, verify folded chunk
    /// hashes.
    pub(crate) fn execute(&self, io: &IoConfig, ctx: &ReadCtx<'_>) -> Result<ReadStats> {
        let t0 = Instant::now();
        let kind = self.kind.unwrap_or(io.kind);
        // Mirror the write engines: buffered = small traditional reads,
        // direct = one large positioned read per io_buf_size step.
        let step = match kind {
            EngineKind::Buffered => io.buffered_chunk.max(1),
            EngineKind::DirectSingle | EngineKind::DirectDouble => io.io_buf_size.max(1),
        };
        // Probe-gated O_DIRECT on the payload descriptor, mirroring the
        // write pipeline's per-device capability cache (and its
        // alignment gate: the probe validates DEFAULT_ALIGN-sized I/O,
        // so only alignments that are a multiple of it are proven).
        let mut direct_file = None;
        if io.try_o_direct
            && kind != EngineKind::Buffered
            && O_DIRECT != 0
            && ctx.staging.align() % crate::io::align::DEFAULT_ALIGN == 0
            && ctx.devices.direct_capability_for(&self.path).is_supported()
        {
            use std::os::unix::fs::OpenOptionsExt;
            direct_file = std::fs::OpenOptions::new()
                .read(true)
                .custom_flags(O_DIRECT)
                .open(&self.path)
                .ok();
        }
        let o_direct = direct_file.is_some();
        let file = match direct_file {
            Some(f) => f,
            None => File::open(&self.path).map_err(|e| self.fail(e))?,
        };
        fadvise_readahead(&file);
        if let Some(expect) = self.expect_file_len {
            let len = file.metadata().map_err(|e| self.fail(e))?.len();
            if len != expect {
                return Err(self.fail(format_args!(
                    "is {len} bytes, manifest says {expect}"
                )));
            }
        }
        let mut stats = ReadStats {
            jobs: 1,
            runs: self.runs.len() as u64,
            coalesced: self.coalesced,
            ..ReadStats::default()
        };
        if let Some(pc) = &self.prefix_check {
            // Container-header validation is a tiny read that doesn't
            // want DMA alignment: under O_DIRECT it goes through a
            // second traditional descriptor.
            let mut buf = vec![0u8; pc.len];
            if o_direct {
                let side = File::open(&self.path).map_err(|e| self.fail(e))?;
                side.read_exact_at(&mut buf, 0).map_err(|e| self.fail(e))?;
            } else {
                file.read_exact_at(&mut buf, 0).map_err(|e| self.fail(e))?;
            }
            stats.prefix_reads += 1;
            (pc.check)(&buf).map_err(|e| self.fail(e))?;
        }
        // Bounds validation for every run, shared by both payload
        // paths: corrupt manifests can carry offsets near u64::MAX,
        // which must be rejected before any arithmetic below can wrap.
        for run in &self.runs {
            run.dest_off
                .checked_add(run.len)
                .filter(|&e| e <= self.dest.len() as u64)
                .ok_or_else(|| self.fail("read run past the end of the stream buffer"))?;
            run.file_off
                .checked_add(run.len)
                .ok_or_else(|| self.fail("read run file offset overflows"))?;
        }
        self.validate_decode_bounds()?;
        if o_direct {
            // Borrow an aligned bounce buffer from the shared staging
            // pool when one is free, but never block for it: a restore
            // must not stall (or be stalled by) concurrent checkpoint
            // writes on the same runtime. When the pool is busy, a
            // private pool-geometry buffer serves this job instead.
            let mut pooled = ctx.staging.try_acquire();
            let mut private: Option<AlignedBuf> = None;
            let bounce = match pooled.as_mut() {
                Some(b) => b,
                None => {
                    // modest private buffer, not pool geometry: the
                    // direct path copies out per block anyway, so a few
                    // MiB costs little throughput and a busy restore
                    // doesn't allocate+zero 32 MiB per job
                    let cap = ctx.staging.buf_size().min(4 << 20).max(ctx.staging.align());
                    private.insert(AlignedBuf::new(cap, ctx.staging.align()))
                }
            };
            let outcome = self.read_runs_direct(&file, bounce, &mut stats);
            if let Some(b) = pooled {
                ctx.staging.release(b);
            }
            outcome?;
        } else {
            self.read_runs_fallback(&file, step, &mut stats)?;
        }
        if !self.decodes.is_empty() {
            // Encoded images and base chunks are small unaligned
            // extents: like the prefix check, they go through plain
            // side descriptors, never the O_DIRECT payload fd.
            let enc_file = if o_direct {
                Some(File::open(&self.path).map_err(|e| self.fail(e))?)
            } else {
                None
            };
            self.run_decodes(
                |off, buf| {
                    enc_file
                        .as_ref()
                        .unwrap_or(&file)
                        .read_exact_at(buf, off)
                        .map_err(Error::from)
                },
                true,
                &mut stats,
            )?;
        }
        for c in &self.checks {
            // Same bounds discipline as the runs: a hand-built job (the
            // fields are public) must error, not read out of bounds.
            c.dest_off
                .checked_add(c.len)
                .filter(|&e| e <= self.dest.len() as u64)
                .ok_or_else(|| {
                    self.fail(format_args!(
                        "chunk {} check past the end of the stream buffer",
                        c.index
                    ))
                })?;
            // SAFETY: in bounds per the check above, and the chunk range
            // lies inside this job's own runs — all finished above.
            let got =
                checksum64_slice(unsafe { self.dest.slice(c.dest_off as usize, c.len as usize) });
            if got != c.hash {
                return Err(self.fail(format_args!(
                    "chunk {} hash mismatch: computed {got:#x}, manifest {:#x}",
                    c.index, c.hash
                )));
            }
            stats.chunks_verified += 1;
        }
        stats.elapsed = t0.elapsed();
        Ok(stats)
    }

    /// Execute this job against an in-memory image of the source file
    /// (the serve layer's segment cache / mmap path —
    /// [`crate::checkpoint::serve`]) instead of the filesystem. Applies
    /// the *same* validation as [`ReadJob::execute`]: expected file
    /// length, container-prefix check, run bounds, and the folded chunk
    /// hashes — a poisoned cache entry fails exactly like a corrupt
    /// file. Issues no preads; `bytes` counts the copied payload.
    pub(crate) fn serve_from(&self, src: &[u8]) -> Result<ReadStats> {
        let t0 = Instant::now();
        if let Some(expect) = self.expect_file_len {
            if src.len() as u64 != expect {
                return Err(self.fail(format_args!(
                    "is {} bytes, manifest says {expect}",
                    src.len()
                )));
            }
        }
        let mut stats = ReadStats {
            jobs: 1,
            runs: self.runs.len() as u64,
            coalesced: self.coalesced,
            ..ReadStats::default()
        };
        if let Some(pc) = &self.prefix_check {
            let prefix = src
                .get(..pc.len)
                .ok_or_else(|| self.fail("cached image shorter than the container header"))?;
            (pc.check)(prefix).map_err(|e| self.fail(e))?;
        }
        for run in &self.runs {
            run.dest_off
                .checked_add(run.len)
                .filter(|&e| e <= self.dest.len() as u64)
                .ok_or_else(|| self.fail("read run past the end of the stream buffer"))?;
            let src_end = run
                .file_off
                .checked_add(run.len)
                .filter(|&e| e <= src.len() as u64)
                .ok_or_else(|| {
                    self.fail(format_args!(
                        "read run [{}..) past the cached image ({} bytes)",
                        run.file_off,
                        src.len()
                    ))
                })?;
            // SAFETY: runs of one restore are planned disjoint (the
            // manifest tables tile the stream), in bounds per the
            // validation above.
            let dst = unsafe { self.dest.slice_mut(run.dest_off as usize, run.len as usize) };
            dst.copy_from_slice(&src[run.file_off as usize..src_end as usize]);
            stats.bytes += run.len;
        }
        if !self.decodes.is_empty() {
            self.validate_decode_bounds()?;
            self.run_decodes(
                |off, buf| {
                    let start = off as usize;
                    let end = start.checked_add(buf.len()).filter(|&e| e <= src.len());
                    match end {
                        Some(e) => {
                            buf.copy_from_slice(&src[start..e]);
                            Ok(())
                        }
                        None => Err(Error::Format(format!(
                            "encoded bytes [{off}..) past the cached image ({} bytes)",
                            src.len()
                        ))),
                    }
                },
                false,
                &mut stats,
            )?;
        }
        for c in &self.checks {
            c.dest_off
                .checked_add(c.len)
                .filter(|&e| e <= self.dest.len() as u64)
                .ok_or_else(|| {
                    self.fail(format_args!(
                        "chunk {} check past the end of the stream buffer",
                        c.index
                    ))
                })?;
            // SAFETY: in bounds per the check above, and the chunk range
            // lies inside this job's own runs — all copied above.
            let got =
                checksum64_slice(unsafe { self.dest.slice(c.dest_off as usize, c.len as usize) });
            if got != c.hash {
                return Err(self.fail(format_args!(
                    "chunk {} hash mismatch: computed {got:#x}, manifest {:#x}",
                    c.index, c.hash
                )));
            }
            stats.chunks_verified += 1;
        }
        stats.elapsed = t0.elapsed();
        Ok(stats)
    }

    /// Bounds discipline for the decode specs, mirroring the run
    /// validation: a hand-built or corrupt spec must error before any
    /// arithmetic below can wrap or any slice can go out of bounds.
    fn validate_decode_bounds(&self) -> Result<()> {
        for d in &self.decodes {
            d.dest_off
                .checked_add(d.raw_len)
                .filter(|&e| e <= self.dest.len() as u64)
                .ok_or_else(|| {
                    self.fail(format_args!(
                        "chunk {} decode past the end of the stream buffer",
                        d.index
                    ))
                })?;
            d.file_off.checked_add(d.enc_len).ok_or_else(|| {
                self.fail(format_args!("chunk {} encoded extent overflows", d.index))
            })?;
            if let Some(b) = &d.base {
                b.file_off.checked_add(b.len).ok_or_else(|| {
                    self.fail(format_args!("chunk {} base extent overflows", d.index))
                })?;
            }
        }
        Ok(())
    }

    /// Decode pass shared by disk execution and cache service: fetch
    /// each spec's encoded image via `read_enc` (positioned read from
    /// the source file, or a copy out of the cached image), fetch its
    /// base chunk — always from disk, bases live in *other* segment
    /// files — and decode into the chunk's destination slice. The
    /// folded [`ChunkCheck`]s that run afterwards verify the decoded
    /// bytes against the manifest's raw hash, so a codec bug or corrupt
    /// image fails exactly like a corrupt raw chunk.
    fn run_decodes(
        &self,
        mut read_enc: impl FnMut(u64, &mut [u8]) -> Result<()>,
        enc_is_pread: bool,
        stats: &mut ReadStats,
    ) -> Result<()> {
        let t0 = Instant::now();
        let mut bases: std::collections::BTreeMap<&PathBuf, File> =
            std::collections::BTreeMap::new();
        for d in &self.decodes {
            let mut enc = vec![0u8; d.enc_len as usize];
            read_enc(d.file_off, &mut enc).map_err(|e| {
                self.fail(format_args!(
                    "chunk {} encoded bytes [{}..): {e}",
                    d.index, d.file_off
                ))
            })?;
            if enc_is_pread {
                stats.preads += 1;
            }
            let base: Option<Vec<u8>> = match &d.base {
                Some(b) => {
                    if !bases.contains_key(&b.path) {
                        let f = File::open(&b.path).map_err(|e| {
                            self.fail(format_args!(
                                "chunk {} base {}: {e}",
                                d.index,
                                b.path.display()
                            ))
                        })?;
                        bases.insert(&b.path, f);
                    }
                    let mut buf = vec![0u8; b.len as usize];
                    bases[&b.path].read_exact_at(&mut buf, b.file_off).map_err(|e| {
                        self.fail(format_args!(
                            "chunk {} base bytes [{}..) of {}: {e}",
                            d.index,
                            b.file_off,
                            b.path.display()
                        ))
                    })?;
                    stats.preads += 1;
                    Some(buf)
                }
                None => None,
            };
            // SAFETY: in bounds per `validate_decode_bounds`, and the
            // decoded chunk's range is disjoint from every run and
            // every other decode (planned from a validated manifest
            // table that tiles the stream).
            let dst = unsafe { self.dest.slice_mut(d.dest_off as usize, d.raw_len as usize) };
            decode_chunk_into(d.codec, &enc, base.as_deref(), dst)
                .map_err(|e| self.fail(format_args!("chunk {} decode: {e}", d.index)))?;
            stats.bytes += d.raw_len;
            stats.bytes_encoded += d.enc_len;
            stats.chunks_decoded += 1;
        }
        stats.decode += t0.elapsed();
        Ok(())
    }

    /// Traditional payload path: positioned reads in `step`-sized
    /// pieces straight into the destination slices (no staging bounce —
    /// the destination *is* the final resting place).
    fn read_runs_fallback(&self, file: &File, step: usize, stats: &mut ReadStats) -> Result<()> {
        for run in &self.runs {
            let file_end = run.file_off + run.len; // pre-validated
            // SAFETY: runs of one restore are planned disjoint (the
            // manifest tables tile the stream), in bounds per the
            // validation pass in `execute`.
            let dst = unsafe { self.dest.slice_mut(run.dest_off as usize, run.len as usize) };
            let mut done = 0usize;
            while done < dst.len() {
                let n = step.min(dst.len() - done);
                file.read_exact_at(&mut dst[done..done + n], run.file_off + done as u64)
                    .map_err(|e| {
                        self.fail(format_args!(
                            "bytes [{}..{file_end}): {e}",
                            run.file_off + done as u64
                        ))
                    })?;
                stats.preads += 1;
                done += n;
            }
            stats.bytes += run.len;
        }
        Ok(())
    }

    /// O_DIRECT payload path: read each run's **aligned enclosure**
    /// into the aligned bounce buffer in pool-buffer-sized steps and
    /// copy the covered range to its destination slice. Offset, length
    /// and memory stay aligned on the direct descriptor; the
    /// sub-alignment head/tail bytes of every run exist only inside the
    /// zeroed bounce buffer ([`ReadStats::bounce_bytes`]). Short reads
    /// are tolerated at end-of-file only.
    fn read_runs_direct(
        &self,
        file: &File,
        bounce: &mut AlignedBuf,
        stats: &mut ReadStats,
    ) -> Result<()> {
        let align = bounce.align() as u64;
        let cap = align_down(bounce.capacity() as u64, align).max(align);
        for run in &self.runs {
            if run.len == 0 {
                continue;
            }
            let file_end = run.file_off + run.len; // pre-validated
            // SAFETY: runs of one restore are planned disjoint (the
            // manifest tables tile the stream), in bounds per the
            // validation pass in `execute`.
            let dst = unsafe { self.dest.slice_mut(run.dest_off as usize, run.len as usize) };
            let mut pos = align_down(run.file_off, align);
            while pos < file_end {
                let want = cap.min(align_up(file_end - pos, align)) as usize;
                let mut got = 0usize;
                while got < want {
                    let n = file
                        .read_at(&mut bounce.as_mut_slice()[got..want], pos + got as u64)
                        .map_err(|e| {
                            self.fail(format_args!("bytes [{pos}..{file_end}): {e}"))
                        })?;
                    stats.preads += 1;
                    if n == 0 {
                        break; // end of file
                    }
                    got += n;
                    if n % align as usize != 0 {
                        // An unaligned count means the file's tail (or a
                        // source that cannot honor aligned retries):
                        // retrying at `pos + got` would violate the
                        // direct-I/O alignment contract, so stop this
                        // block — the coverage check below decides
                        // whether the run was satisfied.
                        break;
                    }
                }
                let lo = run.file_off.max(pos);
                let hi = file_end.min(pos + got as u64);
                if lo >= hi || (got < want && hi < file_end) {
                    return Err(self.fail(format_args!(
                        "bytes [{pos}..{file_end}): unexpected end of file"
                    )));
                }
                dst[(lo - run.file_off) as usize..(hi - run.file_off) as usize]
                    .copy_from_slice(&bounce.as_slice()[(lo - pos) as usize..(hi - pos) as usize]);
                stats.direct_bytes += hi - lo;
                stats.bounce_bytes += got as u64 - (hi - lo);
                pos += got as u64;
            }
            stats.bytes += run.len;
        }
        Ok(())
    }
}

/// Counters from one read job, or the merged totals of a whole restore.
#[derive(Debug, Clone, Default)]
pub struct ReadStats {
    /// Payload bytes read into the stream buffer.
    pub bytes: u64,
    /// Positioned payload reads issued (one per run under the direct
    /// kinds while runs fit `io_buf_size`; `buffered_chunk`-sized steps
    /// under the buffered kind).
    pub preads: u64,
    /// Small container-header validation reads (not payload).
    pub prefix_reads: u64,
    /// Payload bytes that arrived through an **O_DIRECT** descriptor
    /// (0 when the device's probe fell back to buffered reads).
    pub direct_bytes: u64,
    /// Sub-alignment head/tail bytes read into the aligned bounce
    /// buffer and discarded (the alignment overreach of direct reads).
    pub bounce_bytes: u64,
    /// Contiguous runs after planning.
    pub runs: u64,
    /// Chunk reads merged away by the coalescing planner
    /// (`chunks - runs` summed over segment jobs).
    pub coalesced: u64,
    /// Chunk-hash verifications folded into the read pass.
    pub chunks_verified: u64,
    /// Encoded (stored) bytes of the codec-encoded chunks this restore
    /// decoded — what the chunks actually occupied on disk or in cache.
    /// Their decoded raw bytes are counted in [`ReadStats::bytes`].
    pub bytes_encoded: u64,
    /// Codec-encoded chunks decoded inside the read pass.
    pub chunks_decoded: u64,
    /// CPU time spent fetching + decoding encoded chunks (summed across
    /// merged jobs — decode cost is additive even when jobs overlap).
    pub decode: Duration,
    /// Read jobs merged into these stats.
    pub jobs: u64,
    /// Wall time (max across merged jobs — they run concurrently).
    pub elapsed: Duration,
}

impl ReadStats {
    /// Fold another job's counters into these totals.
    pub fn merge(&mut self, other: &ReadStats) {
        self.bytes += other.bytes;
        self.preads += other.preads;
        self.prefix_reads += other.prefix_reads;
        self.direct_bytes += other.direct_bytes;
        self.bounce_bytes += other.bounce_bytes;
        self.runs += other.runs;
        self.coalesced += other.coalesced;
        self.chunks_verified += other.chunks_verified;
        self.bytes_encoded += other.bytes_encoded;
        self.chunks_decoded += other.chunks_decoded;
        self.decode += other.decode;
        self.jobs += other.jobs;
        self.elapsed = self.elapsed.max(other.elapsed);
    }
}

/// Submit every job to the runtime's reader pool and wait for all of
/// them; returns the merged [`ReadStats`], or the first error after
/// **all** tickets completed (so the shared stream buffer is no longer
/// referenced by any reader thread either way).
pub fn run_jobs(runtime: &IoRuntime, jobs: Vec<ReadJob>) -> Result<ReadStats> {
    let tickets: Vec<ReadTicket> = jobs.into_iter().map(|j| runtime.submit_read(j)).collect();
    let mut stats = ReadStats::default();
    let mut first_err = None;
    for t in tickets {
        match t.wait() {
            Ok(s) => stats.merge(&s),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(stats),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::io::runtime::IoRuntimeConfig;
    use crate::util::rng::Rng;

    fn part(file_off: u64, dest_off: u64, len: u64) -> ReadPart {
        ReadPart { file_off, dest_off, len }
    }

    #[test]
    fn planner_merges_adjacent_parts_only() {
        // three chunks adjacent in file AND dest -> one run
        let runs = plan_runs(vec![part(0, 0, 10), part(10, 10, 10), part(20, 20, 5)], true);
        assert_eq!(runs, vec![part(0, 0, 25)]);
        // file gap breaks the run
        let runs = plan_runs(vec![part(0, 0, 10), part(14, 10, 10)], true);
        assert_eq!(runs.len(), 2);
        // dest gap breaks the run even if the file bytes are adjacent
        let runs = plan_runs(vec![part(0, 0, 10), part(10, 99, 10)], true);
        assert_eq!(runs.len(), 2);
        // coalesce=false only sorts
        let runs = plan_runs(vec![part(10, 10, 10), part(0, 0, 10)], false);
        assert_eq!(runs, vec![part(0, 0, 10), part(10, 10, 10)]);
        // zero-length parts vanish
        assert!(plan_runs(vec![part(3, 3, 0)], true).is_empty());
    }

    #[test]
    fn prop_planner_preserves_coverage_and_merges_only_adjacent() {
        // The coalescing planner may merge chunks only when they are
        // byte-adjacent (file and stream), and the merged runs must
        // cover exactly the input bytes in the same file->dest mapping
        // — i.e. it never reorders and never crosses a gap.
        crate::prop::forall("read planner preserves byte mapping", 128, |g| {
            // random disjoint parts along one file, identity-ish dest
            // mapping with random per-part displacement
            let n = g.usize(0, 24);
            let mut file_off = 0u64;
            let mut parts = Vec::new();
            for _ in 0..n {
                file_off += g.u64(0, 3); // occasional gaps
                let len = g.u64(1, 5000);
                let dest_off = file_off + if g.usize(0, 4) == 0 { g.u64(1, 9) << 32 } else { 0 };
                parts.push(part(file_off, dest_off, len));
                file_off += len;
            }
            let runs = plan_runs(parts.clone(), true);
            // expand both sides into (file_byte -> dest_byte) mappings
            let expand = |ps: &[ReadPart]| {
                let mut m = std::collections::BTreeMap::new();
                for p in ps {
                    for i in 0..p.len {
                        m.insert(p.file_off + i, p.dest_off + i);
                    }
                }
                m
            };
            if expand(&parts) != expand(&runs) {
                return false;
            }
            // runs must be sorted by file offset (no reordering) and
            // separated by a genuine break on at least one axis
            for w in runs.windows(2) {
                if w[0].file_off + w[0].len > w[1].file_off {
                    return false; // overlap or out of order
                }
                let file_adjacent = w[0].file_off + w[0].len == w[1].file_off;
                let dest_adjacent = w[0].dest_off + w[0].len == w[1].dest_off;
                if file_adjacent && dest_adjacent {
                    return false; // should have been merged
                }
            }
            true
        });
    }

    fn fallback_runtime() -> IoRuntime {
        // microbench() pins try_o_direct off, so pread counting is
        // deterministic whatever filesystem the scratch dir lives on
        IoRuntime::new(IoRuntimeConfig {
            io: crate::io::engine::IoConfig::default().microbench(),
            ..IoRuntimeConfig::default()
        })
    }

    #[test]
    fn job_reads_runs_into_disjoint_slices_and_verifies_hashes() {
        let dir = scratch_dir("read-job").unwrap();
        let rt = fallback_runtime();
        let mut data = vec![0u8; 100_000];
        Rng::new(3).fill_bytes(&mut data);
        std::fs::write(dir.join("f.bin"), &data).unwrap();
        let dest = rt.alloc_stream(60_000);
        assert_eq!(rt.stream_allocations(), (1, 60_000));
        // two scattered chunks, adjacent in neither axis
        let parts =
            vec![part(10_000, 0, 30_000), part(70_000, 30_000, 30_000)];
        let checks: Vec<ChunkCheck> = parts
            .iter()
            .enumerate()
            .map(|(i, p)| ChunkCheck {
                index: i,
                dest_off: p.dest_off,
                len: p.len,
                hash: checksum64_slice(
                    &data[p.file_off as usize..(p.file_off + p.len) as usize],
                ),
            })
            .collect();
        let job = ReadJob {
            path: dir.join("f.bin"),
            dest: Arc::clone(&dest),
            runs: plan_runs(parts, true),
            decodes: Vec::new(),
            checks,
            coalesced: 0,
            expect_file_len: Some(100_000),
            prefix_check: None,
            kind: None,
            label: "segment",
        };
        let stats = rt.submit_read(job).wait().unwrap();
        assert_eq!(stats.bytes, 60_000);
        assert_eq!(stats.runs, 2);
        assert_eq!(stats.preads, 2, "direct kind: one pread per run");
        assert_eq!(stats.chunks_verified, 2);
        let out = StreamBuffer::into_vec(dest).unwrap();
        assert_eq!(&out[..30_000], &data[10_000..40_000]);
        assert_eq!(&out[30_000..], &data[70_000..]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn buffered_kind_issues_small_reads() {
        let dir = scratch_dir("read-buffered").unwrap();
        let rt = IoRuntime::new(IoRuntimeConfig::default());
        let data = vec![7u8; 256 << 10];
        std::fs::write(dir.join("f.bin"), &data).unwrap();
        let dest = rt.alloc_stream(data.len());
        let job = ReadJob {
            path: dir.join("f.bin"),
            dest: Arc::clone(&dest),
            runs: vec![part(0, 0, data.len() as u64)],
            decodes: Vec::new(),
            checks: Vec::new(),
            coalesced: 0,
            expect_file_len: None,
            prefix_check: None,
            kind: Some(EngineKind::Buffered),
            label: "partition",
        };
        let stats = rt.submit_read(job).wait().unwrap();
        // 256 KiB over 64 KiB buffered chunks -> 4 small reads
        assert_eq!(stats.preads, 4);
        assert_eq!(StreamBuffer::into_vec(dest).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn job_failures_report_resolved_path() {
        let rt = IoRuntime::new(IoRuntimeConfig::default());
        let dest = rt.alloc_stream(10);
        let missing = PathBuf::from("/nonexistent/fpck-feed/part-0.fpck");
        let job = ReadJob {
            path: missing.clone(),
            dest,
            runs: vec![part(0, 0, 10)],
            decodes: Vec::new(),
            checks: Vec::new(),
            coalesced: 0,
            expect_file_len: Some(10),
            prefix_check: None,
            kind: None,
            label: "partition",
        };
        match rt.submit_read(job).wait() {
            Err(Error::Format(msg)) => {
                assert!(msg.contains("fpck-feed"), "error must carry the resolved path: {msg}")
            }
            other => panic!("expected open failure, got {other:?}"),
        }
    }

    #[test]
    fn wrong_file_length_is_rejected_before_reading() {
        let dir = scratch_dir("read-len").unwrap();
        let rt = IoRuntime::new(IoRuntimeConfig::default());
        std::fs::write(dir.join("p.bin"), vec![1u8; 100]).unwrap();
        let dest = rt.alloc_stream(200);
        let job = ReadJob {
            path: dir.join("p.bin"),
            dest,
            runs: vec![part(0, 0, 200)],
            decodes: Vec::new(),
            checks: Vec::new(),
            coalesced: 0,
            expect_file_len: Some(200),
            prefix_check: None,
            kind: None,
            label: "partition",
        };
        match rt.submit_read(job).wait() {
            Err(Error::Format(msg)) => {
                assert!(msg.contains("100 bytes, manifest says 200"), "{msg}")
            }
            other => panic!("expected length error, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn direct_read_path_assembles_identically_when_probe_allows() {
        // With try_o_direct on, the job either engages O_DIRECT
        // (aligned-enclosure reads through the bounce buffer) or falls
        // back after the probe — both must assemble bit-identical bytes
        // for a run with an unaligned head AND an unaligned tail.
        let dir = scratch_dir("read-direct").unwrap();
        let rt = IoRuntime::new(IoRuntimeConfig::default());
        let mut data = vec![0u8; 200_000];
        Rng::new(77).fill_bytes(&mut data);
        std::fs::write(dir.join("f.bin"), &data).unwrap();
        let dest = rt.alloc_stream(100_001);
        let job = ReadJob {
            path: dir.join("f.bin"),
            dest: Arc::clone(&dest),
            runs: vec![part(3, 0, 100_001)], // head off 3, tail unaligned
            decodes: Vec::new(),
            checks: Vec::new(),
            coalesced: 0,
            expect_file_len: Some(200_000),
            prefix_check: None,
            kind: None,
            label: "segment",
        };
        let stats = rt.submit_read(job).wait().unwrap();
        assert_eq!(stats.bytes, 100_001);
        if stats.direct_bytes > 0 {
            assert_eq!(
                stats.direct_bytes, 100_001,
                "every payload byte arrives through the direct fd"
            );
            assert!(stats.bounce_bytes > 0, "unaligned head/tail must pass through the bounce");
            assert!(stats.bounce_bytes < 2 * 4096, "bounce carries only alignment overreach");
        }
        let out = StreamBuffer::into_vec(dest).unwrap();
        assert_eq!(out.as_slice(), &data[3..3 + 100_001]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn serve_from_matches_disk_execution_and_fails_closed() {
        let rt = fallback_runtime();
        let mut data = vec![0u8; 50_000];
        Rng::new(11).fill_bytes(&mut data);
        let parts = vec![part(5_000, 0, 20_000), part(40_000, 20_000, 10_000)];
        let checks: Vec<ChunkCheck> = parts
            .iter()
            .enumerate()
            .map(|(i, p)| ChunkCheck {
                index: i,
                dest_off: p.dest_off,
                len: p.len,
                hash: checksum64_slice(
                    &data[p.file_off as usize..(p.file_off + p.len) as usize],
                ),
            })
            .collect();
        let dest = rt.alloc_stream(30_000);
        let job = ReadJob {
            path: PathBuf::from("/cached/seg-000000.fpseg"),
            dest: Arc::clone(&dest),
            runs: plan_runs(parts, true),
            decodes: Vec::new(),
            checks,
            coalesced: 0,
            expect_file_len: Some(50_000),
            prefix_check: None,
            kind: None,
            label: "segment",
        };
        let stats = job.serve_from(&data).unwrap();
        assert_eq!(stats.bytes, 30_000);
        assert_eq!(stats.preads, 0, "cache service issues no disk reads");
        assert_eq!(stats.chunks_verified, 2);
        drop(job);
        let out = StreamBuffer::into_vec(dest).unwrap();
        assert_eq!(&out[..20_000], &data[5_000..25_000]);
        assert_eq!(&out[20_000..], &data[40_000..]);
        // a poisoned image fails the folded hash check, not silently
        let dest = rt.alloc_stream(10);
        let job = ReadJob {
            path: PathBuf::from("/cached/seg-000000.fpseg"),
            dest,
            runs: vec![part(0, 0, 10)],
            decodes: Vec::new(),
            checks: vec![ChunkCheck {
                index: 0,
                dest_off: 0,
                len: 10,
                hash: checksum64_slice(&data[..10]),
            }],
            coalesced: 0,
            expect_file_len: None,
            prefix_check: None,
            kind: None,
            label: "segment",
        };
        let mut poisoned = data[..10].to_vec();
        poisoned[3] ^= 0x40;
        match job.serve_from(&poisoned) {
            Err(Error::Format(msg)) => assert!(msg.contains("hash mismatch"), "{msg}"),
            other => panic!("expected poisoned-cache rejection, got {other:?}"),
        }
        // a truncated image is rejected by the bounds check
        match job.serve_from(&data[..5]) {
            Err(Error::Format(msg)) => assert!(msg.contains("past the cached image"), "{msg}"),
            other => panic!("expected truncated-image rejection, got {other:?}"),
        }
    }

    #[test]
    fn direct_read_tolerates_enclosure_past_eof() {
        // A run ending exactly at an unaligned EOF: the aligned
        // enclosure extends past the end of the file, and the short
        // read must still cover the run.
        let dir = scratch_dir("read-eof").unwrap();
        let rt = IoRuntime::new(IoRuntimeConfig::default());
        let mut data = vec![0u8; 10_000]; // unaligned file length
        Rng::new(5).fill_bytes(&mut data);
        std::fs::write(dir.join("f.bin"), &data).unwrap();
        let dest = rt.alloc_stream(9_000);
        let job = ReadJob {
            path: dir.join("f.bin"),
            dest: Arc::clone(&dest),
            runs: vec![part(1_000, 0, 9_000)], // ends at EOF
            decodes: Vec::new(),
            checks: Vec::new(),
            coalesced: 0,
            expect_file_len: Some(10_000),
            prefix_check: None,
            kind: None,
            label: "segment",
        };
        let stats = rt.submit_read(job).wait().unwrap();
        assert_eq!(stats.bytes, 9_000);
        let out = StreamBuffer::into_vec(dest).unwrap();
        assert_eq!(out.as_slice(), &data[1_000..]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
