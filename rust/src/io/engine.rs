//! Write-engine abstraction: the seam between checkpoint serialization
//! (which produces an ordered byte stream of serialized tensors) and the
//! storage backend (buffered vs NVMe-optimized).
//!
//! This mirrors the paper's integration trick: `torch.save()` accepts a
//! file-like object, and FastPersist slots in as a compatible writer so
//! serialization is unchanged and only the disk writes differ (§5.1).
//!
//! Since the unified write pipeline ([`crate::io::write`]), an engine is
//! a *planning policy*: [`WriteEngine::plan`] derives the op schedule
//! ([`crate::io::write::WritePlan`]) for a stream, and
//! [`WriteEngine::create_planned`] hands it to the one shared executor.
//! No engine owns a drain loop of its own.

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::io::align::DEFAULT_ALIGN;
use crate::io::write::WritePlan;
use crate::Result;

/// Which write engine to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Traditional buffered I/O in small chunks — the `torch.save()`
    /// baseline (§3.1).
    Buffered,
    /// NVMe-optimized: aligned direct writes from a single pinned staging
    /// buffer (stage, then drain, serially — Fig. 5a).
    DirectSingle,
    /// NVMe-optimized with double buffering: drain of buffer *k* overlaps
    /// staging of buffer *k+1* (Fig. 5b).
    DirectDouble,
}

impl EngineKind {
    /// Stable CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Buffered => "buffered",
            EngineKind::DirectSingle => "direct-single",
            EngineKind::DirectDouble => "direct-double",
        }
    }

    /// Parse a CLI engine name (several aliases per kind).
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "buffered" | "baseline" | "torch" => Ok(EngineKind::Buffered),
            "direct-single" | "single" => Ok(EngineKind::DirectSingle),
            "direct-double" | "double" | "fastpersist" => Ok(EngineKind::DirectDouble),
            other => crate::config_err!("unknown engine {other:?}"),
        }
    }
}

/// Which drain-lane submission backend services staged extents.
///
/// The backend sits *under* the lane API ([`crate::io::write::DrainPool`]):
/// plans, engines, and on-disk formats are identical across backends —
/// only how lane workers hand extents to the kernel differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoBackend {
    /// One positioned `pwrite` syscall per drained extent (the classic
    /// lane worker loop). Works everywhere; the deliberate CI path on
    /// tmpfs/9p filesystems.
    Sync,
    /// io_uring-style batched submission: lane workers queue up to
    /// [`IoConfig::queue_depth`] extents into a submission ring and
    /// issue ONE submission syscall per batch, with staging-pool
    /// buffers pre-registered as fixed buffers. Requires Linux and the
    /// `io-uring` cargo feature; resolution falls back to [`Self::Sync`]
    /// with a logged reason otherwise.
    Ring,
    /// Probe the target filesystem once (cached like the O_DIRECT
    /// probe) and pick [`Self::Ring`] where supported, else
    /// [`Self::Sync`].
    Auto,
}

impl IoBackend {
    /// Stable CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            IoBackend::Sync => "sync",
            IoBackend::Ring => "ring",
            IoBackend::Auto => "auto",
        }
    }

    /// Parse a CLI backend name.
    pub fn parse(s: &str) -> Result<IoBackend> {
        match s {
            "sync" => Ok(IoBackend::Sync),
            "ring" | "uring" | "io-uring" => Ok(IoBackend::Ring),
            "auto" => Ok(IoBackend::Auto),
            other => crate::config_err!("unknown io backend {other:?} (want sync|ring|auto)"),
        }
    }
}

/// Tuning knobs for the write path.
#[derive(Debug, Clone)]
pub struct IoConfig {
    /// Which write engine services the writes.
    pub kind: EngineKind,
    /// Staging ("IO buffer") size — the paper sweeps 2–128 MB (Fig. 7).
    pub io_buf_size: usize,
    /// Direct-I/O alignment (offset/length/memory).
    pub align: usize,
    /// Baseline chunk size (torch.save-style small buffered writes —
    /// CPython's pickle framing emits ~64 KiB frames).
    pub buffered_chunk: usize,
    /// Submission-queue depth of the overlapped ([`EngineKind::DirectDouble`])
    /// plan: maximum staged extents in flight per sink. 2 is classic
    /// double buffering (Fig. 5b); higher values deepen the pipeline on
    /// devices with spare queue capacity. [`EngineKind::DirectSingle`]
    /// is depth 1 by definition and ignores this knob.
    pub queue_depth: usize,
    /// fsync/fdatasync on finish — durability is the point of the paper's
    /// no-volatile-snapshot design, so default true for ALL engines (fair
    /// comparisons).
    pub sync_on_finish: bool,
    /// Try O_DIRECT; fall back to aligned pwrite if the per-device
    /// capability probe (or an individual open) refuses.
    pub try_o_direct: bool,
    /// Drain-lane submission backend ([`IoBackend`]). `Auto` probes the
    /// target filesystem and engages the batched ring path only where
    /// the kernel supports it, so tmpfs/9p CI keeps exercising the sync
    /// path deliberately.
    pub backend: IoBackend,
    /// Deterministic fault-injection plan ([`crate::io::fault`]). `None`
    /// (the default, and the only production value) reduces every hook
    /// to a single `Option` branch on the hot path; tests install a
    /// [`crate::io::fault::FaultPlan`] to fire at chosen op boundaries.
    pub fault: Option<crate::io::fault::FaultPlan>,
}

impl Default for IoConfig {
    fn default() -> Self {
        IoConfig {
            kind: EngineKind::DirectDouble,
            io_buf_size: 32 << 20, // paper Fig. 7 best region for large ckpts
            align: DEFAULT_ALIGN,
            buffered_chunk: 64 << 10,
            queue_depth: 2,
            sync_on_finish: true,
            try_o_direct: true,
            backend: IoBackend::Auto,
            fault: None,
        }
    }
}

impl IoConfig {
    /// The torch.save-equivalent buffered configuration.
    pub fn baseline() -> IoConfig {
        IoConfig { kind: EngineKind::Buffered, ..Default::default() }
    }

    /// The default FastPersist (double-buffered direct) configuration.
    pub fn fastpersist() -> IoConfig {
        IoConfig::default()
    }

    /// Defaults with an explicit engine kind.
    pub fn with_kind(kind: EngineKind) -> IoConfig {
        IoConfig { kind, ..Default::default() }
    }

    /// Override the staging-buffer size.
    pub fn with_buf_size(mut self, size: usize) -> IoConfig {
        self.io_buf_size = size;
        self
    }

    /// Normalize alignment/buffer sizing: align ≥ 512 and a power of
    /// two (callers guarantee the latter), IO buffer a nonzero multiple
    /// of the alignment, queue depth ≥ 1. Engines and the
    /// [`crate::io::runtime::IoRuntime`] apply this once at
    /// construction so every sink sees coherent geometry.
    pub fn normalized(mut self) -> IoConfig {
        let align = self.align.max(512);
        self.align = align;
        self.io_buf_size = self.io_buf_size.max(align).next_multiple_of(align);
        self.queue_depth = self.queue_depth.max(1);
        self
    }

    /// Microbenchmark mode ("pagecache-as-NVMe"): no fsync, no O_DIRECT.
    ///
    /// The container's virtio disk sustains only ~0.4 GB/s and is the
    /// bottleneck for every path once durability is forced, hiding all
    /// software-path differences. The paper's single-writer effects live
    /// in the software path (staging copies, chunk sizes, overlap), so
    /// the Fig. 7 family measures against the page cache standing in for
    /// the fast NVMe array. ARCHITECTURE.md §1 records this substitution.
    pub fn microbench(mut self) -> IoConfig {
        self.sync_on_finish = false;
        self.try_o_direct = false;
        self
    }
}

/// Statistics from one completed checkpoint-file write.
#[derive(Debug, Clone, Default)]
pub struct WriteStats {
    /// Total payload bytes written to the file.
    pub total_bytes: u64,
    /// Bytes written through the aligned fast path.
    pub aligned_bytes: u64,
    /// Bytes written through the traditional suffix path.
    pub suffix_bytes: u64,
    /// Bytes drained through an **O_DIRECT** descriptor (0 when the
    /// per-device probe fell back to buffered). Always an alignment
    /// multiple — the bounce path carries everything else.
    pub direct_bytes: u64,
    /// Aligned extents drained through the O_DIRECT descriptor.
    pub direct_extents: u64,
    /// Sub-alignment head/tail bytes routed through a zeroed bounce
    /// buffer on the traditional descriptor instead of the direct fd.
    pub bounce_bytes: u64,
    /// High-water mark of staged extents in flight on the submission
    /// queue (1 under Fig. 5a plans, up to [`IoConfig::queue_depth`]
    /// under Fig. 5b plans; 0 for the streamed baseline).
    pub queue_depth_max: u64,
    /// Number of storage write ops issued.
    pub write_ops: u64,
    /// Number of fsync/fdatasync calls issued at finish (0 when
    /// durability is off, e.g. [`IoConfig::microbench`]). The
    /// coalescing win of segment stores shows up here: a base
    /// checkpoint costs one fsync per *segment*, not per chunk.
    pub fsyncs: u64,
    /// Ring-backend submission syscalls issued (one per queue-depth
    /// batch of drained extents). 0 on the sync backend — the proof of
    /// which submission path actually ran.
    pub batched_submissions: u64,
    /// High-water mark of submission-queue entries handed to the kernel
    /// in a single batched submission syscall (includes a chained
    /// trailing-fsync op when one was linked). 0 on the sync backend.
    pub sqes_per_submit_max: u64,
    /// Completions reaped off the ring's completion queue. 0 on the
    /// sync backend.
    pub completions_reaped: u64,
    /// Wall time from sink creation to durable finish.
    pub elapsed: Duration,
    /// Cumulative wall time drain-lane workers spent inside this sink's
    /// positioned writes (the DRAM→SSD busy time; 0 for the streamed
    /// baseline, whose writes happen inline on the submitting thread).
    pub drain_busy: Duration,
    /// Whether O_DIRECT was actually engaged.
    pub o_direct: bool,
}

impl WriteStats {
    /// Achieved throughput in decimal GB/s.
    pub fn gbps(&self) -> f64 {
        crate::util::bytes::gbps(self.total_bytes, self.elapsed.as_secs_f64())
    }
}

/// Byte-stream sink for one checkpoint file. Writes preserve order; the
/// bytes on disk are exactly the concatenation of all `write` calls.
pub trait Sink: Send {
    /// Append bytes to the checkpoint stream.
    fn write(&mut self, data: &[u8]) -> Result<()>;
    /// Flush everything, make durable (per config), return stats.
    fn finish(self: Box<Self>) -> Result<WriteStats>;
}

/// A write-planning policy over the unified executor. An engine
/// instance *borrows* its staging pool and submission queues — either
/// private engine-lifetime resources (standalone construction) or the
/// shared pools of an [`crate::io::runtime::IoRuntime`] — and is reused
/// across checkpoints; neither planning nor sink creation allocates
/// staging memory or spawns threads.
pub trait WriteEngine: Send + Sync {
    /// Which engine this is (for reporting).
    fn kind(&self) -> EngineKind;

    /// Derive this policy's op schedule for a stream of `total` bytes
    /// (`None` plans an open-ended sink). This is the *only* thing the
    /// engine kinds do differently.
    fn plan(&self, total: Option<u64>) -> WritePlan;

    /// Open a sink executing an already-constructed `plan` against
    /// `path` — the submission-time half of plan-based execution
    /// ([`crate::io::runtime::IoRuntime::submit`] plans on the
    /// submitting thread and executes on a writer thread).
    fn create_planned(
        &self,
        path: &Path,
        plan: WritePlan,
        expected_size: Option<u64>,
    ) -> Result<Box<dyn Sink>>;

    /// Open a sink writing to `path`; `expected_size` (if known) lets
    /// the engine right-size its plan and pre-allocate the file.
    fn create(&self, path: &Path, expected_size: Option<u64>) -> Result<Box<dyn Sink>> {
        self.create_planned(path, self.plan(expected_size), expected_size)
    }
}

/// Instantiate the engine described by `cfg`.
pub fn build_engine(cfg: &IoConfig) -> Box<dyn WriteEngine> {
    match cfg.kind {
        EngineKind::Buffered => Box::new(crate::io::sync_engine::BufferedEngine::new(cfg.clone())),
        EngineKind::DirectSingle | EngineKind::DirectDouble => {
            Box::new(crate::io::direct_engine::DirectEngine::new(cfg.clone()))
        }
    }
}

/// Convenience: write `data` to `path` with engine `cfg`, return stats.
/// Builds a throwaway engine — for one-off writes only; hot paths go
/// through a persistent [`crate::io::runtime::IoRuntime`].
pub fn write_file(cfg: &IoConfig, path: &Path, data: &[u8]) -> Result<WriteStats> {
    let engine = build_engine(cfg);
    let mut sink = engine.create(path, Some(data.len() as u64))?;
    sink.write(data)?;
    sink.finish()
}

/// Helper used by tests/benches: a scratch directory honoring
/// FASTPERSIST_SCRATCH (so benchmarks can target a real disk).
pub fn scratch_dir(tag: &str) -> Result<PathBuf> {
    let base = std::env::var("FASTPERSIST_SCRATCH")
        .unwrap_or_else(|_| std::env::temp_dir().display().to_string());
    let pid = std::process::id();
    let dir = Path::new(&base).join(format!("fastpersist-{tag}-{pid}"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("fastpersist").unwrap(), EngineKind::DirectDouble);
        assert_eq!(EngineKind::parse("torch").unwrap(), EngineKind::Buffered);
        assert_eq!(EngineKind::parse("single").unwrap(), EngineKind::DirectSingle);
        assert!(EngineKind::parse("x").is_err());
    }

    #[test]
    fn backend_parse_and_names() {
        assert_eq!(IoBackend::parse("sync").unwrap(), IoBackend::Sync);
        assert_eq!(IoBackend::parse("ring").unwrap(), IoBackend::Ring);
        assert_eq!(IoBackend::parse("io-uring").unwrap(), IoBackend::Ring);
        assert_eq!(IoBackend::parse("auto").unwrap(), IoBackend::Auto);
        assert!(IoBackend::parse("fancy").is_err());
        assert_eq!(IoBackend::Ring.name(), "ring");
        assert_eq!(IoConfig::default().backend, IoBackend::Auto);
    }

    #[test]
    fn stats_gbps() {
        let s = WriteStats {
            total_bytes: 2_000_000_000,
            elapsed: Duration::from_secs(1),
            ..Default::default()
        };
        assert!((s.gbps() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn config_builders() {
        assert_eq!(IoConfig::baseline().kind, EngineKind::Buffered);
        assert_eq!(IoConfig::fastpersist().kind, EngineKind::DirectDouble);
        assert_eq!(IoConfig::default().with_buf_size(123).io_buf_size, 123);
        assert_eq!(IoConfig { queue_depth: 0, ..Default::default() }.normalized().queue_depth, 1);
    }

    #[test]
    fn engines_plan_differently_but_only_plan() {
        // The collapse invariant: the three kinds differ ONLY in the
        // plan they produce — streamed vs staged, queue depth 1 vs >= 2.
        let total = Some(1_000_000u64);
        let buffered = build_engine(&IoConfig::baseline());
        let single = build_engine(&IoConfig::with_kind(EngineKind::DirectSingle));
        let double = build_engine(&IoConfig::with_kind(EngineKind::DirectDouble));
        let pb = buffered.plan(total);
        let ps = single.plan(total);
        let pd = double.plan(total);
        assert!(pb.streamed);
        assert!(!ps.streamed && !pd.streamed);
        assert_eq!(ps.queue_depth, 1);
        assert!(pd.queue_depth >= 2);
        for p in [&pb, &ps, &pd] {
            p.validate(4096).unwrap();
            assert_eq!(p.planned_bytes(), 1_000_000);
        }
    }
}
