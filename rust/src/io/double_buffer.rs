//! Buffering-depth *policy* (paper Fig. 5): how deep the stage/drain
//! pipeline of a staged write runs.
//!
//! Before the unified pipeline, this module owned a `StagedWriter` with
//! its own drain loop. That loop now lives once, in the shared executor
//! ([`crate::io::write::WritePipeline`]); what remains here is the
//! *decision* the two NVMe engine kinds actually differ by:
//!
//! * **single buffering** (Fig. 5a): queue depth 1 — the copy into the
//!   staging buffer and its drain to storage strictly alternate;
//! * **double buffering** (Fig. 5b): queue depth ≥ 2 — the drain of
//!   extent *k* overlaps the staging of extent *k+1*, hiding the extra
//!   host hop the missing GPU↔NVMe peer-DMA forces. The exact depth is
//!   [`crate::io::engine::IoConfig::queue_depth`] (default 2; deeper
//!   pipelines suit devices with spare submission-queue capacity).
//!
//! [`plan_staged`] is the policy entry point used by
//! [`crate::io::direct_engine::DirectEngine`]: identical aligned
//! extents, different queue depth — nothing else.

use crate::io::engine::{EngineKind, IoConfig};
use crate::io::write::WritePlan;

/// The stage/drain overlap depth of `kind`: 1 for Fig. 5a
/// (single-buffer serial), `queue_depth.max(2)` for Fig. 5b
/// (double/deep buffering). The buffered baseline streams and has no
/// submission queue, so it reports 1 as well.
pub fn overlap_depth(kind: EngineKind, queue_depth: usize) -> usize {
    match kind {
        EngineKind::DirectDouble => queue_depth.max(2),
        EngineKind::DirectSingle | EngineKind::Buffered => 1,
    }
}

/// Plan a staged write for `cfg` (one of the direct kinds): chunk-sized
/// aligned extents at the kind's overlap depth. This is the **entire**
/// difference between the single- and double-buffered engines.
pub fn plan_staged(cfg: &IoConfig, total: Option<u64>) -> WritePlan {
    WritePlan::staged(cfg, total, overlap_depth(cfg.kind, cfg.queue_depth))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::write::WriteOp;

    fn cfg(kind: EngineKind, queue_depth: usize) -> IoConfig {
        IoConfig { kind, queue_depth, io_buf_size: 1 << 20, ..IoConfig::default() }.normalized()
    }

    #[test]
    fn depths_match_fig5() {
        assert_eq!(overlap_depth(EngineKind::DirectSingle, 2), 1);
        assert_eq!(overlap_depth(EngineKind::DirectSingle, 8), 1, "single is serial by definition");
        assert_eq!(overlap_depth(EngineKind::DirectDouble, 2), 2);
        assert_eq!(overlap_depth(EngineKind::DirectDouble, 4), 4, "queue depth is configurable");
        assert_eq!(overlap_depth(EngineKind::DirectDouble, 1), 2, "double means at least 2");
    }

    #[test]
    fn plans_differ_only_in_depth() {
        let total = Some(10u64 << 20);
        let ps = plan_staged(&cfg(EngineKind::DirectSingle, 2), total);
        let pd = plan_staged(&cfg(EngineKind::DirectDouble, 2), total);
        assert_eq!(ps.extents, pd.extents, "identical extents");
        assert_eq!(ps.ops(), pd.ops(), "identical op schedule");
        assert_eq!(ps.chunk, pd.chunk);
        assert_eq!(ps.queue_depth, 1);
        assert_eq!(pd.queue_depth, 2);
    }

    #[test]
    fn schedule_interleaves_stage_and_drain_per_extent() {
        let plan = plan_staged(&cfg(EngineKind::DirectDouble, 2), Some(3 << 20));
        assert_eq!(plan.extents.len(), 3);
        let ops = plan.ops();
        assert_eq!(
            ops[..6],
            [
                WriteOp::Stage(0),
                WriteOp::Drain(0),
                WriteOp::Stage(1),
                WriteOp::Drain(1),
                WriteOp::Stage(2),
                WriteOp::Drain(2),
            ]
        );
        assert_eq!(*ops.last().unwrap(), WriteOp::Fsync, "durable plan ends with fsync");
    }
}
