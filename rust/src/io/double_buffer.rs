//! Staged writer with single/double buffering (paper Fig. 5).
//!
//! The checkpoint byte stream is staged into aligned pinned buffers (the
//! accelerator→DRAM hop) and drained to storage by a dedicated drain
//! worker (the DRAM→NVMe hop). With a 1-buffer pool the two hops
//! serialize (Fig. 5a, "single buffer mode"); with a 2-buffer pool the
//! drain of buffer *k* overlaps the staging of buffer *k+1* (Fig. 5b,
//! "double buffer mode") — the pool's blocking `acquire` provides the
//! backpressure.

use std::fs::File;
use std::os::unix::fs::FileExt;
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::io::buffer::{AlignedBuf, BufferPool};
use crate::{Error, Result};

/// A full (or final) staged buffer queued for drain at a file offset.
struct Job {
    buf: AlignedBuf,
    offset: u64,
    len: usize,
}

/// Counters from the drain worker.
#[derive(Debug, Default, Clone, Copy)]
pub struct DrainStats {
    pub bytes: u64,
    pub ops: u64,
}

/// Order-preserving staged writer over a file handle.
pub struct StagedWriter {
    pool: BufferPool,
    current: Option<AlignedBuf>,
    /// Next *file* offset at which the current buffer will land.
    submit_offset: u64,
    /// Total bytes staged so far (logical stream position).
    staged: u64,
    tx: Option<Sender<Job>>,
    drain: Option<JoinHandle<DrainStats>>,
    err: Arc<Mutex<Option<Error>>>,
}

impl StagedWriter {
    /// `buffers` = 1 → single-buffer mode; 2 → double-buffer mode.
    /// `file` is the (possibly O_DIRECT) handle the drain worker writes.
    pub fn new(file: File, buffers: usize, buf_size: usize, align: usize) -> StagedWriter {
        assert!(buffers >= 1);
        assert!(buf_size % align == 0, "buf_size must be align-multiple");
        let pool = BufferPool::with_align(buffers, buf_size, align);
        let (tx, rx) = mpsc::channel::<Job>();
        let err = Arc::new(Mutex::new(None::<Error>));
        let drain_err = Arc::clone(&err);
        let drain_pool = pool.clone();
        let drain = std::thread::Builder::new()
            .name("ckpt-drain".into())
            .spawn(move || {
                let mut stats = DrainStats::default();
                for job in rx {
                    // Skip writes after the first error, but keep
                    // recycling buffers so the producer can't deadlock.
                    if drain_err.lock().unwrap().is_none() {
                        match file.write_all_at(&job.buf.filled()[..job.len], job.offset) {
                            Ok(()) => {
                                stats.bytes += job.len as u64;
                                stats.ops += 1;
                            }
                            Err(e) => {
                                *drain_err.lock().unwrap() = Some(Error::Io(e));
                            }
                        }
                    }
                    drain_pool.release(job.buf);
                }
                stats
            })
            .expect("spawn drain worker");
        StagedWriter {
            pool,
            current: None,
            submit_offset: 0,
            staged: 0,
            tx: Some(tx),
            drain: Some(drain),
            err,
        }
    }

    fn check_err(&self) -> Result<()> {
        if let Some(e) = self.err.lock().unwrap().take() {
            return Err(e);
        }
        Ok(())
    }

    /// Stage bytes; full buffers are submitted to the drain worker.
    pub fn stage(&mut self, mut data: &[u8]) -> Result<()> {
        while !data.is_empty() {
            self.check_err()?;
            if self.current.is_none() {
                // Blocks when all buffers are in flight → backpressure.
                self.current = Some(self.pool.acquire());
            }
            let buf = self.current.as_mut().unwrap();
            let n = buf.stage(data);
            self.staged += n as u64;
            data = &data[n..];
            if buf.remaining() == 0 {
                self.submit_full()?;
            }
        }
        Ok(())
    }

    fn submit_full(&mut self) -> Result<()> {
        let buf = self.current.take().expect("submit without buffer");
        let len = buf.len;
        let offset = self.submit_offset;
        self.submit_offset += len as u64;
        self.tx
            .as_ref()
            .expect("writer closed")
            .send(Job { buf, offset, len })
            .map_err(|_| Error::Internal("drain worker died".into()))?;
        Ok(())
    }

    /// Total bytes staged (logical stream length).
    pub fn staged_bytes(&self) -> u64 {
        self.staged
    }

    /// Finish: submit the *aligned* prefix of the final partial buffer
    /// through the drain worker, return `(suffix_bytes, suffix_offset,
    /// drain_stats)` — the caller writes the sub-alignment suffix through
    /// the traditional path (paper §4.1).
    pub fn finish(mut self) -> Result<(Vec<u8>, u64, DrainStats)> {
        let align = match &self.current {
            Some(b) => b.align(),
            None => crate::io::align::DEFAULT_ALIGN,
        };
        let mut suffix = Vec::new();
        if let Some(buf) = self.current.take() {
            let filled = buf.len;
            let aligned = crate::io::align::align_down(filled as u64, align as u64) as usize;
            suffix.extend_from_slice(&buf.filled()[aligned..]);
            if aligned > 0 {
                let offset = self.submit_offset;
                self.submit_offset += aligned as u64;
                self.tx
                    .as_ref()
                    .unwrap()
                    .send(Job { buf, offset, len: aligned })
                    .map_err(|_| Error::Internal("drain worker died".into()))?;
            } else {
                self.pool.release(buf);
            }
        }
        let suffix_offset = self.submit_offset;
        drop(self.tx.take()); // close queue → drain exits after last job
        let stats = self
            .drain
            .take()
            .unwrap()
            .join()
            .map_err(|_| Error::Internal("drain worker panicked".into()))?;
        self.check_err()?;
        Ok((suffix, suffix_offset, stats))
    }
}

impl Drop for StagedWriter {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.drain.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::util::rng::Rng;

    fn run_staged(buffers: usize, buf_size: usize, pieces: &[Vec<u8>]) -> Vec<u8> {
        let dir = scratch_dir(&format!("staged-{buffers}-{buf_size}")).unwrap();
        let path = dir.join("out.bin");
        let file = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        let mut w = StagedWriter::new(file.try_clone().unwrap(), buffers, buf_size, 512);
        for p in pieces {
            w.stage(p).unwrap();
        }
        let total: usize = pieces.iter().map(|p| p.len()).sum();
        assert_eq!(w.staged_bytes(), total as u64);
        let (suffix, suffix_off, _stats) = w.finish().unwrap();
        // caller-side suffix write
        file.write_all_at(&suffix, suffix_off).unwrap();
        file.set_len(total as u64).unwrap();
        let out = std::fs::read(&path).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        out
    }

    #[test]
    fn single_and_double_roundtrip() {
        let mut rng = Rng::new(3);
        let mut pieces = Vec::new();
        for _ in 0..20 {
            let len = rng.range_usize(1, 3000);
            let mut p = vec![0u8; len];
            rng.fill_bytes(&mut p);
            pieces.push(p);
        }
        let expect: Vec<u8> = pieces.concat();
        for buffers in [1, 2] {
            let got = run_staged(buffers, 1024, &pieces);
            assert_eq!(got, expect, "buffers={buffers}");
        }
    }

    #[test]
    fn exact_buffer_multiples() {
        let data = vec![7u8; 4096];
        let got = run_staged(2, 1024, &[data.clone()]);
        assert_eq!(got, data);
    }

    #[test]
    fn tiny_stream_all_suffix() {
        let data = vec![1u8, 2, 3];
        let got = run_staged(2, 1024, &[data.clone()]);
        assert_eq!(got, data);
    }

    #[test]
    fn empty_stream() {
        let got = run_staged(1, 512, &[]);
        assert!(got.is_empty());
    }

    #[test]
    fn prop_order_preserved_any_chunking() {
        crate::prop::forall("staged writer preserves order", 24, |g| {
            let total = g.usize(0, 6000);
            let mut data = vec![0u8; total];
            Rng::new(g.u64(0, u64::MAX)).fill_bytes(&mut data);
            // random chunking
            let mut pieces = Vec::new();
            let mut pos = 0;
            while pos < total {
                let n = g.usize(1, (total - pos).min(1500));
                pieces.push(data[pos..pos + n].to_vec());
                pos += n;
            }
            let buffers = g.usize(1, 2);
            let got = run_staged(buffers, 512, &pieces);
            got == data
        });
    }
}
