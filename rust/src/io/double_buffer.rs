//! Staged writer with single/double buffering (paper Fig. 5), built on
//! *shared* runtime resources.
//!
//! The checkpoint byte stream is staged into aligned pinned buffers (the
//! accelerator→DRAM hop) borrowed from a [`BufferPool`], and drained to
//! storage by a persistent [`DrainPool`] (the DRAM→SSD hop). With a
//! per-sink in-flight cap of 1 the two hops serialize (Fig. 5a, "single
//! buffer mode"); with a cap of 2 the drain of buffer *k* overlaps the
//! staging of buffer *k+1* (Fig. 5b, "double buffer mode").
//!
//! Neither the buffers nor the drain threads are created per checkpoint:
//! the [`crate::io::runtime::IoRuntime`] (or a standalone engine) owns
//! both for its whole lifetime, and sinks only *borrow*. Drain writes
//! are positioned (`pwrite`-style), so any number of sinks can share one
//! drain pool without ordering coordination.

use std::fs::File;
use std::os::unix::fs::FileExt;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;

use crate::io::buffer::{AlignedBuf, BufferPool};
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// Counters from the drain path.
#[derive(Debug, Default, Clone, Copy)]
pub struct DrainStats {
    /// Bytes drained to storage.
    pub bytes: u64,
    /// Positioned write ops issued.
    pub ops: u64,
}

/// Persistent pool of drain workers shared by every staged sink.
///
/// A drain job is one positioned write of a staged buffer; the worker
/// writes, returns the buffer to its staging pool, and reports the
/// outcome on the submitting sink's completion channel. Workers never
/// block on anything but the write syscall itself, so sinks waiting on
/// completions (or on `BufferPool::acquire`) always make progress.
#[derive(Clone)]
pub struct DrainPool {
    pool: Arc<ThreadPool>,
}

impl DrainPool {
    /// A pool of `threads` persistent drain workers.
    pub fn new(threads: usize) -> DrainPool {
        DrainPool { pool: Arc::new(ThreadPool::new(threads.max(1), "ckpt-drain")) }
    }

    /// Number of drain workers.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Submit one positioned write of `buf[..len]` at `offset`. The
    /// buffer is returned to `staging` and the result (bytes written)
    /// is sent on `done` regardless of success.
    pub fn submit(
        &self,
        file: Arc<File>,
        buf: AlignedBuf,
        offset: u64,
        len: usize,
        staging: BufferPool,
        done: Sender<Result<u64>>,
    ) {
        self.pool.execute(move || {
            let result = file
                .write_all_at(&buf.filled()[..len], offset)
                .map(|()| len as u64)
                .map_err(Error::Io);
            // Recycle before reporting so producers blocked in acquire()
            // wake even if the sink has stopped listening.
            staging.release(buf);
            let _ = done.send(result);
        });
    }
}

/// Order-preserving staged writer over a file handle; buffers come from
/// a shared pool, drains go through a shared drain pool.
pub struct StagedWriter {
    file: Arc<File>,
    pool: BufferPool,
    drain: DrainPool,
    current: Option<AlignedBuf>,
    /// Per-sink cap on submitted-but-unfinished drains: 1 = single
    /// buffering, 2 = double buffering.
    max_inflight: usize,
    /// Bytes staged per buffer before submission (≤ pool buffer
    /// capacity; right-sized to the expected stream so small checkpoints
    /// drain promptly).
    chunk: usize,
    /// Next *file* offset at which the current buffer will land.
    submit_offset: u64,
    /// Total bytes staged so far (logical stream position).
    staged: u64,
    inflight: usize,
    done_tx: Sender<Result<u64>>,
    done_rx: Receiver<Result<u64>>,
    stats: DrainStats,
    err: Option<Error>,
}

impl StagedWriter {
    /// `max_inflight` = 1 → single-buffer mode; 2 → double-buffer mode.
    /// `chunk` is clamped to `[align, pool.buf_size()]` and must be an
    /// alignment multiple. `file` is the (possibly O_DIRECT) handle the
    /// drain workers write.
    pub fn new(
        file: Arc<File>,
        pool: BufferPool,
        drain: DrainPool,
        max_inflight: usize,
        chunk: usize,
    ) -> StagedWriter {
        assert!(max_inflight >= 1);
        let chunk = chunk.clamp(pool.align(), pool.buf_size());
        assert!(chunk % pool.align() == 0, "chunk must be an alignment multiple");
        let (done_tx, done_rx) = mpsc::channel();
        StagedWriter {
            file,
            pool,
            drain,
            current: None,
            max_inflight,
            chunk,
            submit_offset: 0,
            staged: 0,
            inflight: 0,
            done_tx,
            done_rx,
            stats: DrainStats::default(),
            err: None,
        }
    }

    /// Stage bytes; full chunks are submitted to the drain pool.
    pub fn stage(&mut self, mut data: &[u8]) -> Result<()> {
        while !data.is_empty() {
            self.check_err()?;
            if self.current.is_none() {
                // Backpressure, two layers: the per-sink in-flight cap
                // (single vs double buffering), then the global pool.
                while self.inflight >= self.max_inflight {
                    self.collect_one();
                }
                self.check_err()?;
                self.current = Some(self.pool.acquire());
            }
            let buf = self.current.as_mut().unwrap();
            let room = self.chunk - buf.len;
            let n = room.min(data.len());
            buf.stage(&data[..n]);
            self.staged += n as u64;
            data = &data[n..];
            if buf.len == self.chunk {
                self.submit_full();
            }
        }
        Ok(())
    }

    fn submit_full(&mut self) {
        let buf = self.current.take().expect("submit without buffer");
        let len = buf.len;
        self.submit_buf(buf, len);
    }

    fn submit_buf(&mut self, buf: AlignedBuf, len: usize) {
        let offset = self.submit_offset;
        self.submit_offset += len as u64;
        self.inflight += 1;
        self.drain.submit(
            Arc::clone(&self.file),
            buf,
            offset,
            len,
            self.pool.clone(),
            self.done_tx.clone(),
        );
    }

    /// Receive one drain completion, folding it into stats/err.
    fn collect_one(&mut self) {
        match self.done_rx.recv() {
            Ok(Ok(bytes)) => {
                self.stats.bytes += bytes;
                self.stats.ops += 1;
                self.inflight -= 1;
            }
            Ok(Err(e)) => {
                if self.err.is_none() {
                    self.err = Some(e);
                }
                self.inflight -= 1;
            }
            Err(_) => {
                if self.err.is_none() {
                    self.err = Some(Error::Internal("drain pool died".into()));
                }
                self.inflight = 0;
            }
        }
    }

    fn check_err(&mut self) -> Result<()> {
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        Ok(())
    }

    /// Total bytes staged (logical stream length).
    pub fn staged_bytes(&self) -> u64 {
        self.staged
    }

    /// Finish: submit the *aligned* prefix of the final partial buffer
    /// through the drain pool, wait for all in-flight drains, return
    /// `(suffix_bytes, suffix_offset, drain_stats)` — the caller writes
    /// the sub-alignment suffix through the traditional path (§4.1).
    pub fn finish(mut self) -> Result<(Vec<u8>, u64, DrainStats)> {
        let align = self.pool.align();
        let mut suffix = Vec::new();
        if let Some(buf) = self.current.take() {
            let filled = buf.len;
            let aligned = crate::io::align::align_down(filled as u64, align as u64) as usize;
            suffix.extend_from_slice(&buf.filled()[aligned..]);
            if aligned > 0 {
                self.submit_buf(buf, aligned);
            } else {
                self.pool.release(buf);
            }
        }
        let suffix_offset = self.submit_offset;
        while self.inflight > 0 {
            self.collect_one();
        }
        self.check_err()?;
        Ok((suffix, suffix_offset, self.stats))
    }
}

impl Drop for StagedWriter {
    fn drop(&mut self) {
        // A sink dropped without finish() must not strand its staging
        // buffer; in-flight buffers are recycled by the drain workers
        // unconditionally.
        if let Some(buf) = self.current.take() {
            self.pool.release(buf);
        }
        // Wait out any in-flight drains (the pre-runtime code joined its
        // drain thread here, and that join was load-bearing): a caller
        // that drops a failed sink and immediately re-creates the same
        // path must not race stale positioned writes into the new file.
        while self.inflight > 0 {
            match self.done_rx.recv() {
                Ok(_) => self.inflight -= 1,
                Err(_) => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::util::rng::Rng;

    fn run_staged(buffers: usize, buf_size: usize, pieces: &[Vec<u8>]) -> Vec<u8> {
        let dir = scratch_dir(&format!("staged-{buffers}-{buf_size}")).unwrap();
        let path = dir.join("out.bin");
        let file = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        let file = Arc::new(file);
        let pool = BufferPool::with_align(buffers, buf_size, 512);
        let drain = DrainPool::new(1);
        let mut w = StagedWriter::new(Arc::clone(&file), pool, drain, buffers, buf_size);
        for p in pieces {
            w.stage(p).unwrap();
        }
        let total: usize = pieces.iter().map(|p| p.len()).sum();
        assert_eq!(w.staged_bytes(), total as u64);
        let (suffix, suffix_off, _stats) = w.finish().unwrap();
        // caller-side suffix write
        file.write_all_at(&suffix, suffix_off).unwrap();
        file.set_len(total as u64).unwrap();
        let out = std::fs::read(&path).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        out
    }

    #[test]
    fn single_and_double_roundtrip() {
        let mut rng = Rng::new(3);
        let mut pieces = Vec::new();
        for _ in 0..20 {
            let len = rng.range_usize(1, 3000);
            let mut p = vec![0u8; len];
            rng.fill_bytes(&mut p);
            pieces.push(p);
        }
        let expect: Vec<u8> = pieces.concat();
        for buffers in [1, 2] {
            let got = run_staged(buffers, 1024, &pieces);
            assert_eq!(got, expect, "buffers={buffers}");
        }
    }

    #[test]
    fn exact_buffer_multiples() {
        let data = vec![7u8; 4096];
        let got = run_staged(2, 1024, &[data.clone()]);
        assert_eq!(got, data);
    }

    #[test]
    fn tiny_stream_all_suffix() {
        let data = vec![1u8, 2, 3];
        let got = run_staged(2, 1024, &[data.clone()]);
        assert_eq!(got, data);
    }

    #[test]
    fn empty_stream() {
        let got = run_staged(1, 512, &[]);
        assert!(got.is_empty());
    }

    #[test]
    fn shared_pool_and_drain_serve_concurrent_sinks() {
        // Many sinks over ONE pool and ONE drain pool: the multi-writer
        // configuration the IoRuntime runs. Order within each file must
        // hold; the pool must not leak buffers.
        let dir = scratch_dir("staged-shared").unwrap();
        let pool = BufferPool::with_align(3, 2048, 512);
        let drain = DrainPool::new(2);
        std::thread::scope(|scope| {
            for i in 0..4usize {
                let pool = pool.clone();
                let drain = drain.clone();
                let path = dir.join(format!("f{i}.bin"));
                scope.spawn(move || {
                    let data = vec![i as u8 + 1; 10_000 + i * 513];
                    let file = Arc::new(
                        std::fs::OpenOptions::new()
                            .create(true)
                            .write(true)
                            .truncate(true)
                            .open(&path)
                            .unwrap(),
                    );
                    let mut w =
                        StagedWriter::new(Arc::clone(&file), pool, drain, 2, 2048);
                    for chunk in data.chunks(777) {
                        w.stage(chunk).unwrap();
                    }
                    let (suffix, off, _) = w.finish().unwrap();
                    file.write_all_at(&suffix, off).unwrap();
                    file.set_len(data.len() as u64).unwrap();
                    assert_eq!(std::fs::read(&path).unwrap(), data);
                });
            }
        });
        // every buffer returned to the pool (try_acquire can recycle or
        // finish warm-up, but never exceed the cap)
        let mut held = Vec::new();
        for _ in 0..3 {
            held.push(pool.try_acquire().expect("buffer leaked"));
        }
        assert!(pool.try_acquire().is_none(), "cap exceeded");
        assert!(pool.allocations() <= 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dropped_sink_returns_buffer() {
        let dir = scratch_dir("staged-drop").unwrap();
        let pool = BufferPool::with_align(1, 1024, 512);
        let drain = DrainPool::new(1);
        let file = Arc::new(
            std::fs::OpenOptions::new()
                .create(true)
                .write(true)
                .truncate(true)
                .open(dir.join("x.bin"))
                .unwrap(),
        );
        let mut w = StagedWriter::new(file, pool.clone(), drain, 1, 1024);
        w.stage(&[1, 2, 3]).unwrap();
        drop(w);
        assert!(pool.try_acquire().is_some(), "current buffer not recycled on drop");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_order_preserved_any_chunking() {
        crate::prop::forall("staged writer preserves order", 24, |g| {
            let total = g.usize(0, 6000);
            let mut data = vec![0u8; total];
            Rng::new(g.u64(0, u64::MAX)).fill_bytes(&mut data);
            // random chunking
            let mut pieces = Vec::new();
            let mut pos = 0;
            while pos < total {
                let n = g.usize(1, (total - pos).min(1500));
                pieces.push(data[pos..pos + n].to_vec());
                pos += n;
            }
            let buffers = g.usize(1, 2);
            let got = run_staged(buffers, 512, &pieces);
            got == data
        });
    }
}
