//! Buffered write *policy* — the `torch.save()`-class baseline (§3.1).
//!
//! Since the unified pipeline ([`crate::io::write`]), this module plans
//! and nothing else: the baseline's op schedule is **one streamed
//! extent** covering the whole file
//! ([`crate::io::write::WritePlan::streamed`]), which the shared
//! executor realizes as std `BufWriter` writes in small chunks (default
//! 64 KiB, matching the CPython buffered-writer behaviour torch.save
//! inherits) — no alignment, no staging buffers, no overlap, no
//! O_DIRECT. This is the engine the paper measures at ~3% of
//! deliverable SSD bandwidth for a single writer.

use std::path::Path;

use crate::io::engine::{EngineKind, IoConfig, Sink, WriteEngine};
use crate::io::write::{WritePipeline, WritePlan, WriteResources};
use crate::Result;

/// The buffered (torch.save-style) planning policy.
pub struct BufferedEngine {
    cfg: IoConfig,
    res: WriteResources,
}

impl BufferedEngine {
    /// A standalone buffered engine (private resources — the streamed
    /// plan never touches the staging pool, so these cost nothing).
    pub fn new(cfg: IoConfig) -> BufferedEngine {
        let res = WriteResources::standalone(&cfg, 1);
        BufferedEngine::with_resources(cfg, res)
    }

    /// A buffered engine borrowing shared runtime resources (kept so
    /// the baseline and the FastPersist engines live on one runtime).
    pub fn with_resources(cfg: IoConfig, res: WriteResources) -> BufferedEngine {
        BufferedEngine { cfg: cfg.normalized(), res }
    }
}

impl WriteEngine for BufferedEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Buffered
    }

    fn plan(&self, total: Option<u64>) -> WritePlan {
        WritePlan::streamed(&self.cfg, total)
    }

    fn create_planned(
        &self,
        path: &Path,
        plan: WritePlan,
        expected_size: Option<u64>,
    ) -> Result<Box<dyn Sink>> {
        WritePipeline::open(&self.cfg, &self.res, plan, path, expected_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrips_bytes() {
        let dir = scratch_dir("sync-rt").unwrap();
        let path = dir.join("ckpt.bin");
        let mut data = vec![0u8; 3_000_000 + 77];
        Rng::new(1).fill_bytes(&mut data);

        let engine = BufferedEngine::new(IoConfig::baseline());
        let mut sink = engine.create(&path, None).unwrap();
        // write in awkward pieces
        sink.write(&data[..1]).unwrap();
        sink.write(&data[1..2_000_000]).unwrap();
        sink.write(&data[2_000_000..]).unwrap();
        let stats = sink.finish().unwrap();

        assert_eq!(stats.total_bytes, data.len() as u64);
        assert_eq!(stats.suffix_bytes, stats.total_bytes, "all traditional path");
        assert_eq!(stats.direct_bytes, 0, "baseline never engages O_DIRECT");
        assert_eq!(std::fs::read(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncates_existing() {
        let dir = scratch_dir("sync-trunc").unwrap();
        let path = dir.join("f.bin");
        std::fs::write(&path, vec![9u8; 100]).unwrap();
        let engine = BufferedEngine::new(IoConfig::baseline());
        let mut sink = engine.create(&path, None).unwrap();
        sink.write(&[1, 2, 3]).unwrap();
        sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), vec![1, 2, 3]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_write_ok() {
        let dir = scratch_dir("sync-empty").unwrap();
        let path = dir.join("e.bin");
        let engine = BufferedEngine::new(IoConfig::baseline());
        let sink = engine.create(&path, None).unwrap();
        let stats = sink.finish().unwrap();
        assert_eq!(stats.total_bytes, 0);
        assert_eq!(std::fs::read(&path).unwrap().len(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn policy_plans_streamed_chunks() {
        let engine = BufferedEngine::new(IoConfig::baseline());
        let plan = engine.plan(Some(5 << 20));
        assert!(plan.streamed);
        assert_eq!(plan.queue_depth, 1);
        assert_eq!(plan.chunk, 64 << 10);
        assert_eq!(plan.planned_bytes(), 5 << 20);
    }
}
