//! Buffered write engine — the `torch.save()`-class baseline (§3.1).
//!
//! Writes go through a std `BufWriter` in small chunks (default 1 MiB,
//! matching the CPython buffered-writer behaviour torch.save inherits),
//! no alignment, no staging buffers, no overlap. This is the engine the
//! paper measures at ~3% of deliverable SSD bandwidth for a single
//! writer.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use crate::io::engine::{EngineKind, IoConfig, Sink, WriteEngine, WriteStats};
use crate::Result;

/// The buffered (torch.save-style) write engine.
pub struct BufferedEngine {
    cfg: IoConfig,
}

impl BufferedEngine {
    /// An engine writing through std buffered I/O per `cfg`.
    pub fn new(cfg: IoConfig) -> BufferedEngine {
        BufferedEngine { cfg }
    }
}

impl WriteEngine for BufferedEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Buffered
    }

    fn create(&self, path: &Path, _expected_size: Option<u64>) -> Result<Box<dyn Sink>> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)?;
        Ok(Box::new(BufferedSink {
            writer: BufWriter::with_capacity(self.cfg.buffered_chunk, file),
            chunk: self.cfg.buffered_chunk,
            sync: self.cfg.sync_on_finish,
            stats: WriteStats::default(),
            start: Instant::now(),
            scratch: Vec::new(),
        }))
    }
}

struct BufferedSink {
    writer: BufWriter<File>,
    chunk: usize,
    sync: bool,
    stats: WriteStats,
    start: Instant,
    /// Serialization scratch: torch.save's pickle framing copies tensor
    /// bytes into Python-level buffers before they reach the OS — the
    /// baseline pays that staging copy too (in small chunks, serially),
    /// which is precisely the inefficiency §3.1 measures.
    scratch: Vec<u8>,
}

impl Sink for BufferedSink {
    fn write(&mut self, data: &[u8]) -> Result<()> {
        // Feed the writer chunk-at-a-time through the serialization
        // scratch: mirrors the many small copying writes of torch.save
        // instead of one giant zero-copy write().
        self.scratch.resize(self.chunk, 0);
        for piece in data.chunks(self.chunk) {
            self.scratch[..piece.len()].copy_from_slice(piece);
            self.writer.write_all(&self.scratch[..piece.len()])?;
            self.stats.write_ops += 1;
        }
        self.stats.total_bytes += data.len() as u64;
        Ok(())
    }

    fn finish(mut self: Box<Self>) -> Result<WriteStats> {
        self.writer.flush()?;
        let file = self.writer.into_inner().map_err(|e| e.into_error())?;
        if self.sync {
            file.sync_data()?;
            self.stats.fsyncs = 1;
        }
        self.stats.suffix_bytes = self.stats.total_bytes; // all traditional path
        self.stats.elapsed = self.start.elapsed();
        Ok(self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrips_bytes() {
        let dir = scratch_dir("sync-rt").unwrap();
        let path = dir.join("ckpt.bin");
        let mut data = vec![0u8; 3_000_000 + 77];
        Rng::new(1).fill_bytes(&mut data);

        let engine = BufferedEngine::new(IoConfig::baseline());
        let mut sink = engine.create(&path, None).unwrap();
        // write in awkward pieces
        sink.write(&data[..1]).unwrap();
        sink.write(&data[1..2_000_000]).unwrap();
        sink.write(&data[2_000_000..]).unwrap();
        let stats = sink.finish().unwrap();

        assert_eq!(stats.total_bytes, data.len() as u64);
        assert_eq!(std::fs::read(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncates_existing() {
        let dir = scratch_dir("sync-trunc").unwrap();
        let path = dir.join("f.bin");
        std::fs::write(&path, vec![9u8; 100]).unwrap();
        let engine = BufferedEngine::new(IoConfig::baseline());
        let mut sink = engine.create(&path, None).unwrap();
        sink.write(&[1, 2, 3]).unwrap();
        sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), vec![1, 2, 3]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_write_ok() {
        let dir = scratch_dir("sync-empty").unwrap();
        let path = dir.join("e.bin");
        let engine = BufferedEngine::new(IoConfig::baseline());
        let sink = engine.create(&path, None).unwrap();
        let stats = sink.finish().unwrap();
        assert_eq!(stats.total_bytes, 0);
        assert_eq!(std::fs::read(&path).unwrap().len(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
