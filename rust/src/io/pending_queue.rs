//! Pending-byte aggregation queue (paper §4.1, "data size restrictions").
//!
//! Checkpoint creation is a sequence of writes of serialized tensors of
//! arbitrary sizes, many of which would individually fail direct-I/O
//! alignment (tensor headers are tens of bytes). FastPersist aggregates
//! them into a queue of pending bytes that is flushed whenever the
//! alignment/flush threshold is met. Bytes of one tensor may be split
//! across flushes and bytes of several tensors may share one flush, but
//! the *order* of bytes on disk is exactly the order they were appended
//! — the correctness condition the paper states.
//!
//! Used at the serializer→sink boundary to coalesce the many small
//! serializer writes into large sink calls.

use crate::Result;

/// Aggregates appended bytes and emits `flush_size`-sized blocks to a
/// callback; `drain` emits whatever remains.
pub struct PendingQueue {
    buf: Vec<u8>,
    flush_size: usize,
    /// Total bytes appended over the queue's lifetime.
    appended: u64,
    /// Total bytes flushed out.
    flushed: u64,
}

impl PendingQueue {
    /// A queue flushing whole `flush_size`-byte blocks.
    pub fn new(flush_size: usize) -> PendingQueue {
        assert!(flush_size > 0);
        PendingQueue { buf: Vec::with_capacity(flush_size), flush_size, appended: 0, flushed: 0 }
    }

    /// Append bytes; invokes `out` zero or more times with full blocks.
    pub fn append<F>(&mut self, mut data: &[u8], mut out: F) -> Result<()>
    where
        F: FnMut(&[u8]) -> Result<()>,
    {
        self.appended += data.len() as u64;
        // Fast path: queue empty and data covers whole blocks — emit
        // directly from the input without copying.
        if self.buf.is_empty() {
            while data.len() >= self.flush_size {
                let (block, rest) = data.split_at(self.flush_size);
                out(block)?;
                self.flushed += block.len() as u64;
                data = rest;
            }
        }
        while !data.is_empty() {
            let room = self.flush_size - self.buf.len();
            let n = room.min(data.len());
            self.buf.extend_from_slice(&data[..n]);
            data = &data[n..];
            if self.buf.len() == self.flush_size {
                out(&self.buf)?;
                self.flushed += self.buf.len() as u64;
                self.buf.clear();
            }
        }
        Ok(())
    }

    /// Flush any remaining pending bytes (the final, possibly unaligned,
    /// tail).
    pub fn drain<F>(&mut self, mut out: F) -> Result<()>
    where
        F: FnMut(&[u8]) -> Result<()>,
    {
        if !self.buf.is_empty() {
            out(&self.buf)?;
            self.flushed += self.buf.len() as u64;
            self.buf.clear();
        }
        Ok(())
    }

    /// Bytes currently buffered (not yet flushed).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Total bytes appended over the queue's lifetime.
    pub fn appended_bytes(&self) -> u64 {
        self.appended
    }

    /// Total bytes flushed out so far.
    pub fn flushed_bytes(&self) -> u64 {
        self.flushed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn collect(flush: usize, pieces: &[&[u8]]) -> (Vec<Vec<u8>>, Vec<u8>) {
        let mut q = PendingQueue::new(flush);
        let mut blocks: Vec<Vec<u8>> = Vec::new();
        for p in pieces {
            q.append(p, |b| {
                blocks.push(b.to_vec());
                Ok(())
            })
            .unwrap();
        }
        q.drain(|b| {
            blocks.push(b.to_vec());
            Ok(())
        })
        .unwrap();
        let joined = blocks.concat();
        (blocks, joined)
    }

    #[test]
    fn emits_full_blocks_in_order() {
        let (blocks, joined) = collect(4, &[&[1, 2], &[3, 4, 5, 6, 7], &[8]]);
        assert_eq!(joined, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(blocks[0], vec![1, 2, 3, 4]);
        assert_eq!(blocks[1], vec![5, 6, 7, 8]);
        assert_eq!(blocks.len(), 2);
    }

    #[test]
    fn tail_drains() {
        let (blocks, joined) = collect(4, &[&[1, 2, 3, 4, 5]]);
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[1], vec![5]);
        assert_eq!(joined, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_copy_fast_path_counts() {
        let mut q = PendingQueue::new(4);
        let mut count = 0;
        q.append(&[0u8; 12], |b| {
            assert_eq!(b.len(), 4);
            count += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(count, 3);
        assert_eq!(q.pending(), 0);
        assert_eq!(q.appended_bytes(), 12);
        assert_eq!(q.flushed_bytes(), 12);
    }

    #[test]
    fn error_propagates() {
        let mut q = PendingQueue::new(2);
        let r = q.append(&[1, 2, 3, 4], |_| Err(crate::Error::Internal("boom".into())));
        assert!(r.is_err());
    }

    #[test]
    fn prop_order_and_block_invariants() {
        crate::prop::forall("pending queue preserves order", 128, |g| {
            let flush = g.usize(1, 64);
            let npieces = g.usize(0, 12);
            let mut rng = Rng::new(g.u64(0, u64::MAX));
            let pieces: Vec<Vec<u8>> = (0..npieces)
                .map(|_| {
                    let mut p = vec![0u8; g.usize(0, 200)];
                    rng.fill_bytes(&mut p);
                    p
                })
                .collect();
            let refs: Vec<&[u8]> = pieces.iter().map(|p| p.as_slice()).collect();
            let (blocks, joined) = collect(flush, &refs);
            let expect: Vec<u8> = pieces.concat();
            // every block except possibly the last is exactly flush-sized
            let full_ok = blocks
                .iter()
                .rev()
                .skip(1)
                .all(|b| b.len() == flush);
            joined == expect && full_ok
        });
    }
}
