//! Multi-device partition routing — the paper's "write parallelism
//! across the SSDs available in the training environment" (§4.2).
//!
//! A [`DeviceMap`] is an ordered set of mount points (real NVMe mounts
//! in production; sibling directories standing in for per-socket SSDs in
//! this reproduction — see ARCHITECTURE.md). Checkpoint partitions are striped
//! round-robin across the devices, so a DP=8 checkpoint over a 4-device
//! map keeps all four SSDs writing concurrently instead of funneling
//! every partition through one filesystem. The delta layer's segment
//! stores ([`crate::checkpoint::delta`]) ride the same routing, keyed
//! by segment index — and size their segment count to at least the
//! device count, so even a small base keeps every SSD writing.
//!
//! Routing is a pure function of `(map, partition index)` — every rank
//! computes the same assignment without communication, preserving §4.2's
//! setup-time-only coordination. The assignment is recorded per
//! partition in the checkpoint manifest and resolved again at load.
//!
//! The empty map is the single-device degenerate case: every partition
//! lands directly in the checkpoint directory, which keeps single-disk
//! layouts byte-compatible with the pre-DeviceMap format.

use std::path::{Path, PathBuf};

use crate::serialize::format::checksum64_slice;
use crate::{Error, Result};

/// Ordered set of storage mount points for checkpoint fan-out.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DeviceMap {
    roots: Vec<PathBuf>,
}

impl DeviceMap {
    /// The single-device map: all partitions go to the checkpoint dir.
    pub fn single() -> DeviceMap {
        DeviceMap::default()
    }

    /// A map over explicit mount points (created if missing).
    pub fn from_roots(roots: Vec<PathBuf>) -> Result<DeviceMap> {
        if roots.is_empty() {
            return Err(Error::Config("DeviceMap::from_roots needs >= 1 root".into()));
        }
        for root in &roots {
            std::fs::create_dir_all(root)?;
        }
        Ok(DeviceMap { roots })
    }

    /// `n` simulated SSDs as sibling dirs `base/ssd0..ssd{n-1}` — the
    /// per-socket NVMe array of a DGX node, modeled on one filesystem.
    pub fn simulated(n: usize, base: &Path) -> Result<DeviceMap> {
        if n == 0 {
            return Err(Error::Config("DeviceMap::simulated needs >= 1 device".into()));
        }
        let roots = (0..n).map(|i| base.join(format!("ssd{i}"))).collect();
        DeviceMap::from_roots(roots)
    }

    /// Number of devices; 0 means the single-device degenerate map.
    pub fn len(&self) -> usize {
        self.roots.len()
    }

    /// True for the degenerate single-device map.
    pub fn is_empty(&self) -> bool {
        self.roots.is_empty()
    }

    /// True when partitions actually fan out over separate mounts.
    pub fn is_multi(&self) -> bool {
        self.roots.len() > 1
    }

    /// The configured mount-point roots, in striping order.
    pub fn roots(&self) -> &[PathBuf] {
        &self.roots
    }

    /// Device index owning partition `index` — round-robin striping.
    /// `None` on the degenerate map. Every partition maps onto exactly
    /// one device (tested as a property below).
    pub fn route(&self, index: usize) -> Option<usize> {
        if self.roots.is_empty() {
            None
        } else {
            Some(index % self.roots.len())
        }
    }

    /// Where partition `index` of the checkpoint in `dir` lives:
    /// `(directory, recorded device root)`. `None` routes to `dir`
    /// itself (degenerate map).
    pub fn partition_dir(&self, dir: &Path, index: usize) -> Option<(PathBuf, String)> {
        self.route(index).map(|d| {
            let root = &self.roots[d];
            (Self::resolve_in(root, dir), root.display().to_string())
        })
    }

    /// The per-checkpoint directory on device `root` for the checkpoint
    /// published at `dir`. Pure function of `(root, dir)`, so writers
    /// and loaders agree without storing absolute partition paths.
    pub fn resolve_in(root: &Path, dir: &Path) -> PathBuf {
        root.join(Self::checkpoint_tag(dir))
    }

    /// Stable tag identifying the checkpoint directory on shared device
    /// mounts (several checkpoints stripe over the same SSDs). The tag
    /// hashes the *canonicalized* directory path, so a checkpoint
    /// directory must not be moved after writing — its device-side
    /// partitions would resolve to a different tag (delete and re-write
    /// instead, or keep single-device layouts relocatable).
    pub fn checkpoint_tag(dir: &Path) -> String {
        let canon = std::fs::canonicalize(dir).unwrap_or_else(|_| dir.to_path_buf());
        let h = checksum64_slice(canon.to_string_lossy().as_bytes());
        format!("fpck-{h:016x}")
    }

    /// Garbage-collect the device-side partition directories of the
    /// checkpoint at `dir`. Call **before** removing `dir` itself (the
    /// tag needs the directory to still canonicalize). No-op on the
    /// degenerate map; missing per-device dirs are ignored.
    pub fn remove_checkpoint(&self, dir: &Path) {
        if self.roots.is_empty() {
            return;
        }
        let tag = Self::checkpoint_tag(dir);
        for root in &self.roots {
            let _ = std::fs::remove_dir_all(root.join(&tag));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;

    #[test]
    fn degenerate_map_routes_nowhere() {
        let m = DeviceMap::single();
        assert!(m.is_empty());
        assert_eq!(m.route(0), None);
        assert!(m.partition_dir(Path::new("/tmp/ck"), 3).is_none());
    }

    #[test]
    fn simulated_creates_roots() {
        let base = scratch_dir("devmap-sim").unwrap();
        let m = DeviceMap::simulated(3, &base).unwrap();
        assert_eq!(m.len(), 3);
        for root in m.roots() {
            assert!(root.is_dir());
        }
        assert!(DeviceMap::simulated(0, &base).is_err());
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn tag_is_stable_and_spelling_invariant() {
        let base = scratch_dir("devmap-tag").unwrap();
        let dir = base.join("ck");
        std::fs::create_dir_all(&dir).unwrap();
        let a = DeviceMap::checkpoint_tag(&dir);
        let b = DeviceMap::checkpoint_tag(&base.join("./ck"));
        assert_eq!(a, b, "canonicalization must absorb path spelling");
        let other = base.join("ck2");
        std::fs::create_dir_all(&other).unwrap();
        assert_ne!(a, DeviceMap::checkpoint_tag(&other));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn writer_and_loader_resolution_agree() {
        let base = scratch_dir("devmap-agree").unwrap();
        let dir = base.join("ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let m = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let (pdir, recorded) = m.partition_dir(&dir, 1).unwrap();
        // loader path: recorded root string + checkpoint dir
        let resolved = DeviceMap::resolve_in(Path::new(&recorded), &dir);
        assert_eq!(pdir, resolved);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn remove_checkpoint_gcs_device_dirs() {
        let base = scratch_dir("devmap-gc").unwrap();
        let dir = base.join("ck");
        std::fs::create_dir_all(&dir).unwrap();
        let m = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let (pdir, _) = m.partition_dir(&dir, 0).unwrap();
        std::fs::create_dir_all(&pdir).unwrap();
        std::fs::write(pdir.join("part-0000-rank00000.fpck"), b"x").unwrap();
        m.remove_checkpoint(&dir);
        assert!(!pdir.exists(), "device-side partitions must be GC'd");
        for root in m.roots() {
            assert!(root.is_dir(), "device roots themselves must survive");
        }
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn prop_routing_tiles_partitions_onto_exactly_one_device() {
        crate::prop::forall("device routing tiles partitions", 128, |g| {
            let ndev = g.usize(1, 8);
            let nparts = g.usize(1, 64);
            let roots: Vec<PathBuf> =
                (0..ndev).map(|i| PathBuf::from(format!("/virtual/dev{i}"))).collect();
            let m = DeviceMap { roots };
            let mut per_device = vec![0usize; ndev];
            for p in 0..nparts {
                // exactly one device, in bounds
                let Some(d) = m.route(p) else { return false };
                if d >= ndev {
                    return false;
                }
                if m.route(p) != Some(d) {
                    return false; // deterministic
                }
                per_device[d] += 1;
            }
            // striping is balanced: counts differ by at most one
            let min = *per_device.iter().min().unwrap();
            let max = *per_device.iter().max().unwrap();
            per_device.iter().sum::<usize>() == nparts && max - min <= 1
        });
    }
}
