//! Multi-device partition routing — the paper's "write parallelism
//! across the SSDs available in the training environment" (§4.2) —
//! plus the per-device **O_DIRECT capability probe** backing the
//! unified write pipeline's direct path.
//!
//! A [`DeviceMap`] is an ordered set of mount points (real NVMe mounts
//! in production; sibling directories standing in for per-socket SSDs in
//! this reproduction — see ARCHITECTURE.md). Checkpoint partitions are striped
//! round-robin across the devices, so a DP=8 checkpoint over a 4-device
//! map keeps all four SSDs writing concurrently instead of funneling
//! every partition through one filesystem. The delta layer's segment
//! stores ([`crate::checkpoint::delta`]) ride the same routing, keyed
//! by segment index — and size their segment count to at least the
//! device count, so even a small base keeps every SSD writing.
//!
//! Routing is a pure function of `(map, partition index)` — every rank
//! computes the same assignment without communication, preserving §4.2's
//! setup-time-only coordination. The assignment is recorded per
//! partition in the checkpoint manifest and resolved again at load.
//!
//! The empty map is the single-device degenerate case: every partition
//! lands directly in the checkpoint directory, which keeps single-disk
//! layouts byte-compatible with the pre-DeviceMap format.
//!
//! **Direct-I/O capability.** Whether `O_DIRECT` works is a property of
//! the *filesystem backing a device*, not of individual checkpoint
//! files, so the map owns a [`DirectProbe`]: the first open on a device
//! performs one real probe (O_DIRECT open + one aligned write of a
//! scratch file) and the verdict is cached for the map's lifetime —
//! clones share the cache. Filesystems that reject O_DIRECT (tmpfs,
//! some overlay/network mounts) get a **logged buffered fallback**; the
//! write pipeline and the read runtime both consult the same cache, so
//! a device is probed once, not once per file.
//!
//! **Ring-submission capability.** The batched submission backend
//! (`io/uring.rs`, behind the `io-uring` feature) gets the same
//! treatment through a [`RingProbe`]: one real probe per filesystem
//! (ring setup + one batched write with a chained flush on a scratch
//! file), verdict cached by `st_dev`, fallback to the per-extent sync
//! backend logged with its reason. Builds without the feature — and CI
//! sandboxes whose seccomp policy rejects `io_uring_setup` — report
//! `Unsupported` here, which is exactly how `--io-backend auto` keeps
//! tmpfs/9p CI on the sync path deliberately.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::serialize::format::checksum64_slice;
use crate::{Error, Result};

/// `O_DIRECT` without a libc dependency (Linux; zero elsewhere, where
/// every open falls back to the buffered descriptor anyway).
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "x86")))]
pub const O_DIRECT: i32 = 0o40000;
/// `O_DIRECT` without a libc dependency (Linux; zero elsewhere, where
/// every open falls back to the buffered descriptor anyway).
#[cfg(all(
    target_os = "linux",
    not(any(target_arch = "x86_64", target_arch = "x86"))
))]
pub const O_DIRECT: i32 = 0o200000;
/// `O_DIRECT` without a libc dependency (Linux; zero elsewhere, where
/// every open falls back to the buffered descriptor anyway).
#[cfg(not(target_os = "linux"))]
pub const O_DIRECT: i32 = 0;

/// Verdict of one O_DIRECT capability probe.
#[derive(Debug, Clone)]
pub enum DirectCapability {
    /// The filesystem accepted an O_DIRECT open and an aligned write.
    Supported,
    /// The probe failed; the reason is logged once and direct I/O for
    /// this device falls back to aligned buffered writes.
    Unsupported(String),
}

impl DirectCapability {
    /// True when the direct path may be used.
    pub fn is_supported(&self) -> bool {
        matches!(self, DirectCapability::Supported)
    }
}

/// Cached per-filesystem O_DIRECT capability probes (shared by
/// clones). The cache is keyed by the directory's `st_dev`, so every
/// directory on one device shares a single probe — a trainer writing a
/// new `step-NNNNNNNN` directory per iteration probes its checkpoint
/// filesystem exactly once, not once per step.
#[derive(Clone, Default)]
pub struct DirectProbe {
    cache: Arc<Mutex<HashMap<u64, ProbeEntry>>>,
}

/// One cached probe verdict. Definitive verdicts (success, or a
/// capability errno) are served forever; transient failures (ENOSPC,
/// EACCES, …) are served from cache too but re-probed every
/// [`TRANSIENT_RETRY_EVERY`] queries, so a momentary condition neither
/// disables the direct path forever nor causes per-job probe/log spam.
struct ProbeEntry {
    cap: DirectCapability,
    definitive: bool,
    queries: u64,
}

/// Cache-hit count after which a non-definitive (transient-failure)
/// verdict is re-probed.
const TRANSIENT_RETRY_EVERY: u64 = 64;

impl DirectProbe {
    /// Capability of the filesystem holding `dir`, probing it on the
    /// first call and serving the cached verdict afterwards. A fallback
    /// is logged with its reason (once per filesystem), so CI runs on
    /// tmpfs show *why* the buffered path engaged.
    pub fn capability(&self, dir: &Path) -> DirectCapability {
        use std::os::unix::fs::MetadataExt;
        // A capability query must never mutate the filesystem: an
        // unreachable directory reports Unsupported WITHOUT probing,
        // caching, or creating anything (the caller's open will surface
        // the real error), and without tying unrelated unreachable
        // paths to one cache entry.
        let key = match std::fs::metadata(dir) {
            Ok(m) => m.dev(),
            Err(e) => {
                return DirectCapability::Unsupported(format!(
                    "cannot stat {}: {e}",
                    dir.display()
                ))
            }
        };
        {
            let mut cache = self.cache.lock().unwrap();
            if let Some(entry) = cache.get_mut(&key) {
                entry.queries += 1;
                if entry.definitive || entry.queries % TRANSIENT_RETRY_EVERY != 0 {
                    return entry.cap.clone();
                }
                // fall through: periodically re-probe a transient failure
            }
        }
        // Probe WITHOUT holding the cache lock: a hung mount must stall
        // only the jobs routed to it, never every thread of the runtime
        // (racing first-touch probes of one device are harmless — each
        // uses a unique scratch name and the verdicts agree).
        let (cap, definitive) = probe_o_direct(dir);
        if let DirectCapability::Unsupported(reason) = &cap {
            eprintln!(
                "fastpersist: O_DIRECT unavailable for {} ({reason}); using the aligned \
                 buffered fallback",
                dir.display()
            );
        }
        self.cache
            .lock()
            .unwrap()
            .insert(key, ProbeEntry { cap: cap.clone(), definitive, queries: 0 });
        cap
    }

    /// Number of filesystems probed so far (test instrumentation: the
    /// probe-once guarantee is `probed()` staying flat across repeated
    /// opens on the same device).
    pub fn probed(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// True when an errno denotes a verdict worth caching for the map's
/// lifetime rather than a transient I/O condition: capability
/// rejections — EINVAL (22), ENOSYS (38), ENOTSUP/EOPNOTSUPP (95) —
/// plus access-class failures — EPERM (1), EACCES (13), EROFS (30) —
/// which would otherwise make every job of a read-only-mount restore
/// re-attempt (and re-log) the probe. Caching only ever disables an
/// optimization, never correctness.
fn is_capability_errno(e: &std::io::Error) -> bool {
    matches!(
        e.raw_os_error(),
        Some(1) | Some(13) | Some(22) | Some(30) | Some(38) | Some(95)
    )
}

/// One real capability probe: O_DIRECT open of a scratch file in `dir`
/// (which the caller has verified exists) plus one aligned write from
/// an aligned buffer (tmpfs rejects at open; some filesystems accept
/// the open and fail the first write). The scratch file is removed
/// whatever the outcome. Returns `(verdict, definitive)` — only
/// definitive verdicts (success, or a capability errno) may be cached.
fn probe_o_direct(dir: &Path) -> (DirectCapability, bool) {
    if O_DIRECT == 0 {
        return (
            DirectCapability::Unsupported(
                "O_DIRECT is not available on this platform".to_string(),
            ),
            true,
        );
    }
    // unique scratch name: pid + a process-wide counter, so concurrent
    // first-touch probes of one device never collide on a file
    static PROBE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = PROBE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let path = dir.join(format!(".fp-direct-probe-{}-{seq}", std::process::id()));
    let opened = {
        use std::os::unix::fs::OpenOptionsExt;
        std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .custom_flags(O_DIRECT)
            .open(&path)
    };
    let file = match opened {
        Ok(f) => f,
        Err(e) => {
            let _ = std::fs::remove_file(&path);
            let definitive = is_capability_errno(&e);
            return (
                DirectCapability::Unsupported(format!("open(O_DIRECT) failed: {e}")),
                definitive,
            );
        }
    };
    let buf = crate::io::buffer::AlignedBuf::new(
        crate::io::align::DEFAULT_ALIGN,
        crate::io::align::DEFAULT_ALIGN,
    );
    let result = {
        use std::os::unix::fs::FileExt;
        file.write_all_at(buf.as_slice(), 0)
    };
    drop(file);
    let _ = std::fs::remove_file(&path);
    match result {
        Ok(()) => (DirectCapability::Supported, true),
        Err(e) => {
            let definitive = is_capability_errno(&e);
            (
                DirectCapability::Unsupported(format!("aligned O_DIRECT write failed: {e}")),
                definitive,
            )
        }
    }
}

/// Verdict of one ring-submission capability probe.
#[derive(Debug, Clone)]
pub enum RingCapability {
    /// A probe ring wrote and flushed a scratch file on the filesystem.
    Supported,
    /// The probe failed (or the backend is not compiled in); the reason
    /// is logged once and drains on this device use the per-extent sync
    /// backend.
    Unsupported(String),
}

impl RingCapability {
    /// True when the batched ring path may be used.
    pub fn is_supported(&self) -> bool {
        matches!(self, RingCapability::Supported)
    }

    /// The fallback reason, when unsupported.
    pub fn reason(&self) -> Option<&str> {
        match self {
            RingCapability::Supported => None,
            RingCapability::Unsupported(r) => Some(r),
        }
    }
}

/// Cached per-filesystem ring-submission capability probes, keyed by
/// `st_dev` exactly like [`DirectProbe`] and shared by clones. Ring
/// verdicts are always cached definitively: a kernel or sandbox that
/// rejects `io_uring_setup` will not change its mind mid-run, and the
/// rare transient probe failure merely costs this process the batching
/// optimization, never correctness.
#[derive(Clone, Default)]
pub struct RingProbe {
    cache: Arc<Mutex<HashMap<u64, RingCapability>>>,
}

impl RingProbe {
    /// Capability of the filesystem holding `dir`, probing on first
    /// query and serving the cached verdict afterwards. A fallback is
    /// logged with its reason once per filesystem, so CI logs show
    /// *why* the sync submission path engaged.
    pub fn capability(&self, dir: &Path) -> RingCapability {
        use std::os::unix::fs::MetadataExt;
        let key = match std::fs::metadata(dir) {
            Ok(m) => m.dev(),
            Err(e) => {
                return RingCapability::Unsupported(format!("cannot stat {}: {e}", dir.display()))
            }
        };
        if let Some(cap) = self.cache.lock().unwrap().get(&key) {
            return cap.clone();
        }
        // Probe without holding the lock (same rationale as DirectProbe:
        // a hung mount must not stall unrelated lanes).
        let cap = match probe_ring_support(dir) {
            Ok(()) => RingCapability::Supported,
            Err(reason) => RingCapability::Unsupported(reason),
        };
        if let RingCapability::Unsupported(reason) = &cap {
            eprintln!(
                "fastpersist: ring submission unavailable for {} ({reason}); using per-extent \
                 sync submission",
                dir.display()
            );
        }
        self.cache.lock().unwrap().insert(key, cap.clone());
        cap
    }

    /// Number of filesystems probed so far (test instrumentation).
    pub fn probed(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// One real ring capability probe, delegated to the io_uring module
/// when it is compiled in.
#[cfg(all(target_os = "linux", feature = "io-uring"))]
fn probe_ring_support(dir: &Path) -> std::result::Result<(), String> {
    crate::io::uring::probe_ring(dir)
}

/// Without the `io-uring` feature (or off Linux) the ring backend does
/// not exist, so every filesystem is definitively unsupported.
#[cfg(not(all(target_os = "linux", feature = "io-uring")))]
fn probe_ring_support(dir: &Path) -> std::result::Result<(), String> {
    let _ = dir;
    Err("io-uring backend not compiled into this build".to_string())
}

/// Ordered set of storage mount points for checkpoint fan-out.
#[derive(Clone, Default)]
pub struct DeviceMap {
    roots: Vec<PathBuf>,
    probe: DirectProbe,
    ring: RingProbe,
}

impl PartialEq for DeviceMap {
    fn eq(&self, other: &Self) -> bool {
        self.roots == other.roots
    }
}

impl Eq for DeviceMap {}

impl std::fmt::Debug for DeviceMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceMap").field("roots", &self.roots).finish()
    }
}

impl DeviceMap {
    /// The single-device map: all partitions go to the checkpoint dir.
    pub fn single() -> DeviceMap {
        DeviceMap::default()
    }

    /// A map over explicit mount points (created if missing).
    pub fn from_roots(roots: Vec<PathBuf>) -> Result<DeviceMap> {
        if roots.is_empty() {
            return Err(Error::Config("DeviceMap::from_roots needs >= 1 root".into()));
        }
        for root in &roots {
            std::fs::create_dir_all(root)?;
        }
        Ok(DeviceMap { roots, probe: DirectProbe::default(), ring: RingProbe::default() })
    }

    /// `n` simulated SSDs as sibling dirs `base/ssd0..ssd{n-1}` — the
    /// per-socket NVMe array of a DGX node, modeled on one filesystem.
    pub fn simulated(n: usize, base: &Path) -> Result<DeviceMap> {
        if n == 0 {
            return Err(Error::Config("DeviceMap::simulated needs >= 1 device".into()));
        }
        let roots = (0..n).map(|i| base.join(format!("ssd{i}"))).collect();
        DeviceMap::from_roots(roots)
    }

    /// Number of devices; 0 means the single-device degenerate map.
    pub fn len(&self) -> usize {
        self.roots.len()
    }

    /// True for the degenerate single-device map.
    pub fn is_empty(&self) -> bool {
        self.roots.is_empty()
    }

    /// True when partitions actually fan out over separate mounts.
    pub fn is_multi(&self) -> bool {
        self.roots.len() > 1
    }

    /// The configured mount-point roots, in striping order.
    pub fn roots(&self) -> &[PathBuf] {
        &self.roots
    }

    /// Device index owning partition `index` — round-robin striping.
    /// `None` on the degenerate map. Every partition maps onto exactly
    /// one device (tested as a property below).
    pub fn route(&self, index: usize) -> Option<usize> {
        if self.roots.is_empty() {
            None
        } else {
            Some(index % self.roots.len())
        }
    }

    /// Device index whose root contains `path` (`None` when the path is
    /// outside every configured root — the degenerate single-device
    /// case). This is the submission-lane key of the write pipeline's
    /// per-device drain queues.
    pub fn device_of(&self, path: &Path) -> Option<usize> {
        self.roots.iter().position(|root| path.starts_with(root))
    }

    /// Directory whose filesystem governs direct-I/O capability for
    /// `path`: the device root when the path is device-routed, the
    /// file's parent directory otherwise.
    pub fn capability_dir(&self, path: &Path) -> PathBuf {
        match self.device_of(path) {
            Some(i) => self.roots[i].clone(),
            None => path
                .parent()
                .filter(|p| !p.as_os_str().is_empty())
                .map(Path::to_path_buf)
                .unwrap_or_else(|| PathBuf::from(".")),
        }
    }

    /// O_DIRECT capability of the filesystem holding `path` — probed
    /// once per device (or per directory on the degenerate map) and
    /// cached for the map's lifetime. Clones share the cache.
    pub fn direct_capability_for(&self, path: &Path) -> DirectCapability {
        self.probe.capability(&self.capability_dir(path))
    }

    /// The probe cache (test instrumentation: `probe().probed()` counts
    /// distinct directories probed).
    pub fn probe(&self) -> &DirectProbe {
        &self.probe
    }

    /// Ring-submission capability of the filesystem holding `path` —
    /// probed once per device (or per directory on the degenerate map)
    /// and cached for the map's lifetime, mirroring
    /// [`Self::direct_capability_for`]. Clones share the cache.
    pub fn ring_capability_for(&self, path: &Path) -> RingCapability {
        self.ring.capability(&self.capability_dir(path))
    }

    /// The ring probe cache (test instrumentation).
    pub fn ring_probe(&self) -> &RingProbe {
        &self.ring
    }

    /// Where partition `index` of the checkpoint in `dir` lives:
    /// `(directory, recorded device root)`. `None` routes to `dir`
    /// itself (degenerate map).
    pub fn partition_dir(&self, dir: &Path, index: usize) -> Option<(PathBuf, String)> {
        self.route(index).map(|d| {
            let root = &self.roots[d];
            (Self::resolve_in(root, dir), root.display().to_string())
        })
    }

    /// The per-checkpoint directory on device `root` for the checkpoint
    /// published at `dir`. Pure function of `(root, dir)`, so writers
    /// and loaders agree without storing absolute partition paths.
    pub fn resolve_in(root: &Path, dir: &Path) -> PathBuf {
        root.join(Self::checkpoint_tag(dir))
    }

    /// Stable tag identifying the checkpoint directory on shared device
    /// mounts (several checkpoints stripe over the same SSDs). The tag
    /// hashes the *canonicalized* directory path, so a checkpoint
    /// directory must not be moved after writing — its device-side
    /// partitions would resolve to a different tag (delete and re-write
    /// instead, or keep single-device layouts relocatable).
    pub fn checkpoint_tag(dir: &Path) -> String {
        let canon = std::fs::canonicalize(dir).unwrap_or_else(|_| dir.to_path_buf());
        let h = checksum64_slice(canon.to_string_lossy().as_bytes());
        format!("fpck-{h:016x}")
    }

    /// Garbage-collect the device-side partition directories of the
    /// checkpoint at `dir`. Call **before** removing `dir` itself (the
    /// tag needs the directory to still canonicalize). No-op on the
    /// degenerate map; missing per-device dirs are ignored.
    pub fn remove_checkpoint(&self, dir: &Path) {
        if self.roots.is_empty() {
            return;
        }
        let tag = Self::checkpoint_tag(dir);
        for root in &self.roots {
            let _ = std::fs::remove_dir_all(root.join(&tag));
        }
    }
}

/// A read-only memory-mapped view of an immutable file — the zero-copy
/// serving path of the restore cache ([`crate::checkpoint::serve`]).
///
/// Segment stores are written once and only ever replaced wholesale (GC
/// rewrites publish a new file via rename), so a mapping taken between
/// invalidations observes a stable byte image. The mapping is dropped
/// with the value; [`MappedFile::map`] returns `Ok(None)` where mmap is
/// unavailable (non-Linux builds, or empty files, which cannot be
/// mapped) so callers fall back to buffered reads.
#[cfg(target_os = "linux")]
pub struct MappedFile {
    ptr: *mut u8,
    len: usize,
}

#[cfg(target_os = "linux")]
// SAFETY: the mapping is PROT_READ/MAP_PRIVATE over an immutable file;
// the raw pointer is only ever exposed as a shared `&[u8]`.
unsafe impl Send for MappedFile {}
#[cfg(target_os = "linux")]
// SAFETY: see the Send impl — all access is read-only.
unsafe impl Sync for MappedFile {}

#[cfg(target_os = "linux")]
impl MappedFile {
    /// Map the whole of `path` read-only. `Ok(None)` when the file is
    /// empty (zero-length mappings are invalid); errors bubble up for
    /// missing files or a refused mmap.
    pub fn map(path: &Path) -> Result<Option<MappedFile>> {
        use std::os::unix::io::AsRawFd;
        extern "C" {
            fn mmap(
                addr: *mut u8,
                len: usize,
                prot: i32,
                flags: i32,
                fd: i32,
                offset: i64,
            ) -> *mut u8;
        }
        const PROT_READ: i32 = 1;
        const MAP_PRIVATE: i32 = 2;
        const MAP_FAILED: isize = -1;
        let file = std::fs::File::open(path)
            .map_err(|e| Error::Format(format!("mmap {}: {e}", path.display())))?;
        let len = file
            .metadata()
            .map_err(|e| Error::Format(format!("mmap {}: {e}", path.display())))?
            .len() as usize;
        if len == 0 {
            return Ok(None);
        }
        // SAFETY: fd is open for the duration of the call; the kernel
        // validates every argument and reports failure via MAP_FAILED.
        let ptr = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr as isize == MAP_FAILED {
            return Err(Error::Format(format!(
                "mmap {}: {}",
                path.display(),
                std::io::Error::last_os_error()
            )));
        }
        Ok(Some(MappedFile { ptr, len }))
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        // SAFETY: ptr/len come from a successful mmap and stay valid
        // until Drop; the mapping is private, so no writer mutates it.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

#[cfg(target_os = "linux")]
impl Drop for MappedFile {
    fn drop(&mut self) {
        extern "C" {
            fn munmap(addr: *mut u8, len: usize) -> i32;
        }
        // SAFETY: exact (ptr, len) pair returned by mmap, unmapped once.
        unsafe {
            let _ = munmap(self.ptr, self.len);
        }
    }
}

/// mmap is Linux-only in this build; other platforms always take the
/// buffered fallback.
#[cfg(not(target_os = "linux"))]
pub struct MappedFile;

#[cfg(not(target_os = "linux"))]
impl MappedFile {
    /// Always `Ok(None)`: no mapping support, callers fall back.
    pub fn map(_path: &Path) -> Result<Option<MappedFile>> {
        Ok(None)
    }

    /// Unreachable — `map` never constructs a value on this platform.
    pub fn bytes(&self) -> &[u8] {
        &[]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;

    #[test]
    fn degenerate_map_routes_nowhere() {
        let m = DeviceMap::single();
        assert!(m.is_empty());
        assert_eq!(m.route(0), None);
        assert!(m.partition_dir(Path::new("/tmp/ck"), 3).is_none());
    }

    #[test]
    fn simulated_creates_roots() {
        let base = scratch_dir("devmap-sim").unwrap();
        let m = DeviceMap::simulated(3, &base).unwrap();
        assert_eq!(m.len(), 3);
        for root in m.roots() {
            assert!(root.is_dir());
        }
        assert!(DeviceMap::simulated(0, &base).is_err());
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn tag_is_stable_and_spelling_invariant() {
        let base = scratch_dir("devmap-tag").unwrap();
        let dir = base.join("ck");
        std::fs::create_dir_all(&dir).unwrap();
        let a = DeviceMap::checkpoint_tag(&dir);
        let b = DeviceMap::checkpoint_tag(&base.join("./ck"));
        assert_eq!(a, b, "canonicalization must absorb path spelling");
        let other = base.join("ck2");
        std::fs::create_dir_all(&other).unwrap();
        assert_ne!(a, DeviceMap::checkpoint_tag(&other));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn writer_and_loader_resolution_agree() {
        let base = scratch_dir("devmap-agree").unwrap();
        let dir = base.join("ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let m = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let (pdir, recorded) = m.partition_dir(&dir, 1).unwrap();
        // loader path: recorded root string + checkpoint dir
        let resolved = DeviceMap::resolve_in(Path::new(&recorded), &dir);
        assert_eq!(pdir, resolved);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn remove_checkpoint_gcs_device_dirs() {
        let base = scratch_dir("devmap-gc").unwrap();
        let dir = base.join("ck");
        std::fs::create_dir_all(&dir).unwrap();
        let m = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let (pdir, _) = m.partition_dir(&dir, 0).unwrap();
        std::fs::create_dir_all(&pdir).unwrap();
        std::fs::write(pdir.join("part-0000-rank00000.fpck"), b"x").unwrap();
        m.remove_checkpoint(&dir);
        assert!(!pdir.exists(), "device-side partitions must be GC'd");
        for root in m.roots() {
            assert!(root.is_dir(), "device roots themselves must survive");
        }
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn device_of_matches_roots_only() {
        let base = scratch_dir("devmap-of").unwrap();
        let m = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let inside = m.roots()[1].join("fpck-x").join("part-0.fpck");
        assert_eq!(m.device_of(&inside), Some(1));
        assert_eq!(m.device_of(&base.join("elsewhere.bin")), None);
        assert_eq!(DeviceMap::single().device_of(&base), None);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn probe_runs_once_per_directory_and_is_cached() {
        let base = scratch_dir("devmap-probe").unwrap();
        let m = DeviceMap::from_roots(vec![base.clone()]).unwrap();
        assert_eq!(m.probe().probed(), 0, "no probe before first capability query");
        let first = m.direct_capability_for(&base.join("f.bin"));
        let cached = m.probe().probed();
        assert!(cached <= 1, "at most one definitive verdict per device");
        // repeated queries (and queries through clones) never grow the
        // cache past the one definitive verdict for this filesystem
        let again = m.clone().direct_capability_for(&base.join("g.bin"));
        assert_eq!(m.probe().probed(), cached, "capability must be cached per device");
        if cached == 1 {
            assert_eq!(first.is_supported(), again.is_supported());
        }
        // no probe litter left behind
        let leftovers: Vec<_> = std::fs::read_dir(&base)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".fp-direct-probe"))
            .collect();
        assert!(leftovers.is_empty(), "probe must clean up its scratch file");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn ring_probe_is_cached_and_feature_off_reports_reason() {
        let base = scratch_dir("devmap-ringprobe").unwrap();
        let m = DeviceMap::from_roots(vec![base.clone()]).unwrap();
        assert_eq!(m.ring_probe().probed(), 0, "no probe before first query");
        let first = m.ring_capability_for(&base.join("f.bin"));
        assert_eq!(m.ring_probe().probed(), 1);
        let again = m.clone().ring_capability_for(&base.join("g.bin"));
        assert_eq!(m.ring_probe().probed(), 1, "ring capability must be cached per device");
        assert_eq!(first.is_supported(), again.is_supported());
        if !cfg!(feature = "io-uring") {
            let reason = first.reason().expect("feature-off builds must be unsupported");
            assert!(reason.contains("not compiled"), "fallback must say why: {reason}");
        }
        let leftovers: Vec<_> = std::fs::read_dir(&base)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".fp-ring-probe"))
            .collect();
        assert!(leftovers.is_empty(), "ring probe must clean up its scratch file");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn capability_dir_prefers_device_root() {
        let base = scratch_dir("devmap-capdir").unwrap();
        let m = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let routed = m.roots()[0].join("fpck-t").join("part.fpck");
        assert_eq!(m.capability_dir(&routed), m.roots()[0]);
        let loose = base.join("ck").join("part.fpck");
        assert_eq!(m.capability_dir(&loose), base.join("ck"));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn mapped_file_serves_exact_bytes() {
        let base = scratch_dir("devmap-mmap").unwrap();
        let path = base.join("seg.bin");
        let payload: Vec<u8> = (0..8192u32).map(|i| (i * 31 + 5) as u8).collect();
        std::fs::write(&path, &payload).unwrap();
        match MappedFile::map(&path).unwrap() {
            Some(m) => assert_eq!(m.bytes(), &payload[..], "mapping must mirror the file"),
            None => assert!(cfg!(not(target_os = "linux")), "linux must map a non-empty file"),
        }
        // empty files cannot be mapped — callers must fall back
        let empty = base.join("empty.bin");
        std::fs::write(&empty, b"").unwrap();
        assert!(MappedFile::map(&empty).unwrap().is_none());
        assert!(MappedFile::map(&base.join("missing.bin")).is_err());
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn prop_routing_tiles_partitions_onto_exactly_one_device() {
        crate::prop::forall("device routing tiles partitions", 128, |g| {
            let ndev = g.usize(1, 8);
            let nparts = g.usize(1, 64);
            let roots: Vec<PathBuf> =
                (0..ndev).map(|i| PathBuf::from(format!("/virtual/dev{i}"))).collect();
            let m = DeviceMap { roots, probe: DirectProbe::default(), ring: RingProbe::default() };
            let mut per_device = vec![0usize; ndev];
            for p in 0..nparts {
                // exactly one device, in bounds
                let Some(d) = m.route(p) else { return false };
                if d >= ndev {
                    return false;
                }
                if m.route(p) != Some(d) {
                    return false; // deterministic
                }
                per_device[d] += 1;
            }
            // striping is balanced: counts differ by at most one
            let min = *per_device.iter().min().unwrap();
            let max = *per_device.iter().max().unwrap();
            per_device.iter().sum::<usize>() == nparts && max - min <= 1
        });
    }
}
