//! NVMe-optimized write engine (paper §4.1): aligned direct writes from
//! pinned staging buffers, single- or double-buffered.
//!
//! The file is opened with `O_DIRECT` when the filesystem supports it
//! (bypassing the page cache, as libaio/io_uring submission paths do);
//! when it doesn't (overlayfs, tmpfs), the engine transparently falls
//! back to aligned `pwrite` on a regular descriptor — the *structure* of
//! the path (alignment, staging, overlap, prefix/suffix split) is
//! identical, which is what the microbenchmarks measure.
//!
//! The engine does **not** own per-sink buffers or threads: staging
//! buffers come from a [`BufferPool`] and drains go through a
//! [`DrainPool`], both either private to the engine (standalone
//! construction, resources created once per engine) or shared across
//! every engine of an [`crate::io::runtime::IoRuntime`]. Either way,
//! creating a sink allocates nothing.

use std::fs::{File, OpenOptions};
use std::os::unix::fs::{FileExt, OpenOptionsExt};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::io::buffer::BufferPool;
use crate::io::double_buffer::{DrainPool, StagedWriter};
use crate::io::engine::{EngineKind, IoConfig, Sink, WriteEngine, WriteStats};
use crate::Result;

/// `O_DIRECT` without a libc dependency (Linux; zero elsewhere, where
/// the open falls back to the buffered descriptor anyway).
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "x86")))]
const O_DIRECT: i32 = 0o40000;
#[cfg(all(
    target_os = "linux",
    not(any(target_arch = "x86_64", target_arch = "x86"))
))]
const O_DIRECT: i32 = 0o200000;
#[cfg(not(target_os = "linux"))]
const O_DIRECT: i32 = 0;

/// The NVMe-optimized (aligned, staged, direct) write engine.
pub struct DirectEngine {
    cfg: IoConfig,
    pool: BufferPool,
    drain: DrainPool,
}

impl DirectEngine {
    /// Standalone engine owning its (engine-lifetime) staging pool and
    /// drain worker.
    pub fn new(cfg: IoConfig) -> DirectEngine {
        let cfg = cfg.normalized();
        let buffers = match cfg.kind {
            EngineKind::DirectDouble => 2,
            _ => 1,
        };
        let pool = BufferPool::with_align(buffers, cfg.io_buf_size, cfg.align);
        let drain = DrainPool::new(1);
        DirectEngine::with_resources(cfg, pool, drain)
    }

    /// Engine borrowing runtime-owned resources; the hot path never
    /// allocates staging memory or spawns threads.
    pub fn with_resources(cfg: IoConfig, pool: BufferPool, drain: DrainPool) -> DirectEngine {
        let mut cfg = cfg.normalized();
        // The shared pool's geometry wins: buffers were sized/aligned at
        // runtime construction.
        cfg.align = pool.align();
        let clamped = cfg.io_buf_size.min(pool.buf_size()).max(pool.align());
        cfg.io_buf_size =
            crate::io::align::align_down(clamped as u64, pool.align() as u64) as usize;
        DirectEngine { cfg, pool, drain }
    }

    /// Per-sink cap on in-flight staged buffers (Fig. 5 a/b).
    fn max_inflight(&self) -> usize {
        match self.cfg.kind {
            EngineKind::DirectDouble => 2,
            _ => 1,
        }
    }

    /// Open `path` for direct writes; returns (file, o_direct_engaged).
    fn open_direct(&self, path: &Path) -> Result<(File, bool)> {
        if self.cfg.try_o_direct && O_DIRECT != 0 {
            let attempt = OpenOptions::new()
                .create(true)
                .write(true)
                .truncate(true)
                .custom_flags(O_DIRECT)
                .open(path);
            if let Ok(f) = attempt {
                return Ok((f, true));
            }
        }
        let f = OpenOptions::new().create(true).write(true).truncate(true).open(path)?;
        Ok((f, false))
    }
}

impl WriteEngine for DirectEngine {
    fn kind(&self) -> EngineKind {
        self.cfg.kind
    }

    fn create(&self, path: &Path, expected_size: Option<u64>) -> Result<Box<dyn Sink>> {
        let (direct_file, o_direct) = self.open_direct(path)?;
        // Second, traditional descriptor for the unaligned suffix (and
        // final truncate) — the paper's two-path file (§4.1).
        let suffix_file = OpenOptions::new().write(true).open(path)?;
        if let Some(size) = expected_size {
            // Pre-allocate so parallel/aligned writes don't fight over
            // metadata updates.
            direct_file.set_len(crate::io::align::align_up(size, self.cfg.align as u64))?;
        }
        // Right-size the staged chunk to the data: pooled buffers are
        // fixed-capacity, but a small checkpoint should drain after its
        // last byte, not after a 32 MB high-water mark. Never below one
        // alignment unit.
        let chunk = match expected_size {
            Some(size) => {
                let need = crate::io::align::align_up(size, self.cfg.align as u64) as usize;
                self.cfg.io_buf_size.min(need.max(self.cfg.align))
            }
            None => self.cfg.io_buf_size,
        };
        let writer = StagedWriter::new(
            Arc::new(direct_file),
            self.pool.clone(),
            self.drain.clone(),
            self.max_inflight(),
            chunk,
        );
        Ok(Box::new(DirectSink {
            writer: Some(writer),
            suffix_file,
            sync: self.cfg.sync_on_finish,
            o_direct,
            start: Instant::now(),
        }))
    }
}

struct DirectSink {
    writer: Option<StagedWriter>,
    suffix_file: File,
    sync: bool,
    o_direct: bool,
    start: Instant,
}

impl Sink for DirectSink {
    fn write(&mut self, data: &[u8]) -> Result<()> {
        self.writer.as_mut().expect("sink finished").stage(data)
    }

    fn finish(mut self: Box<Self>) -> Result<WriteStats> {
        let writer = self.writer.take().unwrap();
        let total = writer.staged_bytes();
        let (suffix, suffix_offset, drain) = writer.finish()?;
        if !suffix.is_empty() {
            self.suffix_file.write_all_at(&suffix, suffix_offset)?;
        }
        // Trim pre-allocation padding to the logical length.
        self.suffix_file.set_len(total)?;
        let mut fsyncs = 0;
        if self.sync {
            // fdatasync is per-inode, not per-descriptor: one call
            // covers bytes written through both paths (O_DIRECT bypasses
            // the page cache but not the device cache; the suffix went
            // through the page cache regardless).
            self.suffix_file.sync_data()?;
            fsyncs = 1;
        }
        Ok(WriteStats {
            total_bytes: total,
            aligned_bytes: drain.bytes,
            suffix_bytes: suffix.len() as u64,
            write_ops: drain.ops + u64::from(!suffix.is_empty()),
            fsyncs,
            elapsed: self.start.elapsed(),
            o_direct: self.o_direct,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::util::rng::Rng;

    fn engine(kind: EngineKind, buf: usize) -> DirectEngine {
        DirectEngine::new(IoConfig {
            kind,
            io_buf_size: buf,
            align: 4096,
            ..IoConfig::default()
        })
    }

    fn roundtrip(kind: EngineKind, buf: usize, data: &[u8], pieces: usize) -> WriteStats {
        let dir = scratch_dir("direct-rt").unwrap();
        let path = dir.join(format!("{}-{}.bin", kind.name(), data.len()));
        let e = engine(kind, buf);
        let mut sink = e.create(&path, Some(data.len() as u64)).unwrap();
        for chunk in data.chunks(data.len().max(1) / pieces.max(1) + 1) {
            sink.write(chunk).unwrap();
        }
        let stats = sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), data, "kind={kind:?}");
        std::fs::remove_dir_all(&dir).unwrap();
        stats
    }

    #[test]
    fn roundtrips_both_modes() {
        let mut data = vec![0u8; 1_000_000 + 777];
        Rng::new(5).fill_bytes(&mut data);
        for kind in [EngineKind::DirectSingle, EngineKind::DirectDouble] {
            let stats = roundtrip(kind, 64 << 10, &data, 7);
            assert_eq!(stats.total_bytes, data.len() as u64);
            assert_eq!(stats.aligned_bytes + stats.suffix_bytes, stats.total_bytes);
            assert!(stats.suffix_bytes < 4096);
        }
    }

    #[test]
    fn aligned_exact_size_has_no_suffix() {
        let data = vec![3u8; 128 << 10]; // multiple of 4096
        let stats = roundtrip(EngineKind::DirectDouble, 32 << 10, &data, 3);
        assert_eq!(stats.suffix_bytes, 0);
        assert_eq!(stats.aligned_bytes, data.len() as u64);
    }

    #[test]
    fn sub_alignment_checkpoint_is_all_suffix() {
        let data = vec![9u8; 100];
        let stats = roundtrip(EngineKind::DirectSingle, 4096, &data, 1);
        assert_eq!(stats.aligned_bytes, 0);
        assert_eq!(stats.suffix_bytes, 100);
    }

    #[test]
    fn empty_checkpoint() {
        let stats = roundtrip(EngineKind::DirectDouble, 4096, &[], 1);
        assert_eq!(stats.total_bytes, 0);
    }

    #[test]
    fn unknown_size_works_without_preallocation() {
        let dir = scratch_dir("direct-nosize").unwrap();
        let path = dir.join("x.bin");
        let e = engine(EngineKind::DirectDouble, 8192);
        let mut sink = e.create(&path, None).unwrap();
        let data = vec![4u8; 10_000];
        sink.write(&data).unwrap();
        sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn config_rounds_buffer_to_alignment() {
        let e = engine(EngineKind::DirectSingle, 5000);
        assert_eq!(e.cfg.io_buf_size % 4096, 0);
        assert!(e.cfg.io_buf_size >= 5000);
    }

    #[test]
    fn engine_reuse_does_not_allocate_buffers() {
        // The satellite regression: sinks must borrow, never allocate.
        let dir = scratch_dir("direct-reuse").unwrap();
        let e = engine(EngineKind::DirectDouble, 16 << 10);
        // warm-up write + deterministic prewarm of the rest of the pool
        let mut sink = e.create(&dir.join("warm.bin"), Some(50_000)).unwrap();
        sink.write(&[1u8; 50_000]).unwrap();
        sink.finish().unwrap();
        e.pool.prewarm();
        let allocs = e.pool.allocations();
        for i in 0..5 {
            let path = dir.join(format!("f{i}.bin"));
            let data = vec![i as u8; 60_000 + i * 123];
            let mut sink = e.create(&path, Some(data.len() as u64)).unwrap();
            sink.write(&data).unwrap();
            sink.finish().unwrap();
            assert_eq!(std::fs::read(&path).unwrap(), data);
        }
        assert_eq!(
            e.pool.allocations(),
            allocs,
            "steady-state create()/finish() must not allocate"
        );
        assert!(e.pool.acquires() >= 5, "sinks must check buffers out of the pool");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shared_resources_between_engines() {
        let dir = scratch_dir("direct-shared").unwrap();
        let pool = BufferPool::with_align(2, 8192, 4096);
        let drain = DrainPool::new(1);
        let single = DirectEngine::with_resources(
            IoConfig { kind: EngineKind::DirectSingle, align: 4096, ..IoConfig::default() },
            pool.clone(),
            drain.clone(),
        );
        let double = DirectEngine::with_resources(
            IoConfig { kind: EngineKind::DirectDouble, align: 4096, ..IoConfig::default() },
            pool.clone(),
            drain,
        );
        for (tag, e) in [("s", &single), ("d", &double)] {
            let path = dir.join(format!("{tag}.bin"));
            let data = vec![7u8; 20_000];
            let mut sink = e.create(&path, Some(data.len() as u64)).unwrap();
            sink.write(&data).unwrap();
            sink.finish().unwrap();
            assert_eq!(std::fs::read(&path).unwrap(), data);
        }
        assert!(pool.allocations() <= 2, "engines share the caller's capped pool");
        assert!(pool.acquires() > 0, "engines must draw from the shared pool");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_roundtrip_any_size() {
        crate::prop::forall("direct engine roundtrip", 16, |g| {
            let len = g.usize(0, 200_000);
            let kind = *g.choose(&[EngineKind::DirectSingle, EngineKind::DirectDouble]);
            let buf = 4096 << g.usize(0, 3);
            let mut data = vec![0u8; len];
            Rng::new(g.u64(0, u64::MAX)).fill_bytes(&mut data);
            let stats = roundtrip(kind, buf, &data, g.usize(1, 5));
            stats.total_bytes == len as u64
        });
    }
}
