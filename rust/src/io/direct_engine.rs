//! NVMe-optimized write engine (paper §4.1): aligned direct writes from
//! pinned staging buffers, single- or double-buffered.
//!
//! The file is opened with `O_DIRECT` when the filesystem supports it
//! (bypassing the page cache, as libaio/io_uring submission paths do);
//! when it doesn't (overlayfs, tmpfs), the engine transparently falls
//! back to aligned `pwrite` on a regular descriptor — the *structure* of
//! the path (alignment, staging, overlap, prefix/suffix split) is
//! identical, which is what the microbenchmarks measure.

use std::fs::{File, OpenOptions};
use std::os::unix::fs::{FileExt, OpenOptionsExt};
use std::path::Path;
use std::time::Instant;

use crate::io::double_buffer::StagedWriter;
use crate::io::engine::{EngineKind, IoConfig, Sink, WriteEngine, WriteStats};
use crate::Result;

pub struct DirectEngine {
    cfg: IoConfig,
}

impl DirectEngine {
    pub fn new(mut cfg: IoConfig) -> DirectEngine {
        // io buffer must be an alignment multiple and nonzero
        let align = cfg.align.max(512);
        cfg.align = align;
        cfg.io_buf_size = cfg.io_buf_size.max(align).next_multiple_of(align);
        DirectEngine { cfg }
    }

    fn buffers(&self) -> usize {
        match self.cfg.kind {
            EngineKind::DirectDouble => 2,
            _ => 1,
        }
    }

    /// Open `path` for direct writes; returns (file, o_direct_engaged).
    fn open_direct(&self, path: &Path) -> Result<(File, bool)> {
        if self.cfg.try_o_direct {
            let attempt = OpenOptions::new()
                .create(true)
                .write(true)
                .truncate(true)
                .custom_flags(libc::O_DIRECT)
                .open(path);
            if let Ok(f) = attempt {
                return Ok((f, true));
            }
        }
        let f = OpenOptions::new().create(true).write(true).truncate(true).open(path)?;
        Ok((f, false))
    }
}

impl WriteEngine for DirectEngine {
    fn kind(&self) -> EngineKind {
        self.cfg.kind
    }

    fn create(&self, path: &Path, expected_size: Option<u64>) -> Result<Box<dyn Sink>> {
        let (direct_file, o_direct) = self.open_direct(path)?;
        // Second, traditional descriptor for the unaligned suffix (and
        // final truncate) — the paper's two-path file (§4.1).
        let suffix_file = OpenOptions::new().write(true).open(path)?;
        if let Some(size) = expected_size {
            // Pre-allocate so parallel/aligned writes don't fight over
            // metadata updates.
            direct_file.set_len(crate::io::align::align_up(size, self.cfg.align as u64))?;
        }
        // Size staging buffers to the data: for small checkpoints the
        // configured IO buffer would be mostly idle allocation cost
        // (zeroed pages). Never below one alignment unit.
        let buf_size = match expected_size {
            Some(size) => {
                let need = crate::io::align::align_up(size, self.cfg.align as u64) as usize;
                self.cfg.io_buf_size.min(need.max(self.cfg.align))
            }
            None => self.cfg.io_buf_size,
        };
        let writer = StagedWriter::new(
            direct_file.try_clone()?,
            self.buffers(),
            buf_size,
            self.cfg.align,
        );
        Ok(Box::new(DirectSink {
            writer: Some(writer),
            direct_file,
            suffix_file,
            sync: self.cfg.sync_on_finish,
            o_direct,
            start: Instant::now(),
        }))
    }
}

struct DirectSink {
    writer: Option<StagedWriter>,
    direct_file: File,
    suffix_file: File,
    sync: bool,
    o_direct: bool,
    start: Instant,
}

impl Sink for DirectSink {
    fn write(&mut self, data: &[u8]) -> Result<()> {
        self.writer.as_mut().expect("sink finished").stage(data)
    }

    fn finish(mut self: Box<Self>) -> Result<WriteStats> {
        let writer = self.writer.take().unwrap();
        let total = writer.staged_bytes();
        let (suffix, suffix_offset, drain) = writer.finish()?;
        if !suffix.is_empty() {
            self.suffix_file.write_all_at(&suffix, suffix_offset)?;
        }
        // Trim pre-allocation padding to the logical length.
        self.suffix_file.set_len(total)?;
        if self.sync {
            // O_DIRECT bypasses the page cache but not the device cache;
            // the suffix went through the page cache regardless.
            self.suffix_file.sync_data()?;
            self.direct_file.sync_data()?;
        }
        Ok(WriteStats {
            total_bytes: total,
            aligned_bytes: drain.bytes,
            suffix_bytes: suffix.len() as u64,
            write_ops: drain.ops + u64::from(!suffix.is_empty()),
            elapsed: self.start.elapsed(),
            o_direct: self.o_direct,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::util::rng::Rng;

    fn engine(kind: EngineKind, buf: usize) -> DirectEngine {
        DirectEngine::new(IoConfig {
            kind,
            io_buf_size: buf,
            align: 4096,
            ..IoConfig::default()
        })
    }

    fn roundtrip(kind: EngineKind, buf: usize, data: &[u8], pieces: usize) -> WriteStats {
        let dir = scratch_dir("direct-rt").unwrap();
        let path = dir.join(format!("{}-{}.bin", kind.name(), data.len()));
        let e = engine(kind, buf);
        let mut sink = e.create(&path, Some(data.len() as u64)).unwrap();
        for chunk in data.chunks(data.len().max(1) / pieces.max(1) + 1) {
            sink.write(chunk).unwrap();
        }
        let stats = sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), data, "kind={kind:?}");
        std::fs::remove_dir_all(&dir).unwrap();
        stats
    }

    #[test]
    fn roundtrips_both_modes() {
        let mut data = vec![0u8; 1_000_000 + 777];
        Rng::new(5).fill_bytes(&mut data);
        for kind in [EngineKind::DirectSingle, EngineKind::DirectDouble] {
            let stats = roundtrip(kind, 64 << 10, &data, 7);
            assert_eq!(stats.total_bytes, data.len() as u64);
            assert_eq!(stats.aligned_bytes + stats.suffix_bytes, stats.total_bytes);
            assert!(stats.suffix_bytes < 4096);
        }
    }

    #[test]
    fn aligned_exact_size_has_no_suffix() {
        let data = vec![3u8; 128 << 10]; // multiple of 4096
        let stats = roundtrip(EngineKind::DirectDouble, 32 << 10, &data, 3);
        assert_eq!(stats.suffix_bytes, 0);
        assert_eq!(stats.aligned_bytes, data.len() as u64);
    }

    #[test]
    fn sub_alignment_checkpoint_is_all_suffix() {
        let data = vec![9u8; 100];
        let stats = roundtrip(EngineKind::DirectSingle, 4096, &data, 1);
        assert_eq!(stats.aligned_bytes, 0);
        assert_eq!(stats.suffix_bytes, 100);
    }

    #[test]
    fn empty_checkpoint() {
        let stats = roundtrip(EngineKind::DirectDouble, 4096, &[], 1);
        assert_eq!(stats.total_bytes, 0);
    }

    #[test]
    fn unknown_size_works_without_preallocation() {
        let dir = scratch_dir("direct-nosize").unwrap();
        let path = dir.join("x.bin");
        let e = engine(EngineKind::DirectDouble, 8192);
        let mut sink = e.create(&path, None).unwrap();
        let data = vec![4u8; 10_000];
        sink.write(&data).unwrap();
        sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn config_rounds_buffer_to_alignment() {
        let e = engine(EngineKind::DirectSingle, 5000);
        assert_eq!(e.cfg.io_buf_size % 4096, 0);
        assert!(e.cfg.io_buf_size >= 5000);
    }

    #[test]
    fn prop_roundtrip_any_size() {
        crate::prop::forall("direct engine roundtrip", 16, |g| {
            let len = g.usize(0, 200_000);
            let kind = *g.choose(&[EngineKind::DirectSingle, EngineKind::DirectDouble]);
            let buf = 4096 << g.usize(0, 3);
            let mut data = vec![0u8; len];
            Rng::new(g.u64(0, u64::MAX)).fill_bytes(&mut data);
            let stats = roundtrip(kind, buf, &data, g.usize(1, 5));
            stats.total_bytes == len as u64
        });
    }
}
