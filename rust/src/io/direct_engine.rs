//! NVMe-optimized write *policy* (paper §4.1): aligned direct writes
//! from pinned staging buffers, single- or double-buffered.
//!
//! Since the unified pipeline ([`crate::io::write`]) this engine only
//! *plans*: it derives the staged op schedule via
//! [`crate::io::double_buffer::plan_staged`] (identical aligned
//! extents for both kinds; the queue depth is the whole difference) and
//! hands it to the one shared executor. O_DIRECT engagement, the
//! per-device capability probe, the zeroed bounce tail, and the drain
//! loop itself all live in the executor — there is no engine-private
//! write code left.
//!
//! The engine does **not** own per-sink buffers or threads: staging
//! buffers and submission lanes come from a
//! [`crate::io::write::WriteResources`], either private to the engine
//! (standalone construction, resources created once per engine) or
//! shared across every engine of an [`crate::io::runtime::IoRuntime`].
//! Either way, planning and sink creation allocate nothing.

use std::path::Path;

use crate::io::double_buffer::{overlap_depth, plan_staged};
use crate::io::engine::{EngineKind, IoConfig, Sink, WriteEngine};
use crate::io::write::{WritePipeline, WritePlan, WriteResources};
use crate::Result;

/// The NVMe-optimized (aligned, staged, direct) planning policy.
pub struct DirectEngine {
    cfg: IoConfig,
    res: WriteResources,
}

impl DirectEngine {
    /// Standalone engine owning its (engine-lifetime) staging pool and
    /// submission lane.
    pub fn new(cfg: IoConfig) -> DirectEngine {
        let cfg = cfg.normalized();
        let buffers = overlap_depth(cfg.kind, cfg.queue_depth);
        let res = WriteResources::standalone(&cfg, buffers);
        DirectEngine::with_resources(cfg, res)
    }

    /// Engine borrowing runtime-owned resources; the hot path never
    /// allocates staging memory or spawns threads.
    pub fn with_resources(cfg: IoConfig, res: WriteResources) -> DirectEngine {
        let mut cfg = cfg.normalized();
        // The shared pool's geometry wins: buffers were sized/aligned at
        // runtime construction.
        cfg.align = res.pool.align();
        let clamped = cfg.io_buf_size.min(res.pool.buf_size()).max(res.pool.align());
        cfg.io_buf_size =
            crate::io::align::align_down(clamped as u64, res.pool.align() as u64) as usize;
        DirectEngine { cfg, res }
    }

    /// The engine's normalized configuration (tests).
    #[cfg(test)]
    pub(crate) fn cfg(&self) -> &IoConfig {
        &self.cfg
    }
}

impl WriteEngine for DirectEngine {
    fn kind(&self) -> EngineKind {
        self.cfg.kind
    }

    fn plan(&self, total: Option<u64>) -> WritePlan {
        plan_staged(&self.cfg, total)
    }

    fn create_planned(
        &self,
        path: &Path,
        plan: WritePlan,
        expected_size: Option<u64>,
    ) -> Result<Box<dyn Sink>> {
        WritePipeline::open(&self.cfg, &self.res, plan, path, expected_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::buffer::BufferPool;
    use crate::io::engine::{scratch_dir, WriteStats};
    use crate::io::write::DrainPool;
    use crate::util::rng::Rng;

    fn engine(kind: EngineKind, buf: usize) -> DirectEngine {
        DirectEngine::new(IoConfig {
            kind,
            io_buf_size: buf,
            align: 4096,
            ..IoConfig::default()
        })
    }

    fn roundtrip(kind: EngineKind, buf: usize, data: &[u8], pieces: usize) -> WriteStats {
        // per-(kind, size, buf) dir: concurrent tests must not remove
        // each other's scratch mid-write
        let dir =
            scratch_dir(&format!("direct-rt-{}-{}-{buf}", kind.name(), data.len())).unwrap();
        let path = dir.join(format!("{}-{}.bin", kind.name(), data.len()));
        let e = engine(kind, buf);
        let mut sink = e.create(&path, Some(data.len() as u64)).unwrap();
        for chunk in data.chunks(data.len().max(1) / pieces.max(1) + 1) {
            sink.write(chunk).unwrap();
        }
        let stats = sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), data, "kind={kind:?}");
        std::fs::remove_dir_all(&dir).unwrap();
        stats
    }

    #[test]
    fn roundtrips_both_modes() {
        let mut data = vec![0u8; 1_000_000 + 777];
        Rng::new(5).fill_bytes(&mut data);
        for kind in [EngineKind::DirectSingle, EngineKind::DirectDouble] {
            let stats = roundtrip(kind, 64 << 10, &data, 7);
            assert_eq!(stats.total_bytes, data.len() as u64);
            assert_eq!(stats.aligned_bytes + stats.suffix_bytes, stats.total_bytes);
            assert!(stats.suffix_bytes < 4096);
        }
    }

    #[test]
    fn aligned_exact_size_has_no_suffix() {
        let data = vec![3u8; 128 << 10]; // multiple of 4096
        let stats = roundtrip(EngineKind::DirectDouble, 32 << 10, &data, 3);
        assert_eq!(stats.suffix_bytes, 0);
        assert_eq!(stats.bounce_bytes, 0, "no tail, no bounce");
        assert_eq!(stats.aligned_bytes, data.len() as u64);
    }

    #[test]
    fn sub_alignment_checkpoint_is_all_suffix() {
        let data = vec![9u8; 100];
        let stats = roundtrip(EngineKind::DirectSingle, 4096, &data, 1);
        assert_eq!(stats.aligned_bytes, 0);
        assert_eq!(stats.suffix_bytes, 100);
        assert_eq!(stats.bounce_bytes, 100, "tail goes through the bounce buffer");
    }

    #[test]
    fn empty_checkpoint() {
        let stats = roundtrip(EngineKind::DirectDouble, 4096, &[], 1);
        assert_eq!(stats.total_bytes, 0);
    }

    #[test]
    fn unknown_size_works_without_preallocation() {
        let dir = scratch_dir("direct-nosize").unwrap();
        let path = dir.join("x.bin");
        let e = engine(EngineKind::DirectDouble, 8192);
        let mut sink = e.create(&path, None).unwrap();
        let data = vec![4u8; 10_000];
        sink.write(&data).unwrap();
        sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn config_rounds_buffer_to_alignment() {
        let e = engine(EngineKind::DirectSingle, 5000);
        assert_eq!(e.cfg().io_buf_size % 4096, 0);
        assert!(e.cfg().io_buf_size >= 5000);
    }

    #[test]
    fn engine_reuse_does_not_allocate_buffers() {
        // The satellite regression: sinks must borrow, never allocate.
        let dir = scratch_dir("direct-reuse").unwrap();
        let e = engine(EngineKind::DirectDouble, 16 << 10);
        // warm-up write + deterministic prewarm of the rest of the pool
        let mut sink = e.create(&dir.join("warm.bin"), Some(50_000)).unwrap();
        sink.write(&[1u8; 50_000]).unwrap();
        sink.finish().unwrap();
        e.res.pool.prewarm();
        let allocs = e.res.pool.allocations();
        for i in 0..5 {
            let path = dir.join(format!("f{i}.bin"));
            let data = vec![i as u8; 60_000 + i * 123];
            let mut sink = e.create(&path, Some(data.len() as u64)).unwrap();
            sink.write(&data).unwrap();
            sink.finish().unwrap();
            assert_eq!(std::fs::read(&path).unwrap(), data);
        }
        assert_eq!(
            e.res.pool.allocations(),
            allocs,
            "steady-state create()/finish() must not allocate"
        );
        assert!(e.res.pool.acquires() >= 5, "sinks must check buffers out of the pool");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shared_resources_between_engines() {
        let dir = scratch_dir("direct-shared").unwrap();
        let res = crate::io::write::WriteResources {
            pool: BufferPool::with_align(2, 8192, 4096),
            drain: DrainPool::new(1),
            devices: crate::io::device::DeviceMap::single(),
            ring: None,
        };
        let single = DirectEngine::with_resources(
            IoConfig { kind: EngineKind::DirectSingle, align: 4096, ..IoConfig::default() },
            res.clone(),
        );
        let double = DirectEngine::with_resources(
            IoConfig { kind: EngineKind::DirectDouble, align: 4096, ..IoConfig::default() },
            res.clone(),
        );
        for (tag, e) in [("s", &single), ("d", &double)] {
            let path = dir.join(format!("{tag}.bin"));
            let data = vec![7u8; 20_000];
            let mut sink = e.create(&path, Some(data.len() as u64)).unwrap();
            sink.write(&data).unwrap();
            sink.finish().unwrap();
            assert_eq!(std::fs::read(&path).unwrap(), data);
        }
        assert!(res.pool.allocations() <= 2, "engines share the caller's capped pool");
        assert!(res.pool.acquires() > 0, "engines must draw from the shared pool");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_roundtrip_any_size() {
        crate::prop::forall("direct engine roundtrip", 16, |g| {
            let len = g.usize(0, 200_000);
            let kind = *g.choose(&[EngineKind::DirectSingle, EngineKind::DirectDouble]);
            let buf = 4096 << g.usize(0, 3);
            let mut data = vec![0u8; len];
            Rng::new(g.u64(0, u64::MAX)).fill_bytes(&mut data);
            let stats = roundtrip(kind, buf, &data, g.usize(1, 5));
            stats.total_bytes == len as u64
        });
    }
}
