//! Batched ring submission backend (io_uring) for the drain lanes.
//!
//! This is the Linux-only, `io-uring`-feature-gated implementation of
//! [`SubmitBackend`]: instead of one `pwrite` syscall per drained
//! extent, a lane worker queues up to the plan's queue depth of extents
//! into a kernel submission ring and issues **one** `io_uring_enter`
//! syscall per batch (FastPersist §4.1: saturate NVMe queue depths with
//! deep, cheap submissions, not blocking per-extent syscalls). The
//! trailing fsync is chained into the same submission as a
//! drain-linked flush op (`IOSQE_IO_DRAIN` + `IORING_OP_FSYNC`): it
//! starts only after every prior write in the ring completes, so one
//! syscall both drains the final batch and makes the file durable.
//!
//! **Registered staging buffers.** At [`RingBackend::create`] the
//! staging pool's buffers are materialized and their
//! `(base address, capacity)` table frozen
//! ([`crate::io::buffer::BufferPool::registration_slots`]). Each lane's
//! ring registers that table once (`IORING_REGISTER_BUFFERS`), after
//! which every drain is an `IORING_OP_WRITE_FIXED` against its buffer's
//! stable slot — the kernel pins the pages once instead of per write.
//! Buffers without a slot (bounce buffers, post-registration growth)
//! take plain `IORING_OP_WRITE` sqes in the same batch.
//!
//! **Ring lifecycle.** Rings are per lane worker: each drain lane is a
//! single persistent thread, so its ring needs no locking and its
//! submission queue is single-producer by construction. The ring is
//! created lazily on the lane's first batch (thread-local) and torn
//! down with the thread. A backend instance only carries the frozen
//! registration table and the ring geometry.
//!
//! Raw syscalls via the glibc `syscall(2)` wrapper — the same
//! no-libc-crate convention as `fallocate` in [`crate::io::write`] and
//! `mmap` in [`crate::io::device`]. Syscall numbers 425/426/427 are the
//! asm-generic (and x86_64) io_uring numbers, identical across modern
//! Linux architectures.
//!
//! Everything degrades gracefully: setup/registration/submission
//! failures fall back to per-extent positioned writes inside the
//! backend, and the per-filesystem probe ([`probe_ring`], cached by
//! [`crate::io::device::DeviceMap::ring_capability_for`]) keeps
//! unsupported mounts (seccomp'd containers, exotic filesystems) on the
//! sync path with a logged reason.

use std::cell::RefCell;
use std::fs::File;
use std::os::raw::{c_long, c_void};
use std::os::unix::fs::FileExt;
use std::os::unix::io::AsRawFd;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use crate::io::buffer::{AlignedBuf, BufferPool};
use crate::io::engine::IoConfig;
use crate::io::write::{BatchEntry, BatchReport, BatchStats, SubmitBackend};

const SYS_IO_URING_SETUP: c_long = 425;
const SYS_IO_URING_ENTER: c_long = 426;
const SYS_IO_URING_REGISTER: c_long = 427;

const IORING_OFF_SQ_RING: i64 = 0;
const IORING_OFF_CQ_RING: i64 = 0x0800_0000;
const IORING_OFF_SQES: i64 = 0x1000_0000;

const IORING_ENTER_GETEVENTS: u32 = 1;
const IORING_REGISTER_BUFFERS: u32 = 0;

const IORING_OP_FSYNC: u8 = 3;
const IORING_OP_WRITE_FIXED: u8 = 5;
const IORING_OP_WRITE: u8 = 23;

/// The flush op starts only after all prior sqes complete.
const IOSQE_IO_DRAIN: u8 = 1 << 1;
const IORING_FSYNC_DATASYNC: u32 = 1;

const PROT_READ: i32 = 1;
const PROT_WRITE: i32 = 2;
const MAP_SHARED: i32 = 1;

extern "C" {
    fn syscall(num: c_long, ...) -> c_long;
    fn mmap(
        addr: *mut c_void,
        len: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut c_void;
    fn munmap(addr: *mut c_void, len: usize) -> i32;
    fn close(fd: i32) -> i32;
}

fn map_failed(p: *mut c_void) -> bool {
    p as isize == -1
}

#[repr(C)]
#[derive(Default, Clone, Copy)]
struct SqringOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    flags: u32,
    dropped: u32,
    array: u32,
    resv1: u32,
    user_addr: u64,
}

#[repr(C)]
#[derive(Default, Clone, Copy)]
struct CqringOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    overflow: u32,
    cqes: u32,
    flags: u32,
    resv1: u32,
    user_addr: u64,
}

#[repr(C)]
#[derive(Default, Clone, Copy)]
struct IoUringParams {
    sq_entries: u32,
    cq_entries: u32,
    flags: u32,
    sq_thread_cpu: u32,
    sq_thread_idle: u32,
    features: u32,
    wq_fd: u32,
    resv: [u32; 3],
    sq_off: SqringOffsets,
    cq_off: CqringOffsets,
}

/// 64-byte submission-queue entry (linux uapi `struct io_uring_sqe`,
/// classic layout).
#[repr(C)]
#[derive(Default, Clone, Copy)]
struct Sqe {
    opcode: u8,
    flags: u8,
    ioprio: u16,
    fd: i32,
    off: u64,
    addr: u64,
    len: u32,
    rw_flags: u32,
    user_data: u64,
    buf_index: u16,
    personality: u16,
    splice_fd_in: i32,
    pad2: [u64; 2],
}

/// 16-byte completion-queue entry.
#[repr(C)]
#[derive(Clone, Copy)]
struct Cqe {
    user_data: u64,
    res: i32,
    flags: u32,
}

const _: () = assert!(std::mem::size_of::<Sqe>() == 64);
const _: () = assert!(std::mem::size_of::<Cqe>() == 16);
const _: () = assert!(std::mem::size_of::<IoUringParams>() == 120);

#[repr(C)]
struct Iovec {
    base: *mut c_void,
    len: usize,
}

fn os_err(what: &str) -> String {
    format!("{what}: {}", std::io::Error::last_os_error())
}

/// One mmap'd io_uring instance owned by a single lane thread.
struct Ring {
    fd: i32,
    sq_ring: *mut u8,
    sq_ring_len: usize,
    cq_ring: *mut u8,
    cq_ring_len: usize,
    sqes: *mut Sqe,
    sqes_len: usize,
    sq_tail: *mut u32,
    sq_mask: u32,
    sq_array: *mut u32,
    cq_head: *mut u32,
    cq_tail: *mut u32,
    cq_mask: u32,
    cqes: *mut Cqe,
    entries: u32,
    /// Fixed buffers registered: WRITE_FIXED usable for slotted buffers.
    fixed: bool,
    /// Number of registered slots (buf_index bound).
    registered: u32,
    /// Identity token of the registration table this ring pinned.
    owner: usize,
}

impl Ring {
    /// Set up a ring of `entries` sqes and register `slots` as fixed
    /// buffers (registration failure downgrades to plain writes, it
    /// does not fail the ring).
    fn new(entries: u32, slots: &[(usize, usize)], owner: usize) -> Result<Ring, String> {
        let mut params = IoUringParams::default();
        let fd = unsafe {
            syscall(SYS_IO_URING_SETUP, entries, &mut params as *mut IoUringParams) as i32
        };
        if fd < 0 {
            return Err(os_err("io_uring_setup"));
        }
        let sq_ring_len = params.sq_off.array as usize + params.sq_entries as usize * 4;
        let cq_ring_len =
            params.cq_off.cqes as usize + params.cq_entries as usize * std::mem::size_of::<Cqe>();
        let sqes_len = params.sq_entries as usize * std::mem::size_of::<Sqe>();
        let prot = PROT_READ | PROT_WRITE;
        unsafe {
            let sq_ring =
                mmap(std::ptr::null_mut(), sq_ring_len, prot, MAP_SHARED, fd, IORING_OFF_SQ_RING);
            if map_failed(sq_ring) {
                let e = os_err("mmap sq ring");
                close(fd);
                return Err(e);
            }
            let cq_ring =
                mmap(std::ptr::null_mut(), cq_ring_len, prot, MAP_SHARED, fd, IORING_OFF_CQ_RING);
            if map_failed(cq_ring) {
                let e = os_err("mmap cq ring");
                munmap(sq_ring, sq_ring_len);
                close(fd);
                return Err(e);
            }
            let sqes = mmap(std::ptr::null_mut(), sqes_len, prot, MAP_SHARED, fd, IORING_OFF_SQES);
            if map_failed(sqes) {
                let e = os_err("mmap sqes");
                munmap(sq_ring, sq_ring_len);
                munmap(cq_ring, cq_ring_len);
                close(fd);
                return Err(e);
            }
            let sq_ring = sq_ring as *mut u8;
            let cq_ring = cq_ring as *mut u8;
            let sq_mask = *(sq_ring.add(params.sq_off.ring_mask as usize) as *const u32);
            let cq_mask = *(cq_ring.add(params.cq_off.ring_mask as usize) as *const u32);
            let mut ring = Ring {
                fd,
                sq_ring,
                sq_ring_len,
                cq_ring,
                cq_ring_len,
                sqes: sqes as *mut Sqe,
                sqes_len,
                sq_tail: sq_ring.add(params.sq_off.tail as usize) as *mut u32,
                sq_mask,
                sq_array: sq_ring.add(params.sq_off.array as usize) as *mut u32,
                cq_head: cq_ring.add(params.cq_off.head as usize) as *mut u32,
                cq_tail: cq_ring.add(params.cq_off.tail as usize) as *mut u32,
                cq_mask,
                cqes: cq_ring.add(params.cq_off.cqes as usize) as *mut Cqe,
                entries: params.sq_entries,
                fixed: false,
                registered: 0,
                owner,
            };
            if !slots.is_empty() {
                let iovecs: Vec<Iovec> = slots
                    .iter()
                    .map(|&(base, len)| Iovec { base: base as *mut c_void, len })
                    .collect();
                let ret = syscall(
                    SYS_IO_URING_REGISTER,
                    fd,
                    IORING_REGISTER_BUFFERS,
                    iovecs.as_ptr(),
                    iovecs.len() as u32,
                );
                // EPERM (memlock limits) and friends: stay unregistered,
                // plain writes still batch through the ring.
                if ret == 0 {
                    ring.fixed = true;
                    ring.registered = iovecs.len() as u32;
                }
            }
            Ok(ring)
        }
    }

    /// Queue every entry (plus the optional drain-linked fsync), issue
    /// one `io_uring_enter` submitting AND reaping the whole batch, and
    /// map completions back to per-entry results. `Err` means the ring
    /// itself failed (not an individual write) — the caller falls back
    /// to positioned writes.
    fn submit(
        &mut self,
        file: &File,
        entries: &[BatchEntry],
        link_fsync: bool,
    ) -> Result<BatchReport, String> {
        let n_writes = entries.len() as u32;
        let n_ops = n_writes + u32::from(link_fsync);
        if n_ops == 0 {
            return Ok(BatchReport {
                results: Vec::new(),
                stats: BatchStats::default(),
                fsync_err: None,
            });
        }
        debug_assert!(n_ops <= self.entries, "batch larger than the ring");
        let fd = file.as_raw_fd();
        unsafe {
            let tail_atomic = &*(self.sq_tail as *const AtomicU32);
            let mut tail = tail_atomic.load(Ordering::Relaxed);
            for (i, e) in entries.iter().enumerate() {
                let idx = (tail & self.sq_mask) as usize;
                let sqe = &mut *self.sqes.add(idx);
                *sqe = Sqe::default();
                match e.buf.slot() {
                    // Fixed-buffer write: zero per-op pin cost against
                    // the slot registered at backend creation.
                    Some(slot) if self.fixed && slot < self.registered => {
                        sqe.opcode = IORING_OP_WRITE_FIXED;
                        sqe.buf_index = slot as u16;
                    }
                    _ => sqe.opcode = IORING_OP_WRITE,
                }
                sqe.fd = fd;
                sqe.off = e.offset;
                sqe.addr = e.buf.base_addr() as u64;
                sqe.len = e.len as u32;
                sqe.user_data = i as u64;
                *self.sq_array.add(idx) = idx as u32;
                tail = tail.wrapping_add(1);
            }
            if link_fsync {
                let idx = (tail & self.sq_mask) as usize;
                let sqe = &mut *self.sqes.add(idx);
                *sqe = Sqe::default();
                sqe.opcode = IORING_OP_FSYNC;
                sqe.flags = IOSQE_IO_DRAIN;
                sqe.fd = fd;
                sqe.rw_flags = IORING_FSYNC_DATASYNC;
                sqe.user_data = n_writes as u64;
                *self.sq_array.add(idx) = idx as u32;
                tail = tail.wrapping_add(1);
            }
            tail_atomic.store(tail, Ordering::Release);
        }
        // ONE submission syscall for the whole batch: submit n_ops and
        // wait for all their completions in the same call. EINTR (and a
        // kernel splitting the submission) retries, honestly counted.
        let mut stats = BatchStats { sqes: n_ops as u64, ..BatchStats::default() };
        let mut to_submit = n_ops;
        let mut cqes: Vec<Cqe> = Vec::with_capacity(n_ops as usize);
        while to_submit > 0 || (cqes.len() as u32) < n_ops {
            let want = n_ops - cqes.len() as u32;
            let ret = unsafe {
                syscall(
                    SYS_IO_URING_ENTER,
                    self.fd,
                    to_submit,
                    want,
                    IORING_ENTER_GETEVENTS,
                    std::ptr::null::<c_void>(),
                    0usize,
                )
            };
            if ret < 0 {
                let err = std::io::Error::last_os_error();
                if err.raw_os_error() == Some(4) {
                    continue; // EINTR before anything was submitted
                }
                return Err(format!("io_uring_enter: {err}"));
            }
            stats.submissions += 1;
            to_submit -= (ret as u32).min(to_submit);
            self.reap(&mut cqes);
        }
        stats.completions = cqes.len() as u64;
        // Map completions back to entries (user_data = entry index).
        let mut results: Vec<std::io::Result<()>> = Vec::with_capacity(entries.len());
        for _ in entries {
            results.push(Err(std::io::Error::other("write completion missing")));
        }
        let mut fsync_res: Option<i32> = None;
        for cqe in &cqes {
            let ud = cqe.user_data as usize;
            if ud < entries.len() {
                let e = &entries[ud];
                results[ud] = if cqe.res < 0 {
                    Err(std::io::Error::from_raw_os_error(-cqe.res))
                } else if (cqe.res as usize) < e.len {
                    // Short ring write (rare on regular files): finish
                    // the extent with a positioned-write tail.
                    let done = cqe.res as usize;
                    file.write_all_at(&e.buf.filled()[done..e.len], e.offset + done as u64)
                } else {
                    Ok(())
                };
            } else {
                fsync_res = Some(cqe.res);
            }
        }
        let fsync_err = if link_fsync {
            match fsync_res {
                Some(res) if res >= 0 => {
                    stats.fsync_done = true;
                    None
                }
                Some(res) => Some(std::io::Error::from_raw_os_error(-res)),
                None => Some(std::io::Error::other("fsync completion missing")),
            }
        } else {
            None
        };
        Ok(BatchReport { results, stats, fsync_err })
    }

    /// Drain every available completion off the cq ring.
    fn reap(&mut self, out: &mut Vec<Cqe>) {
        unsafe {
            let head_atomic = &*(self.cq_head as *const AtomicU32);
            let tail = (*(self.cq_tail as *const AtomicU32)).load(Ordering::Acquire);
            let mut head = head_atomic.load(Ordering::Relaxed);
            while head != tail {
                out.push(*self.cqes.add((head & self.cq_mask) as usize));
                head = head.wrapping_add(1);
            }
            head_atomic.store(head, Ordering::Release);
        }
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        unsafe {
            munmap(self.sqes as *mut c_void, self.sqes_len);
            munmap(self.cq_ring as *mut c_void, self.cq_ring_len);
            munmap(self.sq_ring as *mut c_void, self.sq_ring_len);
            close(self.fd);
        }
    }
}

/// Lane-thread ring slot: lazily created, poisoned on failure so a
/// broken lane doesn't retry ring setup on every batch.
enum LaneSlot {
    Untried,
    Ready(Ring),
    Broken,
}

thread_local! {
    static LANE_RING: RefCell<LaneSlot> = const { RefCell::new(LaneSlot::Untried) };
}

/// The batched [`SubmitBackend`]: per-lane io_uring rings over the
/// staging pool's registered buffers. Create once per
/// [`crate::io::runtime::IoRuntime`] (or standalone resource set) via
/// [`RingBackend::create`]; clone-free sharing through
/// `Arc<dyn SubmitBackend>`.
pub struct RingBackend {
    /// Ring size: smallest power of two fitting a full batch plus the
    /// chained flush op.
    entries: u32,
    /// Frozen `(base address, capacity)` registration table of the
    /// staging pool, pinned by each lane ring at creation. The Arc's
    /// address doubles as the identity token lane rings check so a ring
    /// never serves a table it did not register.
    slots: Arc<Vec<(usize, usize)>>,
}

impl RingBackend {
    /// Resolve the ring backend for `cfg` against `pool`: verify
    /// io_uring works in this process (setup + teardown of a probe
    /// ring), then freeze and adopt the pool's registration table.
    /// Errors report why the environment cannot run the ring path.
    pub fn create(cfg: &IoConfig, pool: &BufferPool) -> Result<RingBackend, String> {
        drop(Ring::new(4, &[], 0)?);
        let slots = Arc::new(pool.registration_slots());
        let entries = (cfg.queue_depth.max(1) as u32 + 1).next_power_of_two().max(8);
        Ok(RingBackend { entries, slots })
    }

    /// Run `f` against this lane's ring, creating (and registering) it
    /// on first use. `None` when the ring cannot be built on this
    /// thread — callers fall back to positioned writes.
    fn with_ring<R>(&self, f: impl FnOnce(&mut Ring) -> R) -> Option<R> {
        LANE_RING.with(|cell| {
            let mut slot = cell.borrow_mut();
            let owner = Arc::as_ptr(&self.slots) as usize;
            // A lane thread serves exactly one backend in practice; if
            // it ever sees another (fresh runtime in tests), rebuild so
            // registered slots always match the pool being drained.
            if matches!(&*slot, LaneSlot::Ready(r) if r.owner != owner) {
                *slot = LaneSlot::Untried;
            }
            if matches!(&*slot, LaneSlot::Untried) {
                *slot = match Ring::new(self.entries, &self.slots, owner) {
                    Ok(ring) => LaneSlot::Ready(ring),
                    Err(_) => LaneSlot::Broken,
                };
            }
            match &mut *slot {
                LaneSlot::Ready(ring) => Some(f(ring)),
                _ => None,
            }
        })
    }
}

/// Per-extent positioned-write fallback used when the ring itself fails
/// mid-flight: positioned writes are idempotent, so re-issuing a batch
/// whose ring submission partially completed is safe.
fn fallback_batch(file: &File, entries: &[BatchEntry], link_fsync: bool) -> BatchReport {
    let mut results = Vec::with_capacity(entries.len());
    for e in entries {
        results.push(file.write_all_at(&e.buf.filled()[..e.len], e.offset));
    }
    let fsync_err = if link_fsync { file.sync_data().err() } else { None };
    BatchReport {
        results,
        stats: BatchStats {
            fsync_done: link_fsync && fsync_err.is_none(),
            ..BatchStats::default()
        },
        fsync_err,
    }
}

impl SubmitBackend for RingBackend {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn submit_batch(&self, file: &File, entries: &[BatchEntry], link_fsync: bool) -> BatchReport {
        match self.with_ring(|ring| ring.submit(file, entries, link_fsync)) {
            Some(Ok(report)) => report,
            // Ring unavailable on this thread or failed as a whole:
            // honest fallback (no batched_submissions counted).
            Some(Err(_)) | None => fallback_batch(file, entries, link_fsync),
        }
    }
}

static PROBE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Capability probe for one filesystem: build a throwaway ring, write
/// one aligned block to a scratch file in `dir` through it with a
/// chained datasync flush, and verify every completion. Mirrors the
/// O_DIRECT probe's contract: `Err(reason)` is a definitive "use the
/// sync path here", cached per device by
/// [`crate::io::device::DeviceMap::ring_capability_for`].
pub fn probe_ring(dir: &Path) -> Result<(), String> {
    let mut ring = Ring::new(4, &[], 0)?;
    let name = format!(
        ".fp-ring-probe-{}-{}",
        std::process::id(),
        PROBE_SEQ.fetch_add(1, Ordering::Relaxed)
    );
    let path = dir.join(name);
    let result = (|| {
        let file = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| format!("probe open: {e}"))?;
        let len = 4096usize;
        let mut buf = AlignedBuf::new(len, len);
        buf.stage(&[7u8; 4096]);
        let entry = BatchEntry { buf, offset: 0, len };
        let report = ring.submit(&file, std::slice::from_ref(&entry), true)?;
        match &report.results[0] {
            Ok(()) => {}
            Err(e) => return Err(format!("probe ring write: {e}")),
        }
        if let Some(e) = report.fsync_err {
            return Err(format!("probe chained fsync: {e}"));
        }
        if report.stats.submissions == 0 {
            return Err("probe made no batched submission".into());
        }
        Ok(())
    })();
    let _ = std::fs::remove_file(&path);
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;

    #[test]
    fn sqe_cqe_layouts_are_abi_sized() {
        assert_eq!(std::mem::size_of::<Sqe>(), 64);
        assert_eq!(std::mem::size_of::<Cqe>(), 16);
        assert_eq!(std::mem::size_of::<IoUringParams>(), 120);
        assert_eq!(std::mem::size_of::<Iovec>(), 16);
    }

    #[test]
    fn probe_and_batched_write_roundtrip_or_unsupported() {
        // On a kernel/sandbox without io_uring the probe must fail with
        // a reason (that is the graceful-skip contract the CI feature
        // job relies on); where it passes, a multi-entry batch must
        // land bit-identical bytes with one submission syscall.
        let dir = scratch_dir("uring-probe").unwrap();
        match probe_ring(&dir) {
            Err(reason) => {
                assert!(!reason.is_empty(), "unsupported probe must carry a reason");
                eprintln!("skipping ring roundtrip: {reason}");
            }
            Ok(()) => {
                let mut ring = Ring::new(8, &[], 0).unwrap();
                let path = dir.join("batch.bin");
                let file = std::fs::OpenOptions::new()
                    .create(true)
                    .write(true)
                    .truncate(true)
                    .open(&path)
                    .unwrap();
                let mut entries = Vec::new();
                for i in 0..3u8 {
                    let mut buf = AlignedBuf::new(4096, 4096);
                    buf.stage(&[i + 1; 4096]);
                    entries.push(BatchEntry { buf, offset: i as u64 * 4096, len: 4096 });
                }
                let report = ring.submit(&file, &entries, true).unwrap();
                assert!(report.results.iter().all(|r| r.is_ok()));
                assert!(report.fsync_err.is_none());
                assert!(report.stats.fsync_done, "chained fsync must complete");
                assert!(report.stats.submissions >= 1);
                assert_eq!(report.stats.sqes, 4, "3 writes + 1 linked flush op");
                assert_eq!(report.stats.completions, 4);
                let mut want = Vec::new();
                for i in 0..3u8 {
                    want.extend_from_slice(&[i + 1; 4096]);
                }
                assert_eq!(std::fs::read(&path).unwrap(), want);
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
