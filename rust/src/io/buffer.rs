//! Aligned ("pinned") staging buffers and a reusable pool.
//!
//! These stand in for the page-locked CPU memory the paper stages
//! checkpoint data through (accelerator → pinned DRAM → NVMe). The two
//! properties that matter are reproduced exactly: (i) the memory is
//! alignment-guaranteed so direct I/O can DMA from it, and (ii) buffers
//! are allocated once and recycled, so the write hot path never touches
//! the allocator (paper §4.3: the helper thread does not allocate).

use std::alloc::{alloc_zeroed, dealloc, Layout};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};

use crate::io::align::DEFAULT_ALIGN;

/// A heap buffer whose base address is aligned to `align` bytes.
pub struct AlignedBuf {
    ptr: *mut u8,
    cap: usize,
    align: usize,
    /// Stable pool slot identity (index into the owning pool's
    /// registration table), assigned at creation and constant for the
    /// buffer's lifetime. Batched submission backends use it to select
    /// fixed-buffer (pre-registered) writes; `None` for buffers created
    /// outside a pool (e.g. bounce buffers), which take the plain write
    /// path.
    slot: Option<u32>,
    /// Bytes currently staged (filled) in the buffer.
    pub len: usize,
}

// SAFETY: AlignedBuf owns its allocation exclusively; the raw pointer is
// never shared. Moving it across threads is sound.
unsafe impl Send for AlignedBuf {}

impl AlignedBuf {
    /// Allocate a zeroed buffer of `cap` bytes at the given alignment.
    pub fn new(cap: usize, align: usize) -> AlignedBuf {
        assert!(align.is_power_of_two() && cap > 0);
        let layout = Layout::from_size_align(cap, align).expect("layout");
        // zeroed so O_DIRECT tail padding never leaks heap garbage to disk
        let ptr = unsafe { alloc_zeroed(layout) };
        assert!(!ptr.is_null(), "aligned alloc failed");
        AlignedBuf { ptr, cap, align, slot: None, len: 0 }
    }

    /// Stable pool slot identity (see the field docs); `None` when the
    /// buffer was created outside a pool.
    pub fn slot(&self) -> Option<u32> {
        self.slot
    }

    /// Base address of the allocation — the registration identity a
    /// batched backend pins with the kernel. Stable for the buffer's
    /// lifetime.
    pub fn base_addr(&self) -> usize {
        self.ptr as usize
    }

    /// Total buffer capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Base-address alignment in bytes.
    pub fn align(&self) -> usize {
        self.align
    }

    /// Whole buffer as a byte slice (including unfilled tail).
    pub fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.cap) }
    }

    /// Whole buffer as a mutable byte slice.
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.cap) }
    }

    /// Filled prefix.
    pub fn filled(&self) -> &[u8] {
        &self.as_slice()[..self.len]
    }

    /// Remaining capacity.
    pub fn remaining(&self) -> usize {
        self.cap - self.len
    }

    /// Stage bytes into the buffer (the "D2H copy" hop). Returns the
    /// number of bytes actually copied (bounded by remaining capacity).
    pub fn stage(&mut self, src: &[u8]) -> usize {
        let n = src.len().min(self.remaining());
        let dst = self.len;
        self.as_mut_slice()[dst..dst + n].copy_from_slice(&src[..n]);
        self.len += n;
        n
    }

    /// Reset the filled length to zero (capacity unchanged).
    pub fn clear(&mut self) {
        self.len = 0;
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        let layout = Layout::from_size_align(self.cap, self.align).unwrap();
        unsafe { dealloc(self.ptr, layout) };
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBuf(cap={}, align={}, len={})", self.cap, self.align, self.len)
    }
}

/// Capped pool of staging buffers. `acquire` blocks (once the cap is
/// reached) until a buffer is free — exactly the backpressure the
/// double-buffered writer relies on (bounded buffers in flight).
///
/// Buffers are allocated lazily on first demand, **never past the cap,
/// and never again once created** — the paper's pinned staging buffers.
/// After warm-up, [`BufferPool::allocations`] is constant for the
/// pool's lifetime: the steady-state checkpoint path performs zero
/// staging allocations, and tests assert exactly that while
/// [`BufferPool::acquires`] keeps climbing (proof of reuse, not of
/// idleness). A pool that is never used costs nothing.
#[derive(Clone)]
pub struct BufferPool {
    rx: Arc<Mutex<Receiver<AlignedBuf>>>,
    tx: Sender<AlignedBuf>,
    buf_size: usize,
    align: usize,
    count: usize,
    /// Buffers created so far (grows to `count`, then freezes).
    created: Arc<Mutex<usize>>,
    /// Staging buffers ever allocated into this pool.
    allocations: Arc<AtomicU64>,
    /// Cumulative successful checkouts (blocking + non-blocking).
    acquires: Arc<AtomicU64>,
    /// Registration table: `(base address, capacity)` of every buffer
    /// ever created, indexed by its slot id. Append-only, frozen once
    /// `created == count`.
    registration: Arc<Mutex<Vec<(usize, usize)>>>,
}

impl BufferPool {
    /// A pool of `count` buffers of `buf_size` bytes at the default
    /// alignment.
    pub fn new(count: usize, buf_size: usize) -> BufferPool {
        Self::with_align(count, buf_size, DEFAULT_ALIGN)
    }

    /// A pool with an explicit buffer alignment.
    pub fn with_align(count: usize, buf_size: usize, align: usize) -> BufferPool {
        assert!(count > 0);
        let (tx, rx) = mpsc::channel();
        BufferPool {
            rx: Arc::new(Mutex::new(rx)),
            tx,
            buf_size,
            align,
            count,
            created: Arc::new(Mutex::new(0)),
            allocations: Arc::new(AtomicU64::new(0)),
            acquires: Arc::new(AtomicU64::new(0)),
            registration: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Create a buffer if the cap allows (warm-up only).
    fn grow(&self) -> Option<AlignedBuf> {
        let slot = {
            let mut created = self.created.lock().unwrap();
            if *created >= self.count {
                return None;
            }
            let slot = *created as u32;
            *created += 1;
            slot
        };
        self.allocations.fetch_add(1, Ordering::Relaxed);
        let mut buf = AlignedBuf::new(self.buf_size, self.align);
        buf.slot = Some(slot);
        {
            let mut reg = self.registration.lock().unwrap();
            debug_assert_eq!(reg.len(), slot as usize);
            reg.push((buf.base_addr(), buf.capacity()));
        }
        Some(buf)
    }

    /// Get a free (recycled) buffer, cleared; blocks when the pool is at
    /// its cap and everything is checked out, creates a buffer during
    /// warm-up otherwise.
    pub fn acquire(&self) -> AlignedBuf {
        if let Ok(mut buf) = self.rx.lock().unwrap().try_recv() {
            buf.clear();
            self.acquires.fetch_add(1, Ordering::Relaxed);
            return buf;
        }
        if let Some(buf) = self.grow() {
            self.acquires.fetch_add(1, Ordering::Relaxed);
            return buf;
        }
        let mut buf = self
            .rx
            .lock()
            .unwrap()
            .recv()
            .expect("buffer pool closed");
        buf.clear();
        self.acquires.fetch_add(1, Ordering::Relaxed);
        buf
    }

    /// Non-blocking acquire (recycled buffer, or warm-up growth).
    pub fn try_acquire(&self) -> Option<AlignedBuf> {
        if let Ok(mut b) = self.rx.lock().unwrap().try_recv() {
            b.clear();
            self.acquires.fetch_add(1, Ordering::Relaxed);
            return Some(b);
        }
        self.grow().map(|b| {
            self.acquires.fetch_add(1, Ordering::Relaxed);
            b
        })
    }

    /// Return a buffer to the pool.
    pub fn release(&self, buf: AlignedBuf) {
        let _ = self.tx.send(buf);
    }

    /// Deterministically finish warm-up: allocate every not-yet-created
    /// buffer up to the cap and place it on the free list. After this,
    /// [`BufferPool::allocations`] can never change again.
    pub fn prewarm(&self) {
        while let Some(buf) = self.grow() {
            let _ = self.tx.send(buf);
        }
    }

    /// Size of each pooled buffer in bytes.
    pub fn buf_size(&self) -> usize {
        self.buf_size
    }

    /// Alignment of the pooled buffers.
    pub fn align(&self) -> usize {
        self.align
    }

    /// Pool cap: the maximum number of buffers ever allocated.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Total staging-buffer allocations performed for this pool. Grows
    /// only during warm-up (bounded by `count`), then constant for the
    /// pool's lifetime; the hot path only recycles.
    pub fn allocations(&self) -> u64 {
        self.allocations.load(Ordering::Relaxed)
    }

    /// Cumulative buffer checkouts over the pool's lifetime.
    pub fn acquires(&self) -> u64 {
        self.acquires.load(Ordering::Relaxed)
    }

    /// Registration hook for batched submission backends: materialize
    /// every buffer up to the cap (via [`BufferPool::prewarm`]) and
    /// return the frozen `(base address, capacity)` table, indexed by
    /// each buffer's [`AlignedBuf::slot`]. The addresses stay valid for
    /// the pool's lifetime — buffers are never deallocated or replaced
    /// once created — so a ring can pin them once at
    /// [`crate::io::runtime::IoRuntime`] construction and service every
    /// subsequent drain as a fixed-buffer write with zero per-op pin
    /// cost. The caller must not outlive the pool.
    pub fn registration_slots(&self) -> Vec<(usize, usize)> {
        self.prewarm();
        self.registration.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_is_respected() {
        for align in [512usize, 4096, 65536] {
            let b = AlignedBuf::new(align * 2, align);
            assert_eq!(b.as_slice().as_ptr() as usize % align, 0);
        }
    }

    #[test]
    fn stage_fills_and_bounds() {
        let mut b = AlignedBuf::new(8, 512);
        assert_eq!(b.stage(&[1, 2, 3]), 3);
        assert_eq!(b.stage(&[4, 5, 6, 7, 8, 9]), 5); // truncated at capacity
        assert_eq!(b.filled(), &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(b.remaining(), 0);
        b.clear();
        assert_eq!(b.remaining(), 8);
    }

    #[test]
    fn zeroed_on_alloc() {
        let b = AlignedBuf::new(4096, 4096);
        assert!(b.as_slice().iter().all(|&x| x == 0));
    }

    #[test]
    fn pool_blocks_until_release() {
        let pool = BufferPool::new(1, 64);
        let b = pool.acquire();
        assert!(pool.try_acquire().is_none());
        pool.release(b);
        assert!(pool.try_acquire().is_some());
    }

    #[test]
    fn pool_recycles_cleared() {
        let pool = BufferPool::new(2, 64);
        let mut b = pool.acquire();
        b.stage(&[9; 10]);
        pool.release(b);
        let _other = pool.acquire();
        let recycled = pool.acquire();
        assert_eq!(recycled.len, 0);
    }

    #[test]
    fn allocation_counter_freezes_after_warmup_while_acquires_climb() {
        let pool = BufferPool::new(2, 64);
        assert_eq!(pool.allocations(), 0, "lazy pool: unused costs nothing");
        // warm-up: first checkouts create up to the cap
        let a = pool.acquire();
        let b = pool.acquire();
        assert_eq!(pool.allocations(), 2);
        pool.release(a);
        pool.release(b);
        // steady state: recycle only
        for _ in 0..10 {
            let a = pool.acquire();
            let b = pool.acquire();
            pool.release(a);
            pool.release(b);
        }
        assert_eq!(pool.allocations(), 2, "pool must never allocate past its cap");
        assert_eq!(pool.acquires(), 22);
    }

    #[test]
    fn registration_slots_are_stable_identities() {
        let pool = BufferPool::new(3, 256);
        let table = pool.registration_slots();
        assert_eq!(table.len(), 3);
        assert_eq!(pool.allocations(), 3);
        // every checked-out buffer carries the slot matching its base
        // address in the frozen table, across recycling
        for _ in 0..3 {
            let a = pool.acquire();
            let b = pool.acquire();
            for buf in [&a, &b] {
                let slot = buf.slot().expect("pooled buffers carry a slot") as usize;
                assert_eq!(table[slot], (buf.base_addr(), buf.capacity()));
            }
            pool.release(a);
            pool.release(b);
        }
        // re-querying does not grow the table
        assert_eq!(pool.registration_slots(), table);
        // standalone buffers have no slot (plain-write path)
        assert_eq!(AlignedBuf::new(64, 512).slot(), None);
    }

    #[test]
    fn pool_cross_thread() {
        let pool = BufferPool::new(2, 1024);
        let p2 = pool.clone();
        let h = std::thread::spawn(move || {
            let mut b = p2.acquire();
            b.stage(&[1; 100]);
            p2.release(b);
        });
        h.join().unwrap();
        assert!(pool.try_acquire().is_some());
    }
}
