//! Deterministic fault injection over the write pipeline's op schedule.
//!
//! FastPersist's durability story rests on one invariant: **recovery
//! always lands on the last durable generation, never a partial one**.
//! The commit protocol that upholds it — segment/partition bytes first,
//! fsync, manifest published last via atomic rename — is exercised here
//! by a seedable, deterministic fault layer threaded through the one
//! write executor ([`crate::io::write::WritePipeline`]) and the
//! manifest publish points.
//!
//! A [`FaultPlan`] is installed per-runtime via
//! [`crate::io::engine::IoConfig::fault`] (default `None`; every hot
//! path guards the hook behind a single `Option` check, so a disabled
//! plan costs one predictable branch). The executor consults the plan
//! at every boundary of the realized op schedule:
//!
//! ```text
//! Stage(k) ─► Drain(k) ─► … ─► Fsync ─► Publish (manifest rename)
//!    │            │              │          │
//!    │            │              │          └─ FaultSite::Publish
//!    │            │              └─ FaultSite::Fsync
//!    │            └─ FaultSite::Drain   (+ FaultSite::GcCopy on the
//!    └─ FaultSite::Stage                 segment-GC sparse rewrite)
//! ```
//!
//! Boundaries of each site class are counted in execution order; a plan
//! armed with [`FaultPlan::fire_at`] fires at exactly the *n*-th
//! boundary of its class, with one of four [`FaultKind`]s:
//!
//! * **Abort** — simulated process death: the boundary fails with
//!   [`crate::Error::FaultTripped`] and the plan latches *halted*, so
//!   every subsequent I/O boundary of the runtime fails too (a dead
//!   process issues no more writes).
//! * **TornWrite** — the drain writes only an aligned prefix of its
//!   extent before the "process dies" (halts like Abort): the bytes of
//!   a positioned write that was in flight at the moment of death.
//! * **ShortFsync** — the fsync is silently skipped; later ops proceed
//!   (a lying device / an elided flush). Non-halting.
//! * **StaleManifest** — the manifest publish rename is suppressed but
//!   reported as success, leaving the temp file and whatever manifest
//!   was previously in place; later ops proceed. Non-halting — the
//!   writer keeps going believing it published.
//!
//! [`FaultPlan::observe`] builds a disarmed plan that only counts
//! boundaries — the probe pass the scenario matrix
//! (`rust/tests/fault_matrix.rs`) runs first to enumerate every
//! boundary of a plan shape before re-running it with a fault armed at
//! each one.
//!
//! **Batch-entry granularity.** The batched submission backend
//! (`--io-backend ring`) queues several drain extents per kernel
//! submission, but fault boundaries are consulted **per batch entry at
//! enqueue time**, in the same execution order the sync backend drains
//! them, so a scenario matrix enumerated against one backend addresses
//! the identical Drain/Fsync boundaries on the other. Two ordering
//! rules keep the semantics exact: a Torn/Abort drain fault first
//! flushes every *previously queued* entry of the pending batch (those
//! writes were issued before the "death"), and a fault-instrumented
//! sink never chains its fsync into the ring — the Fsync boundary stays
//! a distinct op exactly where the sync path has it.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::{Error, Result};

/// What an armed [`FaultPlan`] does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Simulated process death at the boundary: the op fails with
    /// [`Error::FaultTripped`] and the runtime halts all subsequent I/O.
    Abort,
    /// The drain writes only an aligned prefix of its extent, then the
    /// process "dies" (halts like [`FaultKind::Abort`]). Only
    /// meaningful at [`FaultSite::Drain`] / [`FaultSite::GcCopy`].
    TornWrite,
    /// The fsync is skipped; the op reports success and later ops
    /// proceed. Only meaningful at [`FaultSite::Fsync`].
    ShortFsync,
    /// The manifest publish rename is suppressed but reported as
    /// success (temp file left behind, any previous manifest stays in
    /// place). Only meaningful at [`FaultSite::Publish`].
    StaleManifest,
}

impl FaultKind {
    /// Stable report name.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Abort => "abort",
            FaultKind::TornWrite => "torn-write",
            FaultKind::ShortFsync => "short-fsync",
            FaultKind::StaleManifest => "stale-manifest",
        }
    }
}

/// The class of op boundary a [`FaultPlan`] addresses. Boundaries of
/// each class are numbered 0, 1, 2, … in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultSite {
    /// A [`crate::io::write::WriteOp::Stage`] boundary: a staging
    /// buffer is about to be filled (streamed plans count their first
    /// write here).
    Stage,
    /// A [`crate::io::write::WriteOp::Drain`] boundary: a staged extent
    /// is about to be submitted to its drain lane (streamed plans count
    /// their final flush here).
    Drain,
    /// A [`crate::io::write::WriteOp::Fsync`] boundary: the file is
    /// about to be made durable.
    Fsync,
    /// A manifest publish point: the atomic rename that commits a
    /// checkpoint ([`crate::checkpoint::manifest::CheckpointManifest::save_with`]).
    Publish,
    /// One copy run of the segment-GC sparse rewrite
    /// ([`crate::checkpoint::delta::prune_chain_injected`]).
    GcCopy,
}

impl FaultSite {
    /// Every addressable site class, in declaration order.
    pub const ALL: [FaultSite; 5] = [
        FaultSite::Stage,
        FaultSite::Drain,
        FaultSite::Fsync,
        FaultSite::Publish,
        FaultSite::GcCopy,
    ];

    fn index(self) -> usize {
        match self {
            FaultSite::Stage => 0,
            FaultSite::Drain => 1,
            FaultSite::Fsync => 2,
            FaultSite::Publish => 3,
            FaultSite::GcCopy => 4,
        }
    }

    /// Stable report name.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Stage => "stage",
            FaultSite::Drain => "drain",
            FaultSite::Fsync => "fsync",
            FaultSite::Publish => "publish",
            FaultSite::GcCopy => "gc-copy",
        }
    }
}

/// What the caller of a drain-site check must do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainDecision {
    /// No fault here: perform the full positioned write.
    Full,
    /// Torn write: write only an aligned prefix of the extent, then
    /// fail the op with [`FaultPlan::error`] — the plan is already
    /// halted.
    Torn,
}

/// What the caller of a fsync-site check must do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncDecision {
    /// Make the file durable as planned.
    Sync,
    /// Skip the fsync, report success (short fsync fired).
    Skip,
}

/// What the caller of a publish-site check must do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PublishDecision {
    /// Rename the temp manifest into place as planned.
    Publish,
    /// Suppress the rename but report success (stale manifest fired).
    Suppress,
}

/// Shared trip state: every clone of a [`FaultPlan`] (the runtime's
/// engines each hold a cloned [`crate::io::engine::IoConfig`]) sees the
/// same counters and halt latch.
#[derive(Debug, Default)]
struct FaultState {
    /// Armed trigger: `(kind, site, nth)`; `None` observes only.
    trigger: Option<(FaultKind, FaultSite, u64)>,
    /// Boundaries crossed so far, per site class.
    crossed: [AtomicU64; 5],
    /// Simulated process death: all subsequent boundaries fail.
    halted: AtomicBool,
    /// The armed trigger fired at least once.
    tripped: AtomicBool,
    /// Fsyncs skipped by [`FaultKind::ShortFsync`].
    skipped_fsyncs: AtomicU64,
    /// Publishes suppressed by [`FaultKind::StaleManifest`].
    suppressed_publishes: AtomicU64,
}

/// A deterministic fault-injection plan, installed per-runtime through
/// [`crate::io::engine::IoConfig::fault`]. Cloning shares state — keep
/// a handle to the plan you installed to inspect
/// [`FaultPlan::boundaries`] / [`FaultPlan::tripped`] afterwards, and
/// to [`FaultPlan::heal`] the runtime for the recovery phase of a
/// drill.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    state: Arc<FaultState>,
}

impl FaultPlan {
    /// A disarmed plan that never fires — it only counts the boundaries
    /// each site class crosses, for enumerating a scenario's schedule
    /// before arming faults at each index.
    pub fn observe() -> FaultPlan {
        FaultPlan::default()
    }

    /// A plan firing `kind` at the `nth` (0-based, execution order)
    /// boundary of `site`. Kinds are site-specific:
    /// [`FaultKind::Abort`] fires anywhere, [`FaultKind::TornWrite`] at
    /// [`FaultSite::Drain`]/[`FaultSite::GcCopy`],
    /// [`FaultKind::ShortFsync`] at [`FaultSite::Fsync`], and
    /// [`FaultKind::StaleManifest`] at [`FaultSite::Publish`]; a
    /// mismatched pair can never fire.
    pub fn fire_at(kind: FaultKind, site: FaultSite, nth: u64) -> FaultPlan {
        debug_assert!(
            match kind {
                FaultKind::Abort => true,
                FaultKind::TornWrite => matches!(site, FaultSite::Drain | FaultSite::GcCopy),
                FaultKind::ShortFsync => site == FaultSite::Fsync,
                FaultKind::StaleManifest => site == FaultSite::Publish,
            },
            "fault kind {kind:?} cannot fire at site {site:?}"
        );
        FaultPlan {
            state: Arc::new(FaultState {
                trigger: Some((kind, site, nth)),
                ..FaultState::default()
            }),
        }
    }

    /// A plan firing `kind` at a pseudo-random boundary of `site`,
    /// derived deterministically from `seed` (same seed, same trigger):
    /// the seeded entry point of the extended fault sweep. `limit` is
    /// an exclusive upper bound on the chosen index (pass the boundary
    /// count of an [`FaultPlan::observe`] pass).
    pub fn seeded(seed: u64, kind: FaultKind, site: FaultSite, limit: u64) -> FaultPlan {
        // splitmix64: cheap, deterministic, good avalanche for a seed.
        let mut z = seed.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        FaultPlan::fire_at(kind, site, z % limit.max(1))
    }

    /// How many boundaries of `site` have been crossed so far.
    pub fn boundaries(&self, site: FaultSite) -> u64 {
        self.state.crossed[site.index()].load(Ordering::Relaxed)
    }

    /// Whether the armed trigger fired.
    pub fn tripped(&self) -> bool {
        self.state.tripped.load(Ordering::Relaxed)
    }

    /// Whether the simulated process death latched: every subsequent
    /// I/O boundary on this runtime fails with [`Error::FaultTripped`].
    pub fn halted(&self) -> bool {
        self.state.halted.load(Ordering::Relaxed)
    }

    /// Fsyncs skipped by a fired [`FaultKind::ShortFsync`].
    pub fn skipped_fsyncs(&self) -> u64 {
        self.state.skipped_fsyncs.load(Ordering::Relaxed)
    }

    /// Publishes suppressed by a fired [`FaultKind::StaleManifest`].
    pub fn suppressed_publishes(&self) -> u64 {
        self.state.suppressed_publishes.load(Ordering::Relaxed)
    }

    /// Clear the halt latch and disarm the trigger — the "process
    /// restart" of a drill: the same runtime serves the recovery phase
    /// without rebuilding its pools. Boundary counters keep counting.
    pub fn heal(&self) {
        self.state.halted.store(false, Ordering::SeqCst);
        self.state.tripped.store(true, Ordering::Relaxed); // disarm below
        // A healed plan must never fire again: firing is gated on
        // tripped() being false for halting kinds and on the exact
        // boundary index for the rest — marking it tripped disarms every
        // kind because fire() checks the latch first.
    }

    /// The typed error a tripped/halted boundary surfaces.
    pub fn error(&self, site: FaultSite) -> Error {
        Error::FaultTripped(format!("injected fault at {} boundary", site.name()))
    }

    /// Cross one boundary of `site`: count it, fail if the runtime is
    /// halted, and fire the armed trigger when this is its boundary.
    /// Returns the kind that fired here, if any.
    #[inline]
    fn cross(&self, site: FaultSite) -> Result<Option<FaultKind>> {
        let s = &*self.state;
        if s.halted.load(Ordering::SeqCst) {
            return Err(self.error(site));
        }
        let idx = s.crossed[site.index()].fetch_add(1, Ordering::SeqCst);
        match s.trigger {
            Some((kind, t_site, nth))
                if t_site == site && idx == nth && !s.tripped.swap(true, Ordering::SeqCst) =>
            {
                match kind {
                    FaultKind::Abort => {
                        s.halted.store(true, Ordering::SeqCst);
                        Err(self.error(site))
                    }
                    FaultKind::TornWrite => {
                        s.halted.store(true, Ordering::SeqCst);
                        Ok(Some(kind))
                    }
                    FaultKind::ShortFsync => {
                        s.skipped_fsyncs.fetch_add(1, Ordering::Relaxed);
                        Ok(Some(kind))
                    }
                    FaultKind::StaleManifest => {
                        s.suppressed_publishes.fetch_add(1, Ordering::Relaxed);
                        Ok(Some(kind))
                    }
                }
            }
            _ => Ok(None),
        }
    }

    /// A [`FaultSite::Stage`] boundary (buffer about to be filled).
    pub fn on_stage(&self) -> Result<()> {
        self.cross(FaultSite::Stage).map(|_| ())
    }

    /// A [`FaultSite::Drain`] boundary (extent about to be submitted).
    pub fn on_drain(&self) -> Result<DrainDecision> {
        match self.cross(FaultSite::Drain)? {
            Some(FaultKind::TornWrite) => Ok(DrainDecision::Torn),
            _ => Ok(DrainDecision::Full),
        }
    }

    /// A [`FaultSite::Fsync`] boundary (file about to be made durable).
    pub fn on_fsync(&self) -> Result<FsyncDecision> {
        match self.cross(FaultSite::Fsync)? {
            Some(FaultKind::ShortFsync) => Ok(FsyncDecision::Skip),
            _ => Ok(FsyncDecision::Sync),
        }
    }

    /// A [`FaultSite::Publish`] boundary (manifest about to rename into
    /// place).
    pub fn on_publish(&self) -> Result<PublishDecision> {
        match self.cross(FaultSite::Publish)? {
            Some(FaultKind::StaleManifest) => Ok(PublishDecision::Suppress),
            _ => Ok(PublishDecision::Publish),
        }
    }

    /// A [`FaultSite::GcCopy`] boundary (one copy run of a sparse
    /// segment rewrite). Torn here behaves like abort for the caller —
    /// the rewrite stops mid-copy either way; the distinction is that a
    /// torn run first copies a prefix, which the caller performs before
    /// consulting the next boundary.
    pub fn on_gc_copy(&self) -> Result<DrainDecision> {
        match self.cross(FaultSite::GcCopy)? {
            Some(FaultKind::TornWrite) => Ok(DrainDecision::Torn),
            _ => Ok(DrainDecision::Full),
        }
    }

    /// Fail fast when the runtime is halted (job-entry check: a dead
    /// process submits nothing, so a halted runtime must not create or
    /// truncate any file).
    pub fn check_alive(&self, site: FaultSite) -> Result<()> {
        if self.halted() {
            return Err(self.error(site));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_counts_without_firing() {
        let f = FaultPlan::observe();
        for _ in 0..3 {
            f.on_stage().unwrap();
        }
        assert_eq!(f.on_drain().unwrap(), DrainDecision::Full);
        assert_eq!(f.on_fsync().unwrap(), FsyncDecision::Sync);
        assert_eq!(f.on_publish().unwrap(), PublishDecision::Publish);
        assert_eq!(f.boundaries(FaultSite::Stage), 3);
        assert_eq!(f.boundaries(FaultSite::Drain), 1);
        assert_eq!(f.boundaries(FaultSite::Fsync), 1);
        assert_eq!(f.boundaries(FaultSite::Publish), 1);
        assert!(!f.tripped() && !f.halted());
    }

    #[test]
    fn abort_halts_every_subsequent_boundary() {
        let f = FaultPlan::fire_at(FaultKind::Abort, FaultSite::Stage, 1);
        f.on_stage().unwrap();
        let err = f.on_stage().unwrap_err();
        assert!(matches!(err, Error::FaultTripped(_)), "got {err}");
        assert!(f.tripped() && f.halted());
        assert!(f.on_drain().is_err());
        assert!(f.on_fsync().is_err());
        assert!(f.on_publish().is_err());
        assert!(f.check_alive(FaultSite::Stage).is_err());
        // clones share the trip state
        let clone = f.clone();
        assert!(clone.halted());
        // heal: the runtime serves recovery, the trigger never re-fires
        f.heal();
        assert!(!f.halted());
        f.on_stage().unwrap();
        f.on_stage().unwrap();
    }

    #[test]
    fn torn_and_short_and_stale_decisions() {
        let torn = FaultPlan::fire_at(FaultKind::TornWrite, FaultSite::Drain, 0);
        assert_eq!(torn.on_drain().unwrap(), DrainDecision::Torn);
        assert!(torn.halted(), "torn write simulates death mid-write");

        let short = FaultPlan::fire_at(FaultKind::ShortFsync, FaultSite::Fsync, 1);
        assert_eq!(short.on_fsync().unwrap(), FsyncDecision::Sync);
        assert_eq!(short.on_fsync().unwrap(), FsyncDecision::Skip);
        assert_eq!(short.on_fsync().unwrap(), FsyncDecision::Sync, "fires once");
        assert!(!short.halted(), "short fsync lets later ops proceed");
        assert_eq!(short.skipped_fsyncs(), 1);

        let stale = FaultPlan::fire_at(FaultKind::StaleManifest, FaultSite::Publish, 0);
        assert_eq!(stale.on_publish().unwrap(), PublishDecision::Suppress);
        assert_eq!(stale.on_publish().unwrap(), PublishDecision::Publish);
        assert!(!stale.halted());
        assert_eq!(stale.suppressed_publishes(), 1);
    }

    #[test]
    fn seeded_trigger_is_deterministic_and_in_range() {
        for seed in [0u64, 1, 7, 0xdead_beef] {
            let a = FaultPlan::seeded(seed, FaultKind::Abort, FaultSite::Drain, 13);
            let b = FaultPlan::seeded(seed, FaultKind::Abort, FaultSite::Drain, 13);
            assert_eq!(a.state.trigger, b.state.trigger, "seed {seed}");
            let (_, _, nth) = a.state.trigger.unwrap();
            assert!(nth < 13);
        }
    }
}
