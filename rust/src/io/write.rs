//! The unified, plan-based write pipeline (paper §4.1) — ONE executor
//! for every engine kind.
//!
//! PR 4 unified the restore path: reads are *planned* (coalesced runs)
//! and *executed* by the runtime's reader pool. This module is the
//! write-side mirror. A checkpoint write is described by a
//! [`WritePlan`] — an explicit op schedule of [`WriteOp::Stage`] /
//! [`WriteOp::Drain`] / [`WriteOp::Fsync`] steps over aligned
//! [`WriteExtent`]s — and realized by one shared executor
//! ([`WritePipeline`]) against the runtime's staging pool and
//! **per-device submission queues** ([`DrainPool`]). The former three
//! write engines survive only as *planning policies*:
//!
//! * **buffered** (torch.save baseline): one streamed extent covering
//!   the whole file, executed as small copying writes
//!   ([`crate::io::sync_engine`]);
//! * **direct-single** (Fig. 5a): chunk-sized extents, stage→drain
//!   serial — queue depth 1 ([`crate::io::direct_engine`] over
//!   [`crate::io::double_buffer`]);
//! * **direct-double** (Fig. 5b): the same extents with drains
//!   overlapping stages — queue depth ≥ 2
//!   ([`crate::io::double_buffer`]).
//!
//! There is no per-engine drain loop anywhere: every kind flows through
//! [`WritePipeline::open`], which returns the one staged (or streamed)
//! sink implementation.
//!
//! **Real O_DIRECT, end to end.** The staged executor opens its data
//! descriptor with `O_DIRECT` whenever the destination device's probe
//! says the filesystem accepts it ([`DeviceMap::direct_capability_for`]
//! — probed once per device, cached, logged fallback otherwise). Every
//! drain is then a fully aligned positioned write **directly from a
//! pool staging buffer** (aligned base address, aligned offset, aligned
//! length), and the sub-alignment tail of the stream goes through a
//! **zeroed bounce buffer** on a second traditional descriptor — the
//! unaligned bytes never touch the direct fd. [`WriteStats`] accounts
//! the split (`direct_bytes`, `bounce_bytes`, `queue_depth_max`), so
//! benches and tests can prove the direct path is actually taken.
//!
//! **Submission backends.** *How* a lane worker hands a drained extent
//! to the kernel is a [`SubmitBackend`] — an abstraction UNDER the lane
//! API, invisible to plans, engines and on-disk formats. [`SyncBackend`]
//! is the classic loop (one positioned `pwrite` per extent, everywhere).
//! The Linux-gated ring backend ([`crate::io::uring`], behind the
//! `io-uring` cargo feature) queues up to [`WritePlan::queue_depth`]
//! extents per lane into a submission ring, issues ONE submission
//! syscall per batch (fixed-buffer writes from the pre-registered
//! staging pool), reaps completions off the ring, and chains the
//! trailing fsync as a drain-linked flush op. [`IoConfig::backend`]
//! picks sync/ring/auto; `auto` resolves through a cached per-filesystem
//! probe ([`DeviceMap::ring_capability_for`]) with a logged fallback, so
//! tmpfs/9p CI deliberately keeps running the sync path.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::os::unix::fs::{FileExt, OpenOptionsExt};
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::io::align::{align_down, align_up};
use crate::io::buffer::{AlignedBuf, BufferPool};
use crate::io::device::{DeviceMap, O_DIRECT};
use crate::io::engine::{EngineKind, IoBackend, IoConfig, Sink, WriteStats};
use crate::io::fault::{DrainDecision, FaultPlan, FaultSite, FsyncDecision};
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// One planned extent of the output file: stream bytes
/// `[offset, offset + len)` land at the same file offsets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WriteExtent {
    /// File (and stream) offset the extent starts at.
    pub offset: u64,
    /// Extent length in bytes.
    pub len: u64,
}

impl WriteExtent {
    /// One past the last byte of the extent.
    pub fn end(&self) -> u64 {
        self.offset + self.len
    }
}

/// One step of a write plan's op schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteOp {
    /// Copy stream bytes of extent `i` into a staging buffer (the
    /// accelerator→pinned-DRAM hop).
    Stage(usize),
    /// Submit extent `i`'s staged buffer to the destination device's
    /// submission queue (a positioned write; the DRAM→SSD hop).
    Drain(usize),
    /// Make the file durable (fdatasync) once every drain completed.
    Fsync,
}

/// A planned checkpoint-file write: the op schedule the unified
/// executor realizes. Policies ([`crate::io::sync_engine`],
/// [`crate::io::direct_engine`], [`crate::io::double_buffer`]) differ
/// **only** in how they construct this plan.
#[derive(Debug, Clone)]
pub struct WritePlan {
    /// Engine kind the plan was derived from (reporting only).
    pub kind: EngineKind,
    /// Planned extents tiling `[0, total)` when the stream length is
    /// known up front; empty for an open-ended sink, which synthesizes
    /// `chunk`-sized extents as bytes arrive.
    pub extents: Vec<WriteExtent>,
    /// Staged bytes per extent — an alignment multiple, right-sized to
    /// the stream so small checkpoints drain promptly.
    pub chunk: usize,
    /// Maximum drains in flight: 1 serializes stage/drain (Fig. 5a),
    /// ≥ 2 overlaps the drain of extent *k* with the stage of *k+1*
    /// (Fig. 5b).
    pub queue_depth: usize,
    /// Buffered baseline: execute as small streamed copies instead of
    /// staged aligned drains.
    pub streamed: bool,
    /// fdatasync on finish (the plan's trailing [`WriteOp::Fsync`]).
    pub sync: bool,
}

/// Tile `[0, total)` into `chunk`-sized extents: every extent except
/// the last has exactly `chunk` bytes (an alignment multiple), and only
/// the final extent may be shorter or end unaligned.
pub fn plan_extents(total: u64, chunk: usize) -> Vec<WriteExtent> {
    assert!(chunk > 0, "chunk must be positive");
    let mut extents = Vec::with_capacity((total / chunk as u64) as usize + 1);
    let mut offset = 0u64;
    while offset < total {
        let len = (chunk as u64).min(total - offset);
        extents.push(WriteExtent { offset, len });
        offset += len;
    }
    extents
}

fn schedule_ops(n_extents: usize, sync: bool) -> Vec<WriteOp> {
    let mut ops = Vec::with_capacity(n_extents * 2 + 1);
    for i in 0..n_extents {
        ops.push(WriteOp::Stage(i));
        ops.push(WriteOp::Drain(i));
    }
    if sync {
        ops.push(WriteOp::Fsync);
    }
    ops
}

impl WritePlan {
    /// A staged plan (the direct kinds): `chunk`-sized aligned extents
    /// drained through the device submission queue at `queue_depth`.
    /// `total` (when known) right-sizes the chunk so a small checkpoint
    /// drains after its last byte instead of after a 32 MB high-water
    /// mark.
    pub fn staged(cfg: &IoConfig, total: Option<u64>, queue_depth: usize) -> WritePlan {
        let align = cfg.align.max(1) as u64;
        let chunk = match total {
            Some(t) => cfg.io_buf_size.min(align_up(t, align).max(align) as usize),
            None => cfg.io_buf_size,
        };
        let chunk = (align_down(chunk as u64, align) as usize).max(align as usize);
        let extents = total.map(|t| plan_extents(t, chunk)).unwrap_or_default();
        WritePlan {
            kind: cfg.kind,
            extents,
            chunk,
            queue_depth: queue_depth.max(1),
            streamed: false,
            sync: cfg.sync_on_finish,
        }
    }

    /// The buffered-baseline plan: one streamed extent covering the
    /// whole file, written as `buffered_chunk`-sized copies.
    pub fn streamed(cfg: &IoConfig, total: Option<u64>) -> WritePlan {
        let extents = match total {
            Some(t) if t > 0 => vec![WriteExtent { offset: 0, len: t }],
            _ => Vec::new(),
        };
        WritePlan {
            kind: cfg.kind,
            extents,
            chunk: cfg.buffered_chunk.max(1),
            queue_depth: 1,
            streamed: true,
            sync: cfg.sync_on_finish,
        }
    }

    /// The op schedule over the planned extents (Stage/Drain
    /// interleaved in stream order, then Fsync when durable) — derived
    /// on demand so submissions don't allocate it. The executor
    /// realizes exactly this schedule streamingly: bytes arriving at
    /// the sink fill the current extent's staging buffer (its Stage
    /// op), a full extent submits to its drain lane (its Drain op), and
    /// each realized drain is checked against the schedule's extent
    /// offsets ([`WritePlan::validate`] proves the schedule itself
    /// well-formed).
    pub fn ops(&self) -> Vec<WriteOp> {
        schedule_ops(self.extents.len(), self.sync)
    }

    /// Total bytes the planned extents cover.
    pub fn planned_bytes(&self) -> u64 {
        self.extents.iter().map(|e| e.len).sum()
    }

    /// Validate the plan's structural invariants (used by the
    /// property tests): extents cover `[0, planned_bytes)` exactly once
    /// in order, every extent boundary except the final end is
    /// `align`-aligned, and the op schedule stages each extent exactly
    /// once before draining it.
    pub fn validate(&self, align: u64) -> Result<()> {
        let mut expect = 0u64;
        for (i, e) in self.extents.iter().enumerate() {
            if e.offset != expect {
                return Err(Error::Internal(format!(
                    "extent {i} starts at {} expected {expect} (gap or overlap)",
                    e.offset
                )));
            }
            if e.len == 0 {
                return Err(Error::Internal(format!("extent {i} is empty")));
            }
            if !self.streamed && e.offset % align != 0 {
                return Err(Error::Internal(format!("extent {i} offset unaligned")));
            }
            if !self.streamed && i + 1 < self.extents.len() && e.len % align != 0 {
                return Err(Error::Internal(format!("interior extent {i} length unaligned")));
            }
            expect = e.end();
        }
        let ops = self.ops();
        let mut staged = vec![false; self.extents.len()];
        for op in &ops {
            match *op {
                WriteOp::Stage(i) => {
                    if i >= staged.len() || staged[i] {
                        return Err(Error::Internal(format!("extent {i} staged twice")));
                    }
                    staged[i] = true;
                }
                WriteOp::Drain(i) => {
                    if i >= staged.len() || !staged[i] {
                        return Err(Error::Internal(format!("extent {i} drained before staged")));
                    }
                }
                WriteOp::Fsync => {}
            }
        }
        if staged.iter().any(|s| !s) {
            return Err(Error::Internal("plan leaves an extent unstaged".into()));
        }
        if self.sync != ops.last().map(|op| *op == WriteOp::Fsync).unwrap_or(false)
            && !self.extents.is_empty()
        {
            return Err(Error::Internal("durable plan must end with Fsync".into()));
        }
        Ok(())
    }
}

/// Counters from the drain path of one sink.
#[derive(Debug, Default, Clone, Copy)]
pub struct DrainStats {
    /// Bytes drained to storage.
    pub bytes: u64,
    /// Positioned write ops issued.
    pub ops: u64,
    /// Cumulative wall time the drain workers spent inside this sink's
    /// positioned writes.
    pub busy: Duration,
}

/// Batched-submission accounting for one backend batch, carried on the
/// batch's final [`DrainDone`] so the sink can fold it into
/// [`WriteStats`].
#[derive(Debug, Default, Clone, Copy)]
pub struct BatchStats {
    /// Submission syscalls the backend issued for this batch (1 on the
    /// ring path — the batching proof; 0 on the sync path).
    pub submissions: u64,
    /// Submission-queue entries handed to the kernel in one syscall
    /// (batch writes + a chained fsync op when one was linked).
    pub sqes: u64,
    /// Completions reaped off the ring for this batch.
    pub completions: u64,
    /// The chained trailing-fsync op completed successfully — the sink
    /// skips its own fdatasync.
    pub fsync_done: bool,
}

/// Completion record of one drain job, reported on the submitting
/// sink's channel.
#[derive(Debug, Clone, Copy)]
pub struct DrainDone {
    /// Bytes written by the positioned write.
    pub bytes: u64,
    /// Wall time of the positioned write on the lane worker.
    pub busy: Duration,
    /// Batch accounting, present only on the final completion of a
    /// backend batch (`None` for classic per-extent drains).
    pub batch: Option<BatchStats>,
}

/// One staged-extent drain: a positioned write of `buf[..len]` at
/// `offset` of `file`, submitted to a [`DrainPool`] lane.
pub struct DrainJob {
    /// Destination descriptor (O_DIRECT when the pipeline engaged it).
    pub file: Arc<File>,
    /// Staged buffer holding the extent bytes (returned to the staging
    /// pool by the drain worker).
    pub buf: AlignedBuf,
    /// File offset the extent lands at.
    pub offset: u64,
    /// Bytes of `buf` to write.
    pub len: usize,
}

/// One entry of a batched drain submission: the staged extent bytes in
/// `buf[..len]` land at file offset `offset`. Ownership of the buffer
/// travels with the batch; the lane worker recycles it to the staging
/// pool once the backend reports the entry's outcome.
pub struct BatchEntry {
    /// Staged buffer holding the extent bytes.
    pub buf: AlignedBuf,
    /// File offset the extent lands at.
    pub offset: u64,
    /// Bytes of `buf` to write.
    pub len: usize,
}

/// What a backend reports back for one submitted batch.
pub struct BatchReport {
    /// Per-entry write results, parallel to the submitted entries.
    pub results: Vec<std::io::Result<()>>,
    /// Batch-level submission accounting.
    pub stats: BatchStats,
    /// Error of the chained trailing fsync, when one was requested and
    /// failed (the batch's final completion turns into this error).
    pub fsync_err: Option<std::io::Error>,
}

/// How a lane worker hands a batch of drained extents to the kernel —
/// the seam UNDER the lane API that the sync and ring submission paths
/// plug into. Plans, engines, fault boundaries and on-disk bytes are
/// identical across implementations; only the syscall shape differs.
pub trait SubmitBackend: Send + Sync {
    /// Stable report name ("sync" / "ring").
    fn name(&self) -> &'static str;

    /// Write every entry of `entries` to `file` at its offset. With
    /// `link_fsync`, additionally make the file durable after the last
    /// entry completes (the ring backend chains a drain-linked fsync op
    /// into the same submission; the sync backend issues an fdatasync
    /// after its writes). Must report one result per entry.
    fn submit_batch(&self, file: &File, entries: &[BatchEntry], link_fsync: bool) -> BatchReport;
}

/// The classic per-extent backend: one positioned `pwrite` syscall per
/// entry, on any platform and filesystem. The deliberate CI path on
/// tmpfs/9p, and the fallback every other backend resolves to.
pub struct SyncBackend;

impl SubmitBackend for SyncBackend {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn submit_batch(&self, file: &File, entries: &[BatchEntry], link_fsync: bool) -> BatchReport {
        let mut results = Vec::with_capacity(entries.len());
        for e in entries {
            results.push(file.write_all_at(&e.buf.filled()[..e.len], e.offset));
        }
        let fsync_err = if link_fsync { file.sync_data().err() } else { None };
        BatchReport {
            results,
            stats: BatchStats {
                fsync_done: link_fsync && fsync_err.is_none(),
                ..BatchStats::default()
            },
            fsync_err,
        }
    }
}

/// Resolve the configured submission backend into a shared ring
/// backend, or `None` when drains should take the per-extent sync path.
/// Called once per resource set ([`WriteResources`]): building the ring
/// backend snapshots and pins the staging pool's registration table
/// ([`BufferPool::registration_slots`]), so every later fixed-buffer
/// write has zero per-op pin cost. An explicit `ring` request that
/// cannot be honored logs its reason; `auto` falls back quietly at this
/// layer (the per-filesystem probe logs when it rejects a mount).
pub fn resolve_ring_backend(
    cfg: &IoConfig,
    pool: &BufferPool,
) -> Option<Arc<dyn SubmitBackend>> {
    if cfg.backend == IoBackend::Sync {
        return None;
    }
    #[cfg(all(target_os = "linux", feature = "io-uring"))]
    {
        match crate::io::uring::RingBackend::create(cfg, pool) {
            Ok(ring) => Some(Arc::new(ring) as Arc<dyn SubmitBackend>),
            Err(reason) => {
                if cfg.backend == IoBackend::Ring {
                    eprintln!(
                        "fastpersist: io backend 'ring' unavailable ({reason}); \
                         using per-extent sync submission"
                    );
                }
                None
            }
        }
    }
    #[cfg(not(all(target_os = "linux", feature = "io-uring")))]
    {
        let _ = pool;
        if cfg.backend == IoBackend::Ring {
            eprintln!(
                "fastpersist: io backend 'ring' requires linux and the io-uring \
                 cargo feature; using per-extent sync submission"
            );
        }
        None
    }
}

/// Per-device submission queues with persistent drain workers — the
/// executor's DRAM→SSD stage.
///
/// Each *lane* is one ordered queue serviced by one persistent worker;
/// the runtime creates at least one lane per configured device so every
/// SSD has its own submission stream (drain writes are positioned, so
/// any number of sinks share a lane without ordering coordination).
/// A drain job writes a staged buffer, returns it to its staging pool,
/// and reports the outcome on the submitting sink's completion channel;
/// workers never block on anything but the write syscall itself.
///
/// Worker threads spawn lazily on the first submission, so a pool that
/// only ever serves streamed (buffered-baseline) plans costs nothing.
#[derive(Clone)]
pub struct DrainPool {
    count: usize,
    lanes: Arc<std::sync::OnceLock<Vec<ThreadPool>>>,
    rr: Arc<AtomicUsize>,
    /// Dedicated cursor for unrouted drains, shared across every
    /// submitter. Unrouted rotation must not share `rr` with the
    /// device-group rotation: interleaved routed traffic advances a
    /// shared cursor between two unrouted picks, and a periodic
    /// interleaving (e.g. strictly alternating routed/unrouted
    /// submissions over an even lane count) makes the unrouted
    /// residues collapse onto a subset of lanes — or a single lane.
    rr_unrouted: Arc<AtomicUsize>,
    counters: Arc<Vec<LaneCounters>>,
}

/// Per-lane drain counters (shared across every clone of the pool).
#[derive(Default)]
struct LaneCounters {
    /// Drain jobs ever submitted to this lane.
    submissions: AtomicU64,
    /// Nanoseconds the lane worker spent inside positioned writes.
    busy_ns: AtomicU64,
    /// Jobs currently queued or executing on this lane.
    queued: AtomicU64,
    /// High-water mark of `queued`.
    queued_max: AtomicU64,
}

/// Point-in-time snapshot of one lane's counters
/// ([`DrainPool::lane_stats`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct LaneStats {
    /// Drain jobs submitted to this lane over its lifetime.
    pub submissions: u64,
    /// Cumulative wall time the lane worker spent inside positioned
    /// writes (its DRAM→SSD busy time).
    pub busy: Duration,
    /// High-water mark of jobs queued-or-executing on this lane.
    pub max_queued: u64,
}

impl DrainPool {
    /// A pool of `lanes` single-worker submission queues (workers
    /// spawned on first use).
    pub fn new(lanes: usize) -> DrainPool {
        let count = lanes.max(1);
        DrainPool {
            count,
            lanes: Arc::new(std::sync::OnceLock::new()),
            rr: Arc::new(AtomicUsize::new(0)),
            rr_unrouted: Arc::new(AtomicUsize::new(0)),
            counters: Arc::new((0..count).map(|_| LaneCounters::default()).collect()),
        }
    }

    /// Snapshot every lane's counters: submissions, cumulative
    /// write-busy time, and the queue-depth high-water mark.
    pub fn lane_stats(&self) -> Vec<LaneStats> {
        self.counters
            .iter()
            .map(|c| LaneStats {
                submissions: c.submissions.load(Ordering::Relaxed),
                busy: Duration::from_nanos(c.busy_ns.load(Ordering::Relaxed)),
                max_queued: c.queued_max.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Number of submission lanes (= persistent drain workers once
    /// spawned).
    pub fn lanes(&self) -> usize {
        self.count
    }

    fn workers(&self) -> &Vec<ThreadPool> {
        self.lanes.get_or_init(|| {
            (0..self.count).map(|i| ThreadPool::new(1, &format!("ckpt-drain{i}"))).collect()
        })
    }

    /// Lane for one drain to `device` (of `n_devices` configured).
    /// Each device owns the lane group `{d, d+n, d+2n, …}` and
    /// successive drains round-robin within their device's group — so
    /// when the runtime has more drain workers than devices, one busy
    /// device (or one deep-queue sink) still keeps several drains in
    /// flight, while distinct devices never contend for a lane.
    /// Unrouted drains (`None`, the degenerate map) round-robin over
    /// all lanes on their own atomic cursor, shared across submitters —
    /// concurrent routed traffic can never skew (or collapse) the
    /// unrouted rotation.
    pub fn lane_for(&self, device: Option<usize>, n_devices: usize) -> usize {
        let lanes = self.lanes();
        match device {
            Some(d) => {
                let n = n_devices.clamp(1, lanes);
                let d = d % n;
                // device d owns lanes {d, d+n, d+2n, …} below `lanes`,
                // so remainder lanes are distributed instead of idling
                let group = (lanes - d).div_ceil(n);
                d + n * (self.rr.fetch_add(1, Ordering::Relaxed) % group)
            }
            None => self.rr_unrouted.fetch_add(1, Ordering::Relaxed) % lanes,
        }
    }

    /// Submit one [`DrainJob`] on `lane`'s queue. The buffer is
    /// returned to `staging` and the result (bytes written + lane busy
    /// time) is sent on `done` regardless of success.
    pub fn submit(
        &self,
        lane: usize,
        job: DrainJob,
        staging: BufferPool,
        done: Sender<Result<DrainDone>>,
    ) {
        let lane = lane % self.count;
        let counters = Arc::clone(&self.counters);
        counters[lane].submissions.fetch_add(1, Ordering::Relaxed);
        let queued = counters[lane].queued.fetch_add(1, Ordering::Relaxed) + 1;
        counters[lane].queued_max.fetch_max(queued, Ordering::Relaxed);
        self.workers()[lane].execute(move || {
            let DrainJob { file, buf, offset, len } = job;
            let t0 = Instant::now();
            let written = file.write_all_at(&buf.filled()[..len], offset);
            let busy = t0.elapsed();
            counters[lane].busy_ns.fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
            counters[lane].queued.fetch_sub(1, Ordering::Relaxed);
            // Recycle before reporting so producers blocked in acquire()
            // wake even if the sink has stopped listening.
            staging.release(buf);
            let result = written
                .map(|()| DrainDone { bytes: len as u64, busy, batch: None })
                .map_err(Error::Io);
            let _ = done.send(result);
        });
    }

    /// Submit one backend batch on `lane`'s queue: the worker hands the
    /// whole batch to `backend` (ONE submission syscall on the ring
    /// path), recycles every staged buffer to `staging`, and reports one
    /// completion per entry on `done` — the batch's accounting rides on
    /// the final completion ([`DrainDone::batch`]). An empty `entries`
    /// with `link_fsync` submits a flush-only batch that reports exactly
    /// one zero-byte completion.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_batch(
        &self,
        lane: usize,
        file: Arc<File>,
        entries: Vec<BatchEntry>,
        link_fsync: bool,
        backend: Arc<dyn SubmitBackend>,
        staging: BufferPool,
        done: Sender<Result<DrainDone>>,
    ) {
        let lane = lane % self.count;
        let counters = Arc::clone(&self.counters);
        let units = entries.len().max(1) as u64;
        counters[lane].submissions.fetch_add(units, Ordering::Relaxed);
        let queued = counters[lane].queued.fetch_add(units, Ordering::Relaxed) + units;
        counters[lane].queued_max.fetch_max(queued, Ordering::Relaxed);
        self.workers()[lane].execute(move || {
            let t0 = Instant::now();
            let report = backend.submit_batch(&file, &entries, link_fsync);
            let busy = t0.elapsed();
            counters[lane].busy_ns.fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
            counters[lane].queued.fetch_sub(units, Ordering::Relaxed);
            let BatchReport { results, stats, mut fsync_err } = report;
            debug_assert_eq!(results.len(), entries.len(), "one result per batch entry");
            let n = entries.len();
            let mut results = results.into_iter();
            for (i, entry) in entries.into_iter().enumerate() {
                let len = entry.len;
                // Recycle before reporting so producers blocked in
                // acquire() wake even if the sink stopped listening.
                staging.release(entry.buf);
                let last = i + 1 == n;
                let wrote = results.next().unwrap_or_else(|| {
                    Err(std::io::Error::other("backend reported too few results"))
                });
                // A failed chained fsync surfaces on the batch's final
                // completion (unless that entry's write already failed).
                let result = match (wrote, if last { fsync_err.take() } else { None }) {
                    (Ok(()), None) => Ok(DrainDone {
                        bytes: len as u64,
                        busy: if last { busy } else { Duration::ZERO },
                        batch: last.then_some(stats),
                    }),
                    (Ok(()), Some(e)) | (Err(e), _) => Err(Error::Io(e)),
                };
                let _ = done.send(result);
            }
            if n == 0 {
                // Flush-only batch: one completion record carrying the
                // accounting (and the fsync error, if any).
                let result = match fsync_err.take() {
                    None => Ok(DrainDone { bytes: 0, busy, batch: Some(stats) }),
                    Some(e) => Err(Error::Io(e)),
                };
                let _ = done.send(result);
            }
        });
    }
}

/// The shared write-side resources a planning policy borrows: the
/// pinned staging pool, the per-device submission queues, and the
/// device map (routing + O_DIRECT capability cache). Runtime-owned in
/// production; [`WriteResources::standalone`] builds a private set for
/// one-off engines.
#[derive(Clone)]
pub struct WriteResources {
    /// Aligned staging buffers (allocate-once, recycle-forever).
    pub pool: BufferPool,
    /// Per-device submission queues.
    pub drain: DrainPool,
    /// Partition routing + per-device O_DIRECT capability.
    pub devices: DeviceMap,
    /// Resolved batched-submission backend, with the staging pool's
    /// buffers registered ([`resolve_ring_backend`]); `None` means
    /// every drain takes the per-extent [`SyncBackend`] path.
    pub ring: Option<Arc<dyn SubmitBackend>>,
}

impl WriteResources {
    /// Private engine-lifetime resources: `buffers` staging buffers of
    /// `cfg`'s geometry, one submission lane, the degenerate device
    /// map, and the submission backend `cfg.backend` resolves to.
    pub fn standalone(cfg: &IoConfig, buffers: usize) -> WriteResources {
        let cfg = cfg.clone().normalized();
        let pool = BufferPool::with_align(buffers.max(1), cfg.io_buf_size, cfg.align);
        let ring = resolve_ring_backend(&cfg, &pool);
        WriteResources { pool, drain: DrainPool::new(1), devices: DeviceMap::single(), ring }
    }
}

/// Pre-allocate `len` bytes of real blocks for `file`, so aligned
/// drains never extend the file mid-write: block allocation and the
/// inode size update happen once, up front, instead of on every
/// positioned write past EOF (which would serialize parallel drains on
/// the inode lock). Linux calls `fallocate(2)` directly via the glibc
/// wrapper (no libc crate — the same convention as the raw `O_DIRECT`
/// flag in [`crate::io::device`]); filesystems that refuse it
/// (EOPNOTSUPP on some tmpfs/FUSE/9p mounts) fall back to `set_len`,
/// which extends the inode size without reserving blocks. Non-Linux
/// platforms always use `set_len`.
#[cfg(target_os = "linux")]
fn preallocate(file: &File, len: u64) -> std::io::Result<()> {
    use std::os::unix::io::AsRawFd;
    if len == 0 {
        return Ok(()); // the descriptor was opened with truncate
    }
    extern "C" {
        fn fallocate(fd: i32, mode: i32, offset: i64, len: i64) -> i32;
    }
    // mode 0: reserve blocks for the range AND extend the file size to
    // cover it — exactly the "never extend mid-write" guarantee.
    let ret = unsafe { fallocate(file.as_raw_fd(), 0, 0, len as i64) };
    if ret == 0 {
        return Ok(());
    }
    file.set_len(len)
}

#[cfg(not(target_os = "linux"))]
fn preallocate(file: &File, len: u64) -> std::io::Result<()> {
    if len == 0 {
        return Ok(());
    }
    file.set_len(len)
}

/// The one write executor. [`WritePipeline::open`] realizes any
/// [`WritePlan`] as a [`Sink`]; no other code path writes checkpoint
/// bytes.
pub struct WritePipeline;

impl WritePipeline {
    /// Open a sink executing `plan` against `path`. `expected_size`
    /// (when known) pre-allocates the file so parallel aligned writes
    /// don't fight over metadata updates.
    pub fn open(
        cfg: &IoConfig,
        res: &WriteResources,
        plan: WritePlan,
        path: &Path,
        expected_size: Option<u64>,
    ) -> Result<Box<dyn Sink>> {
        if plan.streamed {
            StreamedSink::open(cfg, plan, path)
        } else {
            StagedSink::open(cfg, res, plan, path, expected_size)
        }
    }
}

/// Streamed executor: the torch.save-class baseline. One logical
/// extent, written through a std `BufWriter` in small chunks through a
/// serialization scratch — torch.save's pickle framing copies tensor
/// bytes into Python-level buffers before they reach the OS, and the
/// baseline pays that staging copy too (in small chunks, serially),
/// which is precisely the inefficiency §3.1 measures.
struct StreamedSink {
    writer: BufWriter<File>,
    chunk: usize,
    sync: bool,
    stats: WriteStats,
    start: Instant,
    scratch: Vec<u8>,
    /// Fault hooks (test-only; `None` in production). The streamed
    /// schedule is `[Stage(0), Drain(0), Fsync?]`: Stage fires on the
    /// first byte, Drain on the final flush, Fsync before sync_data.
    fault: Option<FaultPlan>,
    staged_once: bool,
}

impl StreamedSink {
    fn open(cfg: &IoConfig, plan: WritePlan, path: &Path) -> Result<Box<dyn Sink>> {
        if let Some(f) = &cfg.fault {
            f.check_alive(FaultSite::Stage)?;
        }
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(path)?;
        Ok(Box::new(StreamedSink {
            writer: BufWriter::with_capacity(plan.chunk, file),
            chunk: plan.chunk,
            sync: plan.sync,
            stats: WriteStats::default(),
            start: Instant::now(),
            scratch: Vec::new(),
            fault: cfg.fault.clone(),
            staged_once: false,
        }))
    }
}

impl Sink for StreamedSink {
    fn write(&mut self, data: &[u8]) -> Result<()> {
        if let Some(f) = &self.fault {
            if !self.staged_once {
                self.staged_once = true;
                f.on_stage()?;
            } else {
                f.check_alive(FaultSite::Stage)?;
            }
        }
        self.scratch.resize(self.chunk, 0);
        for piece in data.chunks(self.chunk) {
            self.scratch[..piece.len()].copy_from_slice(piece);
            self.writer.write_all(&self.scratch[..piece.len()])?;
            self.stats.write_ops += 1;
        }
        self.stats.total_bytes += data.len() as u64;
        Ok(())
    }

    fn finish(mut self: Box<Self>) -> Result<WriteStats> {
        if let Some(f) = &self.fault {
            // Torn on a streamed plan is process death mid-flush: the
            // BufWriter's earlier incidental flushes are whatever they
            // are, the remainder never lands.
            if f.on_drain()? == DrainDecision::Torn {
                return Err(f.error(FaultSite::Drain));
            }
        }
        self.writer.flush()?;
        let file = self.writer.into_inner().map_err(|e| e.into_error())?;
        if self.sync {
            let decision = match &self.fault {
                Some(f) => f.on_fsync()?,
                None => FsyncDecision::Sync,
            };
            if decision == FsyncDecision::Sync {
                file.sync_data()?;
                self.stats.fsyncs = 1;
            }
        }
        self.stats.suffix_bytes = self.stats.total_bytes; // all traditional path
        self.stats.elapsed = self.start.elapsed();
        Ok(self.stats)
    }
}

/// Staged executor: aligned extents staged into pool buffers and
/// drained through per-device submission queues, O_DIRECT when the
/// device's probe allows, zeroed bounce buffer for the sub-alignment
/// tail.
struct StagedSink {
    /// Data descriptor the drain lanes write (O_DIRECT when engaged).
    file: Arc<File>,
    /// Traditional descriptor: bounce-tail write, truncate, fsync.
    side: File,
    pool: BufferPool,
    drain: DrainPool,
    /// Destination device (lane-group key) and configured device count:
    /// each drain picks a lane from the device's group per submission,
    /// so one sink's in-flight extents drain concurrently up to
    /// min(queue_depth, lanes-per-device) — drains are positioned
    /// writes, so rotating lanes never reorders anything.
    device: Option<usize>,
    n_devices: usize,
    /// Resolved staged-chunk size (plan chunk clamped to the shared
    /// pool's geometry).
    chunk: usize,
    align: usize,
    queue_depth: usize,
    sync: bool,
    o_direct: bool,
    /// How drained extents reach the kernel (sync pwrite loop vs
    /// batched ring submission) — resolved per file at open.
    backend: Arc<dyn SubmitBackend>,
    /// True when `backend` is the batched ring path (enables linked
    /// trailing fsync; reporting).
    ring_path: bool,
    /// Staged extents accumulated toward the next backend batch.
    /// Flushed at `batch_cap` entries (ONE submission syscall on the
    /// ring path), at a fault boundary, and at finish.
    batch: Vec<BatchEntry>,
    /// Extents per backend batch: the plan's queue depth on the ring
    /// path (clamped to the staging pool cap so an unflushed batch can
    /// never starve the pool), 1 on the sync path.
    batch_cap: usize,
    /// Accumulated batch accounting (`sqes` holds the per-submission
    /// high-water mark).
    batched: BatchStats,
    /// The ring chained this sink's trailing fsync and it completed —
    /// finish() skips its own fdatasync.
    ring_fsynced: bool,
    /// The planned extents this sink realizes: each drain is checked
    /// (debug builds) against the schedule's next extent offset;
    /// streams that outgrow the plan synthesize further chunk-sized
    /// extents.
    extents: Vec<WriteExtent>,
    extent_idx: usize,
    current: Option<AlignedBuf>,
    /// Next file offset at which the current buffer will land.
    submit_offset: u64,
    /// Total bytes staged so far (logical stream position).
    staged: u64,
    inflight: usize,
    /// High-water mark of drains in flight ([`WriteStats::queue_depth_max`]).
    inflight_max: usize,
    done_tx: Sender<Result<DrainDone>>,
    done_rx: Receiver<Result<DrainDone>>,
    drained: DrainStats,
    err: Option<Error>,
    start: Instant,
    /// Fault hooks (test-only; `None` in production): a Stage boundary
    /// per staging-buffer acquisition, a Drain boundary per submission,
    /// a Fsync boundary before the durable finish.
    fault: Option<FaultPlan>,
}

impl StagedSink {
    fn open(
        cfg: &IoConfig,
        res: &WriteResources,
        plan: WritePlan,
        path: &Path,
        expected_size: Option<u64>,
    ) -> Result<Box<dyn Sink>> {
        // A halted (simulated-dead) runtime must not create or truncate
        // any file — opening the sink is itself an I/O the dead process
        // never issues.
        if let Some(f) = &cfg.fault {
            f.check_alive(FaultSite::Stage)?;
        }
        let align = res.pool.align();
        // Probe-gated O_DIRECT on the data descriptor: one capability
        // probe per device (cached in the DeviceMap), with a belt-and-
        // braces per-file fallback should an individual open still
        // refuse the flag. The probe validates DEFAULT_ALIGN-sized
        // I/O, which covers any configured alignment that is a
        // multiple of it; smaller alignments are unproven and stay on
        // the buffered fallback.
        let mut direct_file = None;
        if cfg.try_o_direct
            && O_DIRECT != 0
            && align % crate::io::align::DEFAULT_ALIGN == 0
            && res.devices.direct_capability_for(path).is_supported()
        {
            direct_file = OpenOptions::new()
                .create(true)
                .write(true)
                .truncate(true)
                .custom_flags(O_DIRECT)
                .open(path)
                .ok();
        }
        let o_direct = direct_file.is_some();
        let file = match direct_file {
            Some(f) => f,
            None => OpenOptions::new().create(true).write(true).truncate(true).open(path)?,
        };
        // Second, traditional descriptor for the bounce tail (and final
        // truncate + fsync) — the paper's two-path file (§4.1).
        let side = OpenOptions::new().write(true).open(path)?;
        if let Some(size) = expected_size {
            preallocate(&file, align_up(size, align as u64))?;
        }
        // The shared pool's geometry wins over the plan's chunk: buffers
        // were sized/aligned at runtime construction.
        let clamped = plan.chunk.clamp(align, res.pool.buf_size());
        let chunk = (align_down(clamped as u64, align as u64) as usize).max(align);
        // Submission backend, per file: the runtime-resolved ring (when
        // the per-filesystem probe accepts this mount), else the
        // per-extent sync loop.
        let ring = res
            .ring
            .as_ref()
            .filter(|_| res.devices.ring_capability_for(path).is_supported())
            .map(Arc::clone);
        let ring_path = ring.is_some();
        let backend: Arc<dyn SubmitBackend> = match ring {
            Some(b) => b,
            None => Arc::new(SyncBackend),
        };
        let batch_cap = if ring_path {
            plan.queue_depth.max(1).min(res.pool.count().max(1))
        } else {
            1
        };
        let (done_tx, done_rx) = mpsc::channel();
        Ok(Box::new(StagedSink {
            file: Arc::new(file),
            side,
            pool: res.pool.clone(),
            drain: res.drain.clone(),
            device: res.devices.device_of(path),
            n_devices: res.devices.len(),
            chunk,
            align,
            queue_depth: plan.queue_depth.max(1),
            sync: plan.sync,
            o_direct,
            backend,
            ring_path,
            batch: Vec::new(),
            batch_cap,
            batched: BatchStats::default(),
            ring_fsynced: false,
            extents: plan.extents,
            extent_idx: 0,
            current: None,
            submit_offset: 0,
            staged: 0,
            inflight: 0,
            inflight_max: 0,
            done_tx,
            done_rx,
            drained: DrainStats::default(),
            err: None,
            start: Instant::now(),
            fault: cfg.fault.clone(),
        }))
    }

    fn submit_buf(&mut self, buf: AlignedBuf, len: usize) {
        // Drain op boundary: the staged extent is about to hit the
        // submission queue. A halting fault stops the submission; a torn
        // write lands only an aligned prefix of the extent (the
        // positioned write the process died inside of), synchronously,
        // then stops.
        // Fires once per batch ENTRY, not per batch: a batched backend
        // preserves the fault matrix's per-drain crossing counts.
        if let Some(f) = &self.fault {
            match f.on_drain() {
                Ok(DrainDecision::Full) => {}
                Ok(DrainDecision::Torn) => {
                    // Earlier batch entries were real submissions the
                    // dying process issued: they must land. Only THIS
                    // extent tears.
                    self.flush_batch(false);
                    let prefix = align_down((len / 2) as u64, self.align as u64) as usize;
                    if prefix > 0 {
                        let _ = self.file.write_all_at(&buf.filled()[..prefix], self.submit_offset);
                    }
                    self.pool.release(buf);
                    if self.err.is_none() {
                        self.err = Some(f.error(FaultSite::Drain));
                    }
                    return;
                }
                Err(e) => {
                    self.flush_batch(false);
                    self.pool.release(buf);
                    if self.err.is_none() {
                        self.err = Some(e);
                    }
                    return;
                }
            }
        }
        let offset = self.submit_offset;
        // The plan is a contract, not advisory: every realized drain
        // must start exactly where the schedule's next extent starts.
        // (The final extent may drain short — its sub-alignment tail
        // leaves through the bounce path — and streams that outgrow
        // their declared length continue past the planned extents.)
        if let Some(e) = self.extents.get(self.extent_idx) {
            debug_assert_eq!(e.offset, offset, "drain deviates from the planned extent schedule");
        }
        self.extent_idx += 1;
        self.submit_offset += len as u64;
        self.batch.push(BatchEntry { buf, offset, len });
        if self.batch.len() >= self.batch_cap {
            self.flush_batch(false);
        }
    }

    /// Hand the pending batch to a drain lane — ONE backend submission
    /// for up to `batch_cap` staged extents (plus, with `link_fsync`, a
    /// chained trailing flush; an empty batch then submits a flush-only
    /// op). The lane is chosen per BATCH, rotating within the device's
    /// lane group, so a deep-queue sink still spreads batches over the
    /// group's workers.
    fn flush_batch(&mut self, link_fsync: bool) {
        if self.batch.is_empty() && !link_fsync {
            return;
        }
        let entries = std::mem::take(&mut self.batch);
        self.inflight += entries.len().max(1);
        if !entries.is_empty() {
            self.inflight_max = self.inflight_max.max(self.inflight);
        }
        let lane = self.drain.lane_for(self.device, self.n_devices);
        self.drain.submit_batch(
            lane,
            Arc::clone(&self.file),
            entries,
            link_fsync,
            Arc::clone(&self.backend),
            self.pool.clone(),
            self.done_tx.clone(),
        );
    }

    /// Receive one drain completion, folding it into stats/err.
    fn collect_one(&mut self) {
        match self.done_rx.recv() {
            Ok(Ok(done)) => {
                // bytes == 0 marks a flush-only batch completion, not a
                // positioned write (real extents are never empty).
                if done.bytes > 0 {
                    self.drained.bytes += done.bytes;
                    self.drained.ops += 1;
                }
                self.drained.busy += done.busy;
                if let Some(bs) = done.batch {
                    self.batched.submissions += bs.submissions;
                    self.batched.sqes = self.batched.sqes.max(bs.sqes);
                    self.batched.completions += bs.completions;
                    if bs.fsync_done {
                        self.ring_fsynced = true;
                    }
                }
                self.inflight -= 1;
            }
            Ok(Err(e)) => {
                if self.err.is_none() {
                    self.err = Some(e);
                }
                self.inflight -= 1;
            }
            Err(_) => {
                if self.err.is_none() {
                    self.err = Some(Error::Internal("drain pool died".into()));
                }
                self.inflight = 0;
            }
        }
    }

    fn check_err(&mut self) -> Result<()> {
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        Ok(())
    }
}

impl Sink for StagedSink {
    fn write(&mut self, mut data: &[u8]) -> Result<()> {
        while !data.is_empty() {
            self.check_err()?;
            if self.current.is_none() {
                // Stage op boundary: a staging buffer is about to be
                // filled for the next extent.
                if let Some(f) = &self.fault {
                    f.on_stage()?;
                }
                // Backpressure, two layers: the plan's queue depth
                // (Fig. 5 single vs double buffering), then the global
                // staging pool cap.
                while self.inflight >= self.queue_depth {
                    self.collect_one();
                }
                self.check_err()?;
                self.current = Some(self.pool.acquire());
            }
            let buf = self.current.as_mut().unwrap();
            let room = self.chunk - buf.len;
            let n = room.min(data.len());
            buf.stage(&data[..n]);
            self.staged += n as u64;
            data = &data[n..];
            if buf.len == self.chunk {
                let buf = self.current.take().expect("submit without buffer");
                let len = buf.len;
                self.submit_buf(buf, len);
            }
        }
        Ok(())
    }

    fn finish(mut self: Box<Self>) -> Result<WriteStats> {
        let total = self.staged;
        let align = self.align as u64;
        // Final partial extent: drain the aligned prefix through the
        // submission queue, keep the sub-alignment tail for the bounce
        // path.
        let mut tail: Vec<u8> = Vec::new();
        if let Some(buf) = self.current.take() {
            let filled = buf.len;
            let aligned = align_down(filled as u64, align) as usize;
            tail.extend_from_slice(&buf.filled()[aligned..]);
            if aligned > 0 {
                self.submit_buf(buf, aligned);
            } else {
                self.pool.release(buf);
            }
        }
        let tail_offset = self.submit_offset;
        // Chain the trailing fsync into the final ring batch when the
        // stream needs no bounce tail and no fault plan is installed (a
        // fault-instrumented sink must fire its Fsync boundary after
        // every drain completion, at the same op-schedule point as the
        // sync path). With a pending partial batch this links the flush
        // behind its writes in the SAME submission syscall; with an
        // empty one it submits a flush-only op.
        let link = self.ring_path && self.sync && tail.is_empty() && self.fault.is_none();
        self.flush_batch(link);
        while self.inflight > 0 {
            self.collect_one();
        }
        self.check_err()?;
        let mut bounce_bytes = 0u64;
        if !tail.is_empty() {
            // Zeroed bounce buffer: the sub-alignment tail goes through
            // the traditional descriptor at its exact length — the
            // unaligned bytes never pass through the (possibly
            // O_DIRECT) data fd, and the zeroed staging area can never
            // leak heap garbage to disk.
            let mut bounce = AlignedBuf::new(self.align, self.align);
            bounce.stage(&tail);
            self.side.write_all_at(bounce.filled(), tail_offset)?;
            bounce_bytes = tail.len() as u64;
        }
        // Trim pre-allocation padding to the logical length.
        self.side.set_len(total)?;
        let mut fsyncs = 0;
        if self.sync {
            if self.ring_fsynced {
                // The ring already chained the flush behind the final
                // batch; the file is durable.
                fsyncs = 1;
            } else {
                // Fsync op boundary: the plan's trailing durability op.
                let decision = match &self.fault {
                    Some(f) => f.on_fsync()?,
                    None => FsyncDecision::Sync,
                };
                if decision == FsyncDecision::Sync {
                    // fdatasync is per-inode, not per-descriptor: one
                    // call covers bytes written through both paths
                    // (O_DIRECT bypasses the page cache but not the
                    // device cache; the bounce tail went through the
                    // page cache regardless).
                    self.side.sync_data()?;
                    fsyncs = 1;
                }
            }
        }
        Ok(WriteStats {
            total_bytes: total,
            aligned_bytes: self.drained.bytes,
            suffix_bytes: tail.len() as u64,
            direct_bytes: if self.o_direct { self.drained.bytes } else { 0 },
            direct_extents: if self.o_direct { self.drained.ops } else { 0 },
            bounce_bytes,
            queue_depth_max: self.inflight_max as u64,
            write_ops: self.drained.ops + u64::from(!tail.is_empty()),
            fsyncs,
            batched_submissions: self.batched.submissions,
            sqes_per_submit_max: self.batched.sqes,
            completions_reaped: self.batched.completions,
            elapsed: self.start.elapsed(),
            drain_busy: self.drained.busy,
            o_direct: self.o_direct,
        })
    }
}

impl Drop for StagedSink {
    fn drop(&mut self) {
        // A sink dropped without finish() must not strand its staging
        // buffer; in-flight buffers are recycled by the drain workers
        // unconditionally, and never-flushed batch entries here.
        if let Some(buf) = self.current.take() {
            self.pool.release(buf);
        }
        for entry in self.batch.drain(..) {
            self.pool.release(entry.buf);
        }
        // Wait out any in-flight drains: a caller that drops a failed
        // sink and immediately re-creates the same path must not race
        // stale positioned writes into the new file.
        while self.inflight > 0 {
            match self.done_rx.recv() {
                Ok(_) => self.inflight -= 1,
                Err(_) => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::engine::scratch_dir;
    use crate::util::rng::Rng;

    fn cfg(kind: EngineKind, buf: usize) -> IoConfig {
        IoConfig { kind, io_buf_size: buf, align: 4096, ..IoConfig::default() }.normalized()
    }

    fn staged_plan(kind: EngineKind, buf: usize, total: Option<u64>) -> WritePlan {
        let c = cfg(kind, buf);
        let depth = crate::io::double_buffer::overlap_depth(kind, c.queue_depth);
        WritePlan::staged(&c, total, depth)
    }

    fn roundtrip(kind: EngineKind, buf: usize, data: &[u8], pieces: usize) -> WriteStats {
        // per-(kind, size, buf) dir: concurrent tests must not remove
        // each other's scratch mid-write
        let dir = scratch_dir(&format!("wpipe-rt-{}-{}-{buf}", kind.name(), data.len())).unwrap();
        let path = dir.join(format!("{}-{}.bin", kind.name(), data.len()));
        let c = cfg(kind, buf);
        let res = WriteResources::standalone(&c, 2);
        let plan = if kind == EngineKind::Buffered {
            WritePlan::streamed(&c, Some(data.len() as u64))
        } else {
            staged_plan(kind, buf, Some(data.len() as u64))
        };
        let mut sink =
            WritePipeline::open(&c, &res, plan, &path, Some(data.len() as u64)).unwrap();
        for chunk in data.chunks(data.len().max(1) / pieces.max(1) + 1) {
            sink.write(chunk).unwrap();
        }
        let stats = sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), data, "kind={kind:?}");
        std::fs::remove_dir_all(&dir).unwrap();
        stats
    }

    #[test]
    fn all_kinds_roundtrip_through_the_one_executor() {
        let mut data = vec![0u8; 1_000_000 + 777];
        Rng::new(5).fill_bytes(&mut data);
        for kind in
            [EngineKind::Buffered, EngineKind::DirectSingle, EngineKind::DirectDouble]
        {
            let stats = roundtrip(kind, 64 << 10, &data, 7);
            assert_eq!(stats.total_bytes, data.len() as u64, "kind={kind:?}");
            assert_eq!(
                stats.aligned_bytes + stats.suffix_bytes,
                stats.total_bytes,
                "kind={kind:?}: every byte is aligned-path or traditional-path"
            );
            if kind == EngineKind::Buffered {
                assert_eq!(stats.suffix_bytes, stats.total_bytes);
                assert_eq!(stats.direct_bytes, 0);
            }
        }
    }

    #[test]
    fn queue_depth_caps_inflight_drains() {
        let data = vec![7u8; 512 << 10];
        let single = roundtrip(EngineKind::DirectSingle, 16 << 10, &data, 4);
        assert!(single.queue_depth_max <= 1, "single: qd={}", single.queue_depth_max);
        let double = roundtrip(EngineKind::DirectDouble, 16 << 10, &data, 4);
        assert!(double.queue_depth_max <= 2, "double: qd={}", double.queue_depth_max);
        assert!(double.queue_depth_max >= 1);
    }

    #[test]
    fn direct_path_invariants_when_engaged() {
        // Probe-dependent: on an O_DIRECT-capable scratch fs the direct
        // counters must be aligned and complementary to the bounce
        // bytes; on a rejecting fs they must be zero with the fallback
        // engaged. Either way the bytes round-trip bit-identically
        // (asserted inside roundtrip()).
        let mut data = vec![0u8; 300_000 + 1234];
        Rng::new(9).fill_bytes(&mut data);
        let stats = roundtrip(EngineKind::DirectDouble, 64 << 10, &data, 5);
        if stats.o_direct {
            assert!(stats.direct_bytes > 0);
            assert_eq!(stats.direct_bytes % 4096, 0, "direct writes must stay aligned");
            assert_eq!(
                stats.direct_bytes + stats.bounce_bytes,
                stats.total_bytes,
                "every byte goes through exactly one of the two paths"
            );
            assert!(stats.bounce_bytes < 4096, "bounce carries only the sub-alignment tail");
        } else {
            assert_eq!(stats.direct_bytes, 0);
            assert_eq!(stats.direct_extents, 0);
        }
    }

    #[test]
    fn bounce_tail_roundtrips_bit_identically_with_and_without_o_direct() {
        // The satellite acceptance: head/tail bytes round-trip
        // bit-identically through the O_DIRECT attempt AND the forced
        // buffered fallback, for tails of every size class.
        let dir = scratch_dir("wpipe-bounce").unwrap();
        for tail in [0usize, 1, 511, 4095] {
            let mut data = vec![0u8; 16 * 4096 + tail]; // stream tail = `tail` bytes
            Rng::new(tail as u64).fill_bytes(&mut data);
            for try_direct in [true, false] {
                let mut c = cfg(EngineKind::DirectDouble, 16 << 10);
                c.try_o_direct = try_direct;
                let res = WriteResources::standalone(&c, 2);
                let plan = WritePlan::staged(&c, Some(data.len() as u64), 2);
                let path = dir.join(format!("t{tail}-{try_direct}.bin"));
                let mut sink =
                    WritePipeline::open(&c, &res, plan, &path, Some(data.len() as u64))
                        .unwrap();
                sink.write(&data).unwrap();
                let stats = sink.finish().unwrap();
                assert_eq!(std::fs::read(&path).unwrap(), data, "tail={tail}");
                assert_eq!(stats.total_bytes, data.len() as u64);
                if !try_direct {
                    assert!(!stats.o_direct, "fallback must not engage O_DIRECT");
                    assert_eq!(stats.direct_bytes, 0);
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_planned_extents_cover_stream_exactly_once_and_stay_aligned() {
        // Satellite: planned write extents cover [0, len) exactly once,
        // all interior extent boundaries are alignment multiples, and
        // the op schedule stages each extent exactly once before its
        // drain.
        crate::prop::forall("write plan extents tile the stream", 256, |g| {
            let align = 512u64 << g.u64(0, 4); // 512 .. 8192
            let total = g.u64(0, 5 << 20);
            let kind = *g.choose(&[EngineKind::DirectSingle, EngineKind::DirectDouble]);
            let c = IoConfig {
                kind,
                io_buf_size: (align as usize) << g.usize(0, 6),
                align: align as usize,
                ..IoConfig::default()
            }
            .normalized();
            let depth = crate::io::double_buffer::overlap_depth(kind, c.queue_depth);
            let plan = WritePlan::staged(&c, Some(total), depth);
            if plan.validate(align).is_err() {
                return false;
            }
            // exact coverage
            if plan.planned_bytes() != total {
                return false;
            }
            // chunk itself is aligned and positive
            plan.chunk as u64 % align == 0 && plan.chunk > 0
        });
    }

    #[test]
    fn streamed_plan_validates_too() {
        let c = cfg(EngineKind::Buffered, 1 << 20);
        let plan = WritePlan::streamed(&c, Some(123_456));
        plan.validate(4096).unwrap();
        assert_eq!(plan.planned_bytes(), 123_456);
        assert!(plan.streamed);
        // unknown-length plans have no extents but stay executable
        let open = WritePlan::streamed(&c, None);
        assert!(open.extents.is_empty());
        open.validate(4096).unwrap();
    }

    #[test]
    fn open_ended_staged_sink_synthesizes_extents() {
        let dir = scratch_dir("wpipe-open").unwrap();
        let c = cfg(EngineKind::DirectDouble, 8192);
        let res = WriteResources::standalone(&c, 2);
        let plan = WritePlan::staged(&c, None, 2);
        assert!(plan.extents.is_empty());
        let path = dir.join("x.bin");
        let data = vec![4u8; 10_000];
        let mut sink = WritePipeline::open(&c, &res, plan, &path, None).unwrap();
        sink.write(&data).unwrap();
        sink.finish().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drain_lanes_serve_concurrent_sinks() {
        // Many sinks over ONE pool and ONE drain pool: the multi-writer
        // configuration the IoRuntime runs. Order within each file must
        // hold; the pool must not leak buffers.
        let dir = scratch_dir("wpipe-shared").unwrap();
        let c = IoConfig { io_buf_size: 2048, align: 512, ..IoConfig::default() }.normalized();
        let res = WriteResources {
            pool: BufferPool::with_align(3, 2048, 512),
            drain: DrainPool::new(2),
            devices: DeviceMap::single(),
            ring: None,
        };
        std::thread::scope(|scope| {
            for i in 0..4usize {
                let c = c.clone();
                let res = res.clone();
                let path = dir.join(format!("f{i}.bin"));
                scope.spawn(move || {
                    let data = vec![i as u8 + 1; 10_000 + i * 513];
                    let plan = WritePlan::staged(&c, Some(data.len() as u64), 2);
                    let mut sink =
                        WritePipeline::open(&c, &res, plan, &path, Some(data.len() as u64))
                            .unwrap();
                    for chunk in data.chunks(777) {
                        sink.write(chunk).unwrap();
                    }
                    sink.finish().unwrap();
                    assert_eq!(std::fs::read(&path).unwrap(), data);
                });
            }
        });
        // every buffer returned to the pool
        let mut held = Vec::new();
        for _ in 0..3 {
            held.push(res.pool.try_acquire().expect("buffer leaked"));
        }
        assert!(res.pool.try_acquire().is_none(), "cap exceeded");
        assert!(res.pool.allocations() <= 3);
        // lane counters saw the traffic: every submission is accounted
        // on some lane, with nonzero busy time and a sane high-water
        let stats = res.drain.lane_stats();
        assert_eq!(stats.len(), 2);
        let submitted: u64 = stats.iter().map(|l| l.submissions).sum();
        assert!(submitted > 0, "no drain submissions counted");
        let busy: Duration = stats.iter().map(|l| l.busy).sum();
        assert!(busy > Duration::ZERO, "drain busy time not accounted");
        assert!(stats.iter().all(|l| l.max_queued <= submitted));
        assert!(stats.iter().any(|l| l.max_queued >= 1));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn preallocate_extends_file_and_finish_trims_to_logical_length() {
        let dir = scratch_dir("wpipe-prealloc").unwrap();
        // the helper itself: real size extension, idempotent on 0
        let path = dir.join("raw.bin");
        let f = OpenOptions::new().create(true).write(true).truncate(true).open(&path).unwrap();
        preallocate(&f, 0).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        preallocate(&f, 1 << 20).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 1 << 20);
        // end to end: a staged sink given expected_size never extends
        // mid-write and still trims to the exact logical length
        let c = cfg(EngineKind::DirectDouble, 16 << 10);
        let res = WriteResources::standalone(&c, 2);
        let mut data = vec![0u8; 100_000 + 123];
        Rng::new(3).fill_bytes(&mut data);
        let plan = WritePlan::staged(&c, Some(data.len() as u64), 2);
        let out = dir.join("staged.bin");
        let mut sink =
            WritePipeline::open(&c, &res, plan, &out, Some(data.len() as u64)).unwrap();
        sink.write(&data).unwrap();
        sink.finish().unwrap();
        assert_eq!(std::fs::metadata(&out).unwrap().len(), data.len() as u64);
        assert_eq!(std::fs::read(&out).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lane_groups_keep_devices_disjoint_and_saturated() {
        use std::collections::BTreeSet;
        let pool = DrainPool::new(4);
        // 1 device over 4 lanes: sinks spread over every drain worker
        let used: BTreeSet<usize> = (0..8).map(|_| pool.lane_for(Some(0), 1)).collect();
        assert_eq!(used.len(), 4, "single device must keep every drain worker busy");
        // 2 devices over 4 lanes: lane groups never overlap
        let d0: BTreeSet<usize> = (0..8).map(|_| pool.lane_for(Some(0), 2)).collect();
        let d1: BTreeSet<usize> = (0..8).map(|_| pool.lane_for(Some(1), 2)).collect();
        assert!(d0.is_disjoint(&d1), "devices must not share a lane: {d0:?} vs {d1:?}");
        assert_eq!(d0.len(), 2, "each device owns half the lanes");
        // more devices than lanes: still in bounds, one lane per device mod lanes
        for d in 0..8 {
            assert!(pool.lane_for(Some(d), 8) < 4);
        }
        // unrouted sinks reach every lane too
        let any: BTreeSet<usize> = (0..8).map(|_| pool.lane_for(None, 0)).collect();
        assert_eq!(any.len(), 4);
        // remainder lanes are distributed, not idled: 3 lanes over 2
        // devices -> device 0 owns {0, 2}, device 1 owns {1}
        let odd = DrainPool::new(3);
        let d0: BTreeSet<usize> = (0..8).map(|_| odd.lane_for(Some(0), 2)).collect();
        let d1: BTreeSet<usize> = (0..8).map(|_| odd.lane_for(Some(1), 2)).collect();
        assert_eq!(d0, BTreeSet::from([0, 2]));
        assert_eq!(d1, BTreeSet::from([1]));
    }

    #[test]
    fn unrouted_round_robin_spreads_under_interleaved_submitters() {
        // Satellite regression: the unrouted rotation owns its cursor.
        // On the old shared cursor, strictly alternating routed and
        // unrouted picks advance it twice per unrouted pick, so over an
        // even lane count the unrouted residues collapse onto half the
        // lanes (or one). Interleave from several threads and assert
        // near-even unrouted spread.
        let pool = DrainPool::new(4);
        let lanes = pool.lanes();
        let hits: Vec<AtomicU64> = (0..lanes).map(|_| AtomicU64::new(0)).collect();
        let per_thread = 400usize;
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let pool = pool.clone();
                let hits = &hits;
                scope.spawn(move || {
                    for _ in 0..per_thread {
                        // routed pick in between, as a concurrent
                        // multi-sink workload produces
                        let _ = pool.lane_for(Some(0), 1);
                        let lane = pool.lane_for(None, 1);
                        hits[lane].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        let total = 4 * per_thread as u64;
        let expect = total / lanes as u64;
        for (lane, h) in hits.iter().enumerate() {
            let n = h.load(Ordering::Relaxed);
            assert!(
                n >= expect / 2 && n <= expect * 2,
                "unrouted spread collapsed: lane {lane} got {n} of {total} (expect ~{expect})"
            );
        }
        // single-threaded determinism: strictly alternating traffic
        // still reaches every lane
        let det = DrainPool::new(4);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..8 {
            let _ = det.lane_for(Some(0), 1);
            seen.insert(det.lane_for(None, 1));
        }
        assert_eq!(seen.len(), 4, "alternating traffic must still cover all lanes: {seen:?}");
    }

    #[test]
    fn sync_backend_batches_report_per_entry_and_write_correctly() {
        // The batch machinery itself, on the always-available backend:
        // one submission with several entries writes every extent at
        // its offset, recycles every buffer, reports one completion per
        // entry with the accounting on the last.
        let dir = scratch_dir("wpipe-batch").unwrap();
        let path = dir.join("b.bin");
        let file = Arc::new(
            OpenOptions::new().create(true).write(true).truncate(true).open(&path).unwrap(),
        );
        let pool = BufferPool::with_align(3, 1024, 512);
        let drain = DrainPool::new(1);
        let (tx, rx) = mpsc::channel();
        let mut entries = Vec::new();
        for i in 0..3u8 {
            let mut buf = pool.acquire();
            buf.stage(&vec![i + 1; 512]);
            entries.push(BatchEntry { buf, offset: i as u64 * 512, len: 512 });
        }
        drain.submit_batch(
            0,
            Arc::clone(&file),
            entries,
            true,
            Arc::new(SyncBackend),
            pool.clone(),
            tx,
        );
        let mut dones = Vec::new();
        for _ in 0..3 {
            dones.push(rx.recv().unwrap().unwrap());
        }
        assert!(dones.iter().all(|d| d.bytes == 512));
        let with_stats: Vec<_> = dones.iter().filter(|d| d.batch.is_some()).collect();
        assert_eq!(with_stats.len(), 1, "batch accounting rides on exactly one completion");
        let bs = with_stats[0].batch.unwrap();
        assert_eq!(bs.submissions, 0, "sync backend issues no batched submission syscalls");
        assert!(bs.fsync_done, "link_fsync on the sync backend fdatasyncs");
        let mut want = Vec::new();
        for i in 0..3u8 {
            want.extend(vec![i + 1; 512]);
        }
        assert_eq!(std::fs::read(&path).unwrap(), want);
        // all three buffers back in the pool
        for _ in 0..3 {
            pool.try_acquire().expect("batch buffer leaked");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dropped_sink_returns_buffer() {
        let dir = scratch_dir("wpipe-drop").unwrap();
        let c = IoConfig { io_buf_size: 1024, align: 512, ..IoConfig::default() }.normalized();
        let res = WriteResources {
            pool: BufferPool::with_align(1, 1024, 512),
            drain: DrainPool::new(1),
            devices: DeviceMap::single(),
            ring: None,
        };
        let plan = WritePlan::staged(&c, Some(1024), 1);
        let mut sink =
            WritePipeline::open(&c, &res, plan, &dir.join("x.bin"), None).unwrap();
        sink.write(&[1, 2, 3]).unwrap();
        drop(sink);
        assert!(res.pool.try_acquire().is_some(), "current buffer not recycled on drop");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_order_preserved_any_chunking() {
        crate::prop::forall("staged pipeline preserves order", 24, |g| {
            let total = g.usize(0, 6000);
            let mut data = vec![0u8; total];
            Rng::new(g.u64(0, u64::MAX)).fill_bytes(&mut data);
            let kind = *g.choose(&[EngineKind::DirectSingle, EngineKind::DirectDouble]);
            let stats = roundtrip(kind, 512, &data, g.usize(1, 5));
            stats.total_bytes == total as u64
        });
    }
}
