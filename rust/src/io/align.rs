//! Alignment arithmetic for the direct-I/O path.
//!
//! Direct I/O (and SSD block interfaces generally) require offset, length
//! and memory alignment — 512 B on classic Linux block devices, 4 KiB on
//! modern NVMe namespaces. The paper (§4.1) splits each checkpoint into
//! the largest aligned *prefix* (fast path) and a tiny unaligned *suffix*
//! (traditional I/O), instead of padding the file.

/// Default alignment: 4 KiB covers O_DIRECT on every modern fs/namespace.
pub const DEFAULT_ALIGN: usize = 4096;

/// Largest multiple of `align` that is <= `len`.
#[inline]
pub fn align_down(len: u64, align: u64) -> u64 {
    debug_assert!(align.is_power_of_two());
    len & !(align - 1)
}

/// Smallest multiple of `align` that is >= `len`.
#[inline]
pub fn align_up(len: u64, align: u64) -> u64 {
    debug_assert!(align.is_power_of_two());
    len.checked_add(align - 1).expect("align_up overflow") & !(align - 1)
}

/// True when `v` is a multiple of `align`.
#[inline]
pub fn is_aligned(v: u64, align: u64) -> bool {
    debug_assert!(align.is_power_of_two());
    v & (align - 1) == 0
}

/// Split `total` into (aligned prefix, unaligned suffix) — paper §4.1.
/// The suffix is always < align, so for GB-scale checkpoints it is a
/// negligible fraction written through the traditional path.
#[inline]
pub fn prefix_suffix(total: u64, align: u64) -> (u64, u64) {
    let prefix = align_down(total, align);
    (prefix, total - prefix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    #[test]
    fn align_basics() {
        assert_eq!(align_down(4097, 4096), 4096);
        assert_eq!(align_down(4096, 4096), 4096);
        assert_eq!(align_down(4095, 4096), 0);
        assert_eq!(align_up(1, 4096), 4096);
        assert_eq!(align_up(0, 4096), 0);
        assert!(is_aligned(8192, 4096));
        assert!(!is_aligned(8191, 4096));
    }

    #[test]
    fn prefix_suffix_split() {
        let (p, s) = prefix_suffix(10_000, 4096);
        assert_eq!((p, s), (8192, 1808));
        let (p, s) = prefix_suffix(8192, 4096);
        assert_eq!((p, s), (8192, 0));
        let (p, s) = prefix_suffix(100, 4096);
        assert_eq!((p, s), (0, 100));
    }

    #[test]
    fn prop_prefix_suffix_invariants() {
        forall("prefix+suffix==total, suffix<align", 512, |g| {
            let align = 1u64 << g.u64(0, 16);
            let total = g.u64(0, 1 << 40);
            let (p, s) = prefix_suffix(total, align);
            p + s == total && s < align && is_aligned(p, align)
        });
    }
}
