//! Lightweight metrics: named timers/counters and a JSON report writer
//! used by the training loop, examples, and the `repro` harness.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::Result;

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Time since [`Timer::start`].
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Accumulates named sample series (seconds, bytes, ratios...).
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    series: BTreeMap<String, Vec<f64>>,
    counters: BTreeMap<String, u64>,
}

impl Recorder {
    /// An empty recorder.
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Append one sample to the named series.
    pub fn record(&mut self, name: &str, value: f64) {
        self.series.entry(name.to_string()).or_default().push(value);
    }

    /// Append a duration sample (in seconds) to the named series.
    pub fn record_duration(&mut self, name: &str, d: Duration) {
        self.record(name, d.as_secs_f64());
    }

    /// Increment the named counter.
    pub fn count(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_default() += by;
    }

    /// All samples of a series (empty if never recorded).
    pub fn samples(&self, name: &str) -> &[f64] {
        self.series.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Current value of a counter (0 if never counted).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Summary statistics of a series.
    pub fn summary(&self, name: &str) -> Summary {
        Summary::of(self.samples(name))
    }

    /// Sum of all samples of a series.
    pub fn total(&self, name: &str) -> f64 {
        self.samples(name).iter().sum()
    }

    /// Arithmetic mean of a series (0.0 if never recorded).
    pub fn mean(&self, name: &str) -> f64 {
        let s = self.samples(name);
        if s.is_empty() {
            0.0
        } else {
            s.iter().sum::<f64>() / s.len() as f64
        }
    }

    /// Serialize all series summaries + counters for a results file.
    pub fn to_json(&self) -> Json {
        let mut obj = Vec::new();
        for (name, samples) in &self.series {
            let s = Summary::of(samples);
            obj.push((
                name.as_str(),
                Json::obj(vec![
                    ("n", Json::from(s.n)),
                    ("mean", Json::from(s.mean)),
                    ("p50", Json::from(s.p50)),
                    ("p95", Json::from(s.p95)),
                    ("min", Json::from(s.min)),
                    ("max", Json::from(s.max)),
                    ("total", Json::from(samples.iter().sum::<f64>())),
                ]),
            ));
        }
        let counters: Vec<(&str, Json)> = self
            .counters
            .iter()
            .map(|(k, &v)| (k.as_str(), Json::from(v as i64)))
            .collect();
        Json::obj(vec![
            ("series", Json::obj(obj)),
            ("counters", Json::obj(counters)),
        ])
    }

    /// Write the JSON report to `path` (creating parent dirs).
    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut r = Recorder::new();
        for v in [1.0, 2.0, 3.0] {
            r.record("lat", v);
        }
        r.count("ckpts", 2);
        r.count("ckpts", 1);
        assert_eq!(r.samples("lat").len(), 3);
        assert_eq!(r.summary("lat").p50, 2.0);
        assert_eq!(r.total("lat"), 6.0);
        assert_eq!(r.mean("lat"), 2.0);
        assert_eq!(r.mean("missing"), 0.0);
        assert_eq!(r.counter("ckpts"), 3);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn json_report_roundtrips() {
        let mut r = Recorder::new();
        r.record("x", 0.5);
        r.count("n", 7);
        let j = r.to_json();
        assert_eq!(j.get("counters").unwrap().get("n").unwrap().as_i64().unwrap(), 7);
        let mean = j.get("series").unwrap().get("x").unwrap().get("mean").unwrap();
        assert_eq!(mean.as_f64().unwrap(), 0.5);
    }

    #[test]
    fn writes_file() {
        let dir = crate::io::engine::scratch_dir("metrics").unwrap();
        let path = dir.join("sub").join("report.json");
        let mut r = Recorder::new();
        r.record("a", 1.0);
        r.write_json(&path).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn timer_measures() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.secs() >= 0.002);
    }
}
