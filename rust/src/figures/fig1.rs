//! Figure 1: impact of data parallelism on training time — compute
//! shrinks with DP while the (baseline) checkpoint cost is constant, so
//! checkpointing increasingly dominates.
//!
//! Paper anchors: dense (a) checkpoint share grows ~50% → ~89% over
//! DP 8→64; sparse MoE (b) ~82% → ~96% over DP 1→8.

use crate::cluster::bandwidth::WritePath;
use crate::cluster::ClusterSpec;
use crate::checkpoint::strategy::WriterStrategy;
use crate::model::gpt3::find;
use crate::sim::ckpt_sim::simulate_model_checkpoint;
use crate::util::json::Json;
use crate::util::table::{fnum, Table};
use crate::Result;

/// One (model, DP) point of Figure 1.
pub struct Fig1Row {
    /// Model name.
    pub model: String,
    /// Data-parallel degree.
    pub dp: usize,
    /// Per-iteration compute time (seconds).
    pub compute_s: f64,
    /// Baseline checkpoint time (seconds).
    pub ckpt_s: f64,
    /// Checkpoint share of the iteration (0..1).
    pub ckpt_share: f64,
}

/// Compute every row of the figure.
pub fn compute() -> Result<Vec<Fig1Row>> {
    let mut rows = Vec::new();
    // dense: gpt3-1.3b (mp=2, DP 8..64 fits 8 DGX-2 nodes at DP=64)
    let dense = find("gpt3-1.3b").unwrap();
    for dp in [8usize, 16, 32, 64] {
        let nodes = (dp * dense.mp()).div_ceil(16);
        let spec = ClusterSpec::dgx2(nodes.max(1));
        let compute = dense.iter_time(dp, 1).total();
        let ckpt = simulate_model_checkpoint(
            &spec, dense, dp, WriterStrategy::Rank0, WritePath::Baseline,
        )?
        .result
        .latency_s;
        rows.push(Fig1Row {
            model: dense.name.to_string(),
            dp,
            compute_s: compute,
            ckpt_s: ckpt,
            ckpt_share: ckpt / (ckpt + compute),
        });
    }
    // sparse: gpt3-1.8B-MoE (EP=16, DP 1..8)
    let moe = find("gpt3-1.8b-moe").unwrap();
    for dp in [1usize, 2, 4, 8] {
        let nodes = (dp * moe.mp()).div_ceil(16);
        let spec = ClusterSpec::dgx2(nodes.max(1));
        let compute = moe.iter_time(dp, 1).total();
        let ckpt =
            simulate_model_checkpoint(&spec, moe, dp, WriterStrategy::Rank0, WritePath::Baseline)?
                .result
                .latency_s;
        rows.push(Fig1Row {
            model: moe.name.to_string(),
            dp,
            compute_s: compute,
            ckpt_s: ckpt,
            ckpt_share: ckpt / (ckpt + compute),
        });
    }
    Ok(rows)
}

/// Print the figure and save its JSON result.
pub fn run() -> Result<()> {
    let rows = compute()?;
    let mut t = Table::new(vec!["model", "DP", "compute (s)", "ckpt (s)", "ckpt share"]);
    for r in &rows {
        t.row(vec![
            r.model.clone(),
            r.dp.to_string(),
            fnum(r.compute_s),
            fnum(r.ckpt_s),
            format!("{:.0}%", r.ckpt_share * 100.0),
        ]);
    }
    println!("\n== Figure 1: checkpoint share of iteration time vs DP ==");
    println!("paper: dense 50%→89% (DP 8→64); sparse 82%→96% (DP 1→8)\n{}", t.render());
    let json = Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("model", Json::str(&r.model)),
            ("dp", Json::from(r.dp)),
            ("compute_s", Json::from(r.compute_s)),
            ("ckpt_s", Json::from(r.ckpt_s)),
            ("ckpt_share", Json::from(r.ckpt_share)),
        ])
    }));
    super::save_result("fig1", &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_share_grows_with_dp() {
        let rows = compute().unwrap();
        let dense: Vec<&Fig1Row> =
            rows.iter().filter(|r| r.model == "gpt3-1.3b").collect();
        assert!(dense.windows(2).all(|w| w[1].ckpt_share > w[0].ckpt_share));
        // shape anchors: starts ≥ 25%, ends ≥ 70%
        assert!(dense[0].ckpt_share > 0.25, "{}", dense[0].ckpt_share);
        assert!(dense.last().unwrap().ckpt_share > 0.70);
        let moe: Vec<&Fig1Row> =
            rows.iter().filter(|r| r.model == "gpt3-1.8b-moe").collect();
        assert!(moe[0].ckpt_share > 0.5);
        assert!(moe.last().unwrap().ckpt_share > 0.85);
    }
}
