//! Figure 10: FastPersist on the sparse gpt3-1.8B-MoE model (EP=16,
//! 67 GB checkpoints, DP ≤ 8).
//!
//! Paper anchors: checkpoint speedup 7× at DP=1 up to 32× at DP=8; E2E
//! speedup ~15× at DP=8; baseline stuck around ~4 GB/s while
//! FastPersist scales near-linearly toward the hardware bound.

use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::bandwidth::WritePath;
use crate::cluster::ClusterSpec;
use crate::model::gpt3::find;
use crate::sim::ckpt_sim::simulate_model_checkpoint;
use crate::sim::trainsim::{simulate_training, CkptMode};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

/// One DP point of Figure 10 (sparse MoE model).
pub struct Fig10Row {
    /// Data-parallel degree.
    pub dp: usize,
    /// Machine count (one replica per node at EP=16).
    pub nodes: usize,
    /// Baseline throughput (decimal GB/s).
    pub base_gbps: f64,
    /// FastPersist throughput (decimal GB/s).
    pub fp_gbps: f64,
    /// Checkpoint-latency speedup over baseline.
    pub ckpt_speedup: f64,
    /// End-to-end training speedup.
    pub e2e_speedup: f64,
}

/// Simulate every row of the figure.
pub fn compute() -> Result<Vec<Fig10Row>> {
    let m = find("gpt3-1.8b-moe").unwrap();
    let mut rows = Vec::new();
    for dp in [1usize, 2, 4, 8] {
        let nodes = dp; // EP=16 → one replica per DGX-2 node
        let spec = ClusterSpec::dgx2(nodes);
        let base =
            simulate_model_checkpoint(&spec, m, dp, WriterStrategy::Rank0, WritePath::Baseline)?;
        let fp = simulate_model_checkpoint(
            &spec, m, dp, WriterStrategy::AllReplicas, WritePath::FastPersist,
        )?;
        let base_train = simulate_training(&spec, m, dp, 1, CkptMode::Baseline)?;
        let fp_train = simulate_training(
            &spec, m, dp, 1, CkptMode::Pipelined(WriterStrategy::AllReplicas),
        )?;
        rows.push(Fig10Row {
            dp,
            nodes,
            base_gbps: base.result.agg_gbps,
            fp_gbps: fp.result.agg_gbps,
            ckpt_speedup: base.result.latency_s / fp.result.latency_s,
            e2e_speedup: base_train.iter / fp_train.iter,
        });
    }
    Ok(rows)
}

/// Print the figure and save its JSON result.
pub fn run() -> Result<()> {
    let rows = compute()?;
    let mut t =
        Table::new(vec!["DP", "nodes", "base GB/s", "FP GB/s", "ckpt speedup", "E2E speedup"]);
    for r in &rows {
        t.row(vec![
            r.dp.to_string(),
            r.nodes.to_string(),
            format!("{:.1}", r.base_gbps),
            format!("{:.1}", r.fp_gbps),
            format!("{:.1}x", r.ckpt_speedup),
            format!("{:.1}x", r.e2e_speedup),
        ]);
    }
    println!("\n== Figure 10: gpt3-1.8B-MoE (EP=16, 67 GB checkpoints) ==");
    println!("paper: ckpt 7x@DP1 → 32x@DP8; E2E ~15x@DP8; baseline ~4 GB/s\n{}", t.render());
    let json = Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("dp", Json::from(r.dp)),
            ("nodes", Json::from(r.nodes)),
            ("base_gbps", Json::from(r.base_gbps)),
            ("fp_gbps", Json::from(r.fp_gbps)),
            ("ckpt_speedup", Json::from(r.ckpt_speedup)),
            ("e2e_speedup", Json::from(r.e2e_speedup)),
        ])
    }));
    super::save_result("fig10", &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_stuck_low_fp_scales() {
        let rows = compute().unwrap();
        // baseline roughly flat and low
        assert!(rows.iter().all(|r| r.base_gbps < 12.0), "{:?}",
            rows.iter().map(|r| r.base_gbps).collect::<Vec<_>>());
        // FastPersist scales near-linearly with nodes
        let ratio = rows[3].fp_gbps / rows[0].fp_gbps;
        assert!(ratio > 4.0, "scaling ratio={ratio}");
    }

    #[test]
    fn speedups_grow_with_dp_and_bracket_paper() {
        let rows = compute().unwrap();
        assert!(rows.windows(2).all(|w| w[1].ckpt_speedup > w[0].ckpt_speedup));
        // DP=1 ≈ 7x, DP=8 ≈ 32x in the paper; accept the right bands
        assert!(rows[0].ckpt_speedup > 2.0 && rows[0].ckpt_speedup < 20.0,
            "dp1: {}", rows[0].ckpt_speedup);
        assert!(rows[3].ckpt_speedup > 15.0 && rows[3].ckpt_speedup < 80.0,
            "dp8: {}", rows[3].ckpt_speedup);
    }

    #[test]
    fn e2e_speedup_large_at_dp8() {
        // paper: ~15x at DP=8 — sparse models amplify FastPersist's win
        let rows = compute().unwrap();
        assert!(rows[3].e2e_speedup > 5.0, "dp8 e2e: {}", rows[3].e2e_speedup);
        // and bigger than the dense 13b at the same DP (paper §5.5.2)
        let spec = ClusterSpec::dgx2(8);
        let dense = find("gpt3-13b").unwrap();
        let dense_su = simulate_training(&spec, dense, 8, 1, CkptMode::Baseline).unwrap().iter
            / simulate_training(
                &spec, dense, 8, 1,
                CkptMode::Pipelined(WriterStrategy::PerSocket),
            )
            .unwrap()
            .iter;
        assert!(rows[3].e2e_speedup > dense_su, "moe {} vs dense {dense_su}",
            rows[3].e2e_speedup);
    }
}
