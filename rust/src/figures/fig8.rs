//! Figure 8 (+ appendix Fig. 15): parallel checkpoint write of
//! gpt3-0.7b (~10 GB) across 1/2/4/8 nodes, sweeping the write
//! parallelism degree, Replica (spread over all DP ranks) vs Socket
//! (one writer per CPU socket).
//!
//! Paper anchors: best on 2 nodes = 8 writers at 41.8 GB/s (91% of
//! peak); best on 8 nodes = 16 writers (Socket) at 129.8 GB/s; Replica
//! degrades past the per-node sweet spot.

use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::bandwidth::WritePath;
use crate::cluster::ClusterSpec;
use crate::model::gpt3::find;
use crate::sim::ckpt_sim::simulate_model_checkpoint;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

/// One simulated point of the Fig. 8 sweep.
pub struct Fig8Cell {
    /// Machine count.
    pub nodes: usize,
    /// Parallel writer count.
    pub writers: usize,
    /// Writer-selection strategy label.
    pub strategy: String,
    /// Aggregate write throughput (decimal GB/s).
    pub gbps: f64,
    /// Fraction of the cluster's deliverable peak (0..1).
    pub peak_frac: f64,
}

/// Simulate every cell of the sweep.
pub fn compute() -> Result<Vec<Fig8Cell>> {
    let m = find("gpt3-0.7b").unwrap(); // mp=1 → one slice, group = all
    let mut out = Vec::new();
    for nodes in [1usize, 2, 4, 8] {
        let spec = ClusterSpec::dgx2(nodes);
        let dp = nodes * 16;
        // Replica-style: sweep writer counts spread across the cluster.
        let mut k = 1usize;
        while k <= dp {
            let sim = simulate_model_checkpoint(
                &spec,
                m,
                dp,
                WriterStrategy::FixedCount(k),
                WritePath::FastPersist,
            )?;
            out.push(Fig8Cell {
                nodes,
                writers: sim.writers,
                strategy: "replica".into(),
                gbps: sim.result.agg_gbps,
                peak_frac: sim.result.peak_frac,
            });
            k *= 2;
        }
        // Socket: one writer per CPU socket.
        let sim = simulate_model_checkpoint(
            &spec,
            m,
            dp,
            WriterStrategy::PerSocket,
            WritePath::FastPersist,
        )?;
        out.push(Fig8Cell {
            nodes,
            writers: sim.writers,
            strategy: "socket".into(),
            gbps: sim.result.agg_gbps,
            peak_frac: sim.result.peak_frac,
        });
    }
    Ok(out)
}

/// Print the figure and save its JSON result.
pub fn run() -> Result<()> {
    let cells = compute()?;
    println!("\n== Figure 8/15: parallel write of gpt3-0.7b (10 GB), simulated cluster ==");
    println!("paper: 2 nodes best 41.8 GB/s @8 writers; 8 nodes best ~130 GB/s @16 (Socket)\n");
    for nodes in [1usize, 2, 4, 8] {
        let mut t = Table::new(vec!["writers", "strategy", "GB/s", "% of peak"]);
        for c in cells.iter().filter(|c| c.nodes == nodes) {
            t.row(vec![
                c.writers.to_string(),
                c.strategy.clone(),
                format!("{:.1}", c.gbps),
                format!("{:.0}%", c.peak_frac * 100.0),
            ]);
        }
        println!("{nodes} node(s):\n{}", t.render());
    }
    let json = Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("nodes", Json::from(c.nodes)),
            ("writers", Json::from(c.writers)),
            ("strategy", Json::str(&c.strategy)),
            ("gbps", Json::from(c.gbps)),
            ("peak_frac", Json::from(c.peak_frac)),
        ])
    }));
    super::save_result("fig8", &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_node_best_near_paper() {
        let cells = compute().unwrap();
        let best2 = cells
            .iter()
            .filter(|c| c.nodes == 2)
            .map(|c| c.gbps)
            .fold(0.0f64, f64::max);
        assert!(best2 > 33.0 && best2 < 50.0, "best2={best2}");
    }

    #[test]
    fn eight_node_best_exceeds_100gbps() {
        let cells = compute().unwrap();
        let best8 = cells
            .iter()
            .filter(|c| c.nodes == 8)
            .map(|c| c.gbps)
            .fold(0.0f64, f64::max);
        assert!(best8 > 100.0, "best8={best8}");
    }

    #[test]
    fn replica_degrades_past_sweet_spot_on_8_nodes() {
        let cells = compute().unwrap();
        let replica8: Vec<&Fig8Cell> = cells
            .iter()
            .filter(|c| c.nodes == 8 && c.strategy == "replica")
            .collect();
        let best = replica8.iter().map(|c| c.gbps).fold(0.0f64, f64::max);
        let at_max_writers = replica8.last().unwrap().gbps;
        assert!(at_max_writers < best * 0.85, "no degradation: {at_max_writers} vs {best}");
    }

    #[test]
    fn socket_competitive_at_8_nodes() {
        let cells = compute().unwrap();
        let socket8 = cells
            .iter()
            .find(|c| c.nodes == 8 && c.strategy == "socket")
            .unwrap();
        let best8 = cells
            .iter()
            .filter(|c| c.nodes == 8)
            .map(|c| c.gbps)
            .fold(0.0f64, f64::max);
        assert!(socket8.gbps > 0.8 * best8, "socket {} vs best {best8}", socket8.gbps);
    }
}
