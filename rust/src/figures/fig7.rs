//! Figure 7 (+ appendix Figs. 13/14): single-writer microbenchmark on
//! **real local disk** — FastPersist speedup over the torch.save-style
//! buffered baseline, sweeping IO-buffer size and checkpoint size, in
//! single- and double-buffer modes.
//!
//! Paper anchors (on NVMe RAID-0): single buffer 1.8–3.6×, double
//! buffer 1.8–6.6×; benefits grow with checkpoint size; best IO-buffer
//! size is checkpoint-size dependent; double ≥ single almost always.
//!
//! Substrate note: the container's virtio disk (~0.4 GB/s, fsync-bound)
//! would hide every software-path difference, so this experiment runs
//! in [`IoConfig::microbench`] mode — the page cache stands in for the
//! fast NVMe array and the measured differences are exactly the
//! paper's subject: small copying buffered writes (torch.save) vs.
//! large aligned staged writes with single/double buffering.

use crate::io::engine::{build_engine, EngineKind, IoConfig};
use crate::util::bytes::MB;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::Result;

/// One measured point of the Fig. 7 sweep.
pub struct Fig7Cell {
    /// Checkpoint size (decimal MB).
    pub ckpt_mb: u64,
    /// IO (staging) buffer size (decimal MB).
    pub io_buf_mb: u64,
    /// Engine mode label (single/double).
    pub mode: &'static str,
    /// Measured throughput (decimal GB/s).
    pub gbps: f64,
    /// Speedup over the buffered baseline at the same sizes.
    pub speedup_vs_baseline: f64,
}

/// Median-of-k timing for one engine config writing `data`. The engine
/// (and with it the staging pool) is built once and reused across reps
/// — construction stays off the measured path.
fn measure(cfg: &IoConfig, dir: &std::path::Path, data: &[u8], reps: usize) -> Result<f64> {
    let engine = build_engine(cfg);
    let mut times = Vec::with_capacity(reps);
    for i in 0..reps {
        let path = dir.join(format!("ckpt-{}-{i}.bin", cfg.kind.name()));
        let mut sink = engine.create(&path, Some(data.len() as u64))?;
        sink.write(data)?;
        let stats = sink.finish()?;
        times.push(stats.elapsed.as_secs_f64());
        let _ = std::fs::remove_file(&path);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(times[times.len() / 2])
}

/// Measure every cell of the sweep on local disk.
pub fn compute(fast: bool) -> Result<Vec<Fig7Cell>> {
    let dir = crate::io::engine::scratch_dir("fig7")?;
    let (ckpt_sizes, buf_sizes, reps): (Vec<u64>, Vec<u64>, usize) = if fast {
        (vec![16, 128], vec![2, 8, 32], 3)
    } else {
        (vec![16, 32, 64, 128, 256, 512], vec![2, 4, 8, 16, 32, 64, 128], 5)
    };
    let mut out = Vec::new();
    for &ckpt_mb in &ckpt_sizes {
        let mut data = vec![0u8; (ckpt_mb * MB) as usize];
        let head = (MB as usize).min(data.len());
        Rng::new(ckpt_mb).fill_bytes(&mut data[..head]);
        let base_cfg = IoConfig::baseline().microbench();
        let base_t = measure(&base_cfg, &dir, &data, reps)?;
        let base_gbps = crate::util::bytes::gbps(data.len() as u64, base_t);
        out.push(Fig7Cell {
            ckpt_mb,
            io_buf_mb: 0,
            mode: "baseline",
            gbps: base_gbps,
            speedup_vs_baseline: 1.0,
        });
        for &buf_mb in &buf_sizes {
            for (mode, kind) in
                [("single", EngineKind::DirectSingle), ("double", EngineKind::DirectDouble)]
            {
                let cfg =
                    IoConfig::with_kind(kind).with_buf_size((buf_mb * MB) as usize).microbench();
                let t = measure(&cfg, &dir, &data, reps)?;
                let gbps = crate::util::bytes::gbps(data.len() as u64, t);
                out.push(Fig7Cell {
                    ckpt_mb,
                    io_buf_mb: buf_mb,
                    mode,
                    gbps,
                    speedup_vs_baseline: base_t / t,
                });
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
    Ok(out)
}

/// Print the figure and save its JSON result.
pub fn run(fast: bool) -> Result<()> {
    let cells = compute(fast)?;
    let ckpt_sizes: Vec<u64> = {
        let mut v: Vec<u64> = cells.iter().map(|c| c.ckpt_mb).collect();
        v.dedup();
        v
    };
    println!("\n== Figure 7/13/14: single-writer speedup over torch.save (real disk) ==");
    println!("paper: single 1.8-3.6x, double 1.8-6.6x, growing with ckpt size\n");
    for &ck in &ckpt_sizes {
        let mut t = Table::new(vec!["io buf (MB)", "single x", "double x"]);
        let bufs: Vec<u64> = cells
            .iter()
            .filter(|c| c.ckpt_mb == ck && c.mode == "single")
            .map(|c| c.io_buf_mb)
            .collect();
        for b in bufs {
            let s = cells
                .iter()
                .find(|c| c.ckpt_mb == ck && c.io_buf_mb == b && c.mode == "single")
                .unwrap();
            let d = cells
                .iter()
                .find(|c| c.ckpt_mb == ck && c.io_buf_mb == b && c.mode == "double")
                .unwrap();
            t.row(vec![
                b.to_string(),
                format!("{:.2}", s.speedup_vs_baseline),
                format!("{:.2}", d.speedup_vs_baseline),
            ]);
        }
        let base = cells
            .iter()
            .find(|c| c.ckpt_mb == ck && c.mode == "baseline")
            .unwrap();
        println!("{ck} MB checkpoint (baseline {:.2} GB/s):\n{}", base.gbps, t.render());
    }
    let json = Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("ckpt_mb", Json::from(c.ckpt_mb as i64)),
            ("io_buf_mb", Json::from(c.io_buf_mb as i64)),
            ("mode", Json::str(c.mode)),
            ("gbps", Json::from(c.gbps)),
            ("speedup", Json::from(c.speedup_vs_baseline)),
        ])
    }));
    super::save_result("fig7", &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structural_invariants_on_this_substrate() {
        // The container substrate (DRAM-speed "SSD") compresses the
        // paper's 1.8-6.6x gap — both paths are memcpy-bound here (see
        // ARCHITECTURE.md §1). What must still hold structurally:
        // (1) the NVMe path is never catastrophically slower than the
        //     baseline (floor guards regressions), and
        // (2) double buffering is at least as good as single buffering
        //     on aggregate (overlap never hurts).
        let cells = compute(true).unwrap();
        let geo = |mode: &str| {
            let v: Vec<f64> = cells
                .iter()
                .filter(|c| c.mode == mode)
                .map(|c| c.speedup_vs_baseline.ln())
                .collect();
            (v.iter().sum::<f64>() / v.len() as f64).exp()
        };
        let single = geo("single");
        let double = geo("double");
        assert!(single > 0.6, "single geomean speedup {single}");
        assert!(double > 0.6, "double geomean speedup {double}");
        assert!(double > single * 0.92, "double {double} vs single {single}");
    }
}
