//! Figure 2: `torch.save()` write throughput as a percentage of the
//! deliverable SSD peak, for the five dense models on 1–8 machines.
//!
//! Paper anchors: single writer (gpt3-0.7b, 1 node) ≈ 3% of the
//! 24.8 GB/s node peak; gpt3-13b's 16 writers ≈ 7× the single-writer
//! rate (parallel inefficiency); peak stays < 20% everywhere.

use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::bandwidth::WritePath;
use crate::cluster::ClusterSpec;
use crate::model::gpt3::MODEL_ZOO;
use crate::sim::ckpt_sim::simulate_model_checkpoint;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

/// One (model, nodes) point of Figure 2.
pub struct Fig2Cell {
    /// Model name.
    pub model: String,
    /// Machine count.
    pub nodes: usize,
    /// Baseline write throughput (decimal GB/s).
    pub gbps: f64,
    /// Percentage of the deliverable SSD peak.
    pub peak_pct: f64,
}

/// Compute every cell of the figure.
pub fn compute() -> Result<Vec<Fig2Cell>> {
    let mut out = Vec::new();
    for m in MODEL_ZOO.iter().filter(|m| m.dense) {
        for nodes in [1usize, 2, 4, 8] {
            let spec = ClusterSpec::dgx2(nodes);
            let dp = (nodes * 16 / m.mp()).max(1);
            if dp * m.mp() > spec.total_gpus() {
                continue;
            }
            let sim =
                simulate_model_checkpoint(&spec, m, dp, WriterStrategy::Rank0, WritePath::Baseline)?;
            out.push(Fig2Cell {
                model: m.name.to_string(),
                nodes,
                gbps: sim.result.agg_gbps,
                peak_pct: 100.0 * sim.result.agg_gbps / spec.cluster_write_gbps(),
            });
        }
    }
    Ok(out)
}

/// Print the figure and save its JSON result.
pub fn run() -> Result<()> {
    let cells = compute()?;
    let mut t = Table::new(vec!["model", "1 node", "2 nodes", "4 nodes", "8 nodes"]);
    for m in MODEL_ZOO.iter().filter(|m| m.dense) {
        let mut row = vec![m.name.to_string()];
        for nodes in [1usize, 2, 4, 8] {
            match cells.iter().find(|c| c.model == m.name && c.nodes == nodes) {
                Some(c) => row.push(format!("{:.1}% ({:.1} GB/s)", c.peak_pct, c.gbps)),
                None => row.push("-".into()),
            }
        }
        t.row(row);
    }
    println!("\n== Figure 2: torch.save() throughput as % of SSD peak ==");
    println!("paper: single writer ~3%; peak < 20% for all models/scales\n{}", t.render());
    let json = Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("model", Json::str(&c.model)),
            ("nodes", Json::from(c.nodes)),
            ("gbps", Json::from(c.gbps)),
            ("peak_pct", Json::from(c.peak_pct)),
        ])
    }));
    super::save_result("fig2", &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_anchors() {
        let cells = compute().unwrap();
        // single writer ~3%
        let c07 = cells.iter().find(|c| c.model == "gpt3-0.7b" && c.nodes == 1).unwrap();
        assert!((c07.peak_pct - 3.0).abs() < 1.0, "{}", c07.peak_pct);
        // 13b on one node: ~7x the single-writer rate
        let c13 = cells.iter().find(|c| c.model == "gpt3-13b" && c.nodes == 1).unwrap();
        let ratio = c13.gbps / c07.gbps;
        assert!(ratio > 5.0 && ratio < 9.0, "ratio={ratio}");
        // every cell well under peak utilization (paper: < 20%; our
        // contention fit puts the worst cell at ~21%)
        assert!(cells.iter().all(|c| c.peak_pct < 25.0),
            "max={:?}", cells.iter().map(|c| c.peak_pct).fold(0.0f64, f64::max));
    }
}
