//! Table 1: required write bandwidth B_C (Eq. 1) to hide checkpoint
//! creation behind the next iteration's forward+backward, at the
//! maximum valid DP for each model's published GBS.

use crate::model::gpt3::find;
use crate::util::json::Json;
use crate::util::table::{fnum, Table};
use crate::Result;

/// One model row of Table 1.
pub struct Table1Row {
    /// Model name.
    pub model: String,
    /// Maximum valid data-parallel degree.
    pub dp: usize,
    /// Paper's node count for that DP.
    pub nodes: usize,
    /// Required write bandwidth from Eq. 1 (decimal GB/s).
    pub bc_gbps: f64,
    /// The paper's stated B_C (decimal GB/s).
    pub paper_bc: f64,
}

/// Compute every row of the table.
pub fn compute() -> Vec<Table1Row> {
    // (model, max DP, paper nodes, paper B_C)
    let cases = [
        ("gpt3-0.7b", 256usize, 16usize, 34.0),
        ("gpt3-1.3b", 512, 64, 59.0),
        ("gpt3-2.7b", 512, 128, 81.0),
        ("gpt3-6.7b", 1024, 512, 160.0),
        ("gpt3-13b", 1024, 1024, 28.0),
    ];
    cases
        .iter()
        .map(|&(name, dp, nodes, paper)| {
            let m = find(name).unwrap();
            Table1Row {
                model: name.to_string(),
                dp,
                nodes,
                bc_gbps: m.required_bc_gbps(dp, 1),
                paper_bc: paper,
            }
        })
        .collect()
}

/// Print the table and save its JSON result.
pub fn run() -> Result<()> {
    let rows = compute();
    let mut t = Table::new(vec!["model", "DP", "# nodes", "B_C model (GB/s)", "B_C paper (GB/s)"]);
    for r in &rows {
        t.row(vec![
            r.model.clone(),
            r.dp.to_string(),
            r.nodes.to_string(),
            fnum(r.bc_gbps),
            fnum(r.paper_bc),
        ]);
    }
    println!("\n== Table 1: required write bandwidth to hide checkpointing ==");
    println!("{}", t.render());
    let json = Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("model", Json::str(&r.model)),
            ("dp", Json::from(r.dp)),
            ("nodes", Json::from(r.nodes)),
            ("bc_gbps", Json::from(r.bc_gbps)),
            ("paper_bc_gbps", Json::from(r.paper_bc)),
        ])
    }));
    super::save_result("table1", &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_3x_of_paper_and_same_trend() {
        let rows = compute();
        for r in &rows {
            let ratio = r.bc_gbps / r.paper_bc;
            assert!(
                (1.0 / 3.0..=3.0).contains(&ratio),
                "{}: model {:.0} vs paper {:.0}",
                r.model,
                r.bc_gbps,
                r.paper_bc
            );
        }
        // rise through 6.7B, drop at 13B (PP bubble + tiny micro-batch)
        assert!(rows[3].bc_gbps > rows[0].bc_gbps);
        assert!(rows[4].bc_gbps < rows[3].bc_gbps);
    }
}
