//! Figure 12: projection to DP=128 (1024–2048 GPUs) for gpt3-6.7B and
//! gpt3-13B, plus the 13B full-TP variant (§5.7) — and the restart
//! model fed by a **measured** restore throughput.
//!
//! Paper anchors: up to 10.2× (6.7B) and 3.6× (13B) training speedup;
//! 11.3× for 13B with full TP; FastPersist overhead stays < 2%.
//!
//! Recovery time used to assume write-bound restore. Since the
//! ReadRuntime, this figure measures an actual small checkpoint restore
//! (coalesced reads, single-copy assembly — `ReadStats` accounting) and
//! scales the measured per-node read throughput to the projected
//! cluster; the write-bound model remains the fallback when the
//! measurement is unavailable.
//!
//! Substrate note: the measurement runs in `IoConfig::microbench()`
//! mode, i.e. against the **page cache standing in for the NVMe
//! array** — the same deliberate substitution every measured figure in
//! this repo uses (ARCHITECTURE.md §1): the container's ~0.4 GB/s
//! virtio disk would measure the device, not the restore software
//! path. On a host with a real NVMe array, point FASTPERSIST_SCRATCH
//! at it for a device-true number. The printout and the JSON label the
//! substrate so the recovery column is never mistaken for cold-storage
//! restore time.

use crate::sim::project::fig12_sweep_with_read;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

/// Measure real restore throughput (GB/s over read+verify+parse) with
/// a small checkpoint through a ReadRuntime — the `ReadStats`-backed
/// number the restart model consumes. `None` when the measurement
/// cannot run (e.g. read-only scratch).
fn measured_read_gbps() -> Option<f64> {
    use crate::checkpoint::engine::CheckpointEngine;
    use crate::checkpoint::load::{load_checkpoint_with, RestoreOptions};
    use crate::checkpoint::strategy::WriterStrategy;
    use crate::io::engine::IoConfig;
    use crate::io::runtime::IoRuntime;
    use crate::tensor::{DType, Tensor, TensorStore};
    use crate::util::rng::Rng;

    let dir = crate::io::engine::scratch_dir("fig12-restore").ok()?;
    // inner closure so every early exit still reaches the cleanup below
    let measured = (|| {
        let rt = IoRuntime::shared(IoConfig::default().microbench());
        let n = 8usize << 20;
        let mut data = vec![0u8; n];
        Rng::new(12).fill_bytes(&mut data);
        let mut store = TensorStore::new();
        store.push(Tensor::new("w", DType::U8, vec![n], data).ok()?).ok()?;
        let engine =
            CheckpointEngine::with_runtime(std::sync::Arc::clone(&rt), WriterStrategy::Rank0);
        let ck = dir.join("ck");
        engine.write_single(&store, Default::default(), &ck).ok()?;
        let loaded = load_checkpoint_with(&ck, &rt, RestoreOptions::default()).ok()?;
        let gbps = loaded.gbps();
        (gbps.is_finite() && gbps > 0.0).then_some(gbps)
    })();
    let _ = std::fs::remove_dir_all(&dir);
    measured
}

/// Print the projection table and save its JSON result.
pub fn run() -> Result<()> {
    let read_gbps = measured_read_gbps();
    let sweep = fig12_sweep_with_read(read_gbps)?;
    let mut t = Table::new(vec![
        "model", "DP", "nodes", "baseline iter (s)", "FastPersist iter (s)", "speedup",
        "FP overhead", "recovery (s)",
    ]);
    for p in &sweep {
        t.row(vec![
            p.model.clone(),
            p.dp.to_string(),
            p.nodes.to_string(),
            format!("{:.2}", p.baseline_iter),
            format!("{:.2}", p.fastpersist_iter),
            format!("{:.1}x", p.speedup),
            format!("{:.2}%", p.fp_overhead * 100.0),
            format!("{:.1}", p.recovery_s),
        ]);
    }
    println!("\n== Figure 12: projection to DP<=128 (simulated) ==");
    match read_gbps {
        Some(g) => println!(
            "restart model: measured restore throughput {g:.2} GB/s/node x node count \
             (ReadRuntime restore on the pagecache-as-NVMe substrate, ARCHITECTURE.md §1 — \
             set FASTPERSIST_SCRATCH to a real NVMe mount for device-true numbers)"
        ),
        None => println!("restart model: write-bound fallback (restore measurement unavailable)"),
    }
    println!("paper: up to 10.2x (6.7B), 3.6x (13B), 11.3x (13B full-TP); FP overhead <2%\n{}",
        t.render());
    let json = Json::arr(sweep.iter().map(|p| {
        Json::obj(vec![
            ("model", Json::str(&p.model)),
            ("dp", Json::from(p.dp)),
            ("nodes", Json::from(p.nodes)),
            ("baseline_iter_s", Json::from(p.baseline_iter)),
            ("fastpersist_iter_s", Json::from(p.fastpersist_iter)),
            ("speedup", Json::from(p.speedup)),
            ("fp_overhead", Json::from(p.fp_overhead)),
            ("recovery_s", Json::from(p.recovery_s)),
            ("recovery_measured", Json::Bool(p.recovery_measured)),
            ("recovery_substrate", Json::str("pagecache-as-nvme")),
        ])
    }));
    super::save_result("fig12", &json)
}

#[cfg(test)]
mod tests {
    // fig12 behaviour is covered by sim::project::tests; here we only
    // check the harness (including the real restore measurement) runs
    // end-to-end.
    #[test]
    fn runs_and_saves() {
        let dir = crate::io::engine::scratch_dir("fig12-results").unwrap();
        std::env::set_var("FASTPERSIST_RESULTS", &dir);
        super::run().unwrap();
        assert!(dir.join("fig12.json").exists());
        std::env::remove_var("FASTPERSIST_RESULTS");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn restore_measurement_produces_a_throughput() {
        // the measurement is best-effort, but on a writable scratch it
        // must produce a positive, finite GB/s
        let g = super::measured_read_gbps();
        if let Some(g) = g {
            assert!(g > 0.0 && g.is_finite());
        }
    }
}
