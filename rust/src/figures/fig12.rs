//! Figure 12: projection to DP=128 (1024–2048 GPUs) for gpt3-6.7B and
//! gpt3-13B, plus the 13B full-TP variant (§5.7).
//!
//! Paper anchors: up to 10.2× (6.7B) and 3.6× (13B) training speedup;
//! 11.3× for 13B with full TP; FastPersist overhead stays < 2%.

use crate::sim::project::fig12_sweep;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

/// Print the projection table and save its JSON result.
pub fn run() -> Result<()> {
    let sweep = fig12_sweep()?;
    let mut t = Table::new(vec![
        "model", "DP", "nodes", "baseline iter (s)", "FastPersist iter (s)", "speedup",
        "FP overhead",
    ]);
    for p in &sweep {
        t.row(vec![
            p.model.clone(),
            p.dp.to_string(),
            p.nodes.to_string(),
            format!("{:.2}", p.baseline_iter),
            format!("{:.2}", p.fastpersist_iter),
            format!("{:.1}x", p.speedup),
            format!("{:.2}%", p.fp_overhead * 100.0),
        ]);
    }
    println!("\n== Figure 12: projection to DP<=128 (simulated) ==");
    println!("paper: up to 10.2x (6.7B), 3.6x (13B), 11.3x (13B full-TP); FP overhead <2%\n{}",
        t.render());
    let json = Json::arr(sweep.iter().map(|p| {
        Json::obj(vec![
            ("model", Json::str(&p.model)),
            ("dp", Json::from(p.dp)),
            ("nodes", Json::from(p.nodes)),
            ("baseline_iter_s", Json::from(p.baseline_iter)),
            ("fastpersist_iter_s", Json::from(p.fastpersist_iter)),
            ("speedup", Json::from(p.speedup)),
            ("fp_overhead", Json::from(p.fp_overhead)),
        ])
    }));
    super::save_result("fig12", &json)
}

#[cfg(test)]
mod tests {
    // fig12 behaviour is covered by sim::project::tests; here we only
    // check the harness runs end-to-end.
    #[test]
    fn runs_and_saves() {
        let dir = crate::io::engine::scratch_dir("fig12-results").unwrap();
        std::env::set_var("FASTPERSIST_RESULTS", &dir);
        super::run().unwrap();
        assert!(dir.join("fig12.json").exists());
        std::env::remove_var("FASTPERSIST_RESULTS");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
