//! Figure 11: pipelined checkpointing — (a) sensitivity of the
//! per-iteration-checkpointing slowdown to gradient accumulation (GAS
//! 1–512, gpt3-1.3b, DP=1), with and without pipelining; (b) slowdown
//! of the dense models on 8 nodes, with and without pipelining.
//!
//! Paper anchors: pipelining wins for GAS < 64 and reaches ≤8% slowdown
//! by GAS=8; on 8 nodes the 1.3b–13b models see <5% overhead with
//! pipelining.

use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::ClusterSpec;
use crate::model::gpt3::{find, MODEL_ZOO};
use crate::sim::trainsim::{simulate_training, simulate_training_fixed_micro, CkptMode};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

/// One gradient-accumulation point of Fig. 11a.
pub struct GasRow {
    /// Gradient-accumulation steps per optimizer update.
    pub gas: u64,
    /// Slowdown with synchronous checkpointing (1.0 = none).
    pub sync_slowdown: f64,
    /// Slowdown with pipelined checkpointing.
    pub pipe_slowdown: f64,
}

/// One model point of Fig. 11b.
pub struct ModelRow {
    /// Model name.
    pub model: String,
    /// Data-parallel degree.
    pub dp: usize,
    /// Slowdown with synchronous checkpointing (1.0 = none).
    pub sync_slowdown: f64,
    /// Slowdown with pipelined checkpointing.
    pub pipe_slowdown: f64,
}

/// Simulate the GAS sensitivity sweep (Fig. 11a).
pub fn compute_gas_sweep() -> Result<Vec<GasRow>> {
    // gpt3-1.3b, DP=1 on one node (paper uses 2 GPUs of one box) with a
    // fixed micro-batch: per-replica batch = mb * GAS, so compute grows
    // with GAS while the checkpoint stays constant (§2.1.2, §5.6.1).
    let spec = ClusterSpec::dgx2(1);
    let m = find("gpt3-1.3b").unwrap();
    let strat = WriterStrategy::AllReplicas;
    let mb = 1u64;
    let mut rows = Vec::new();
    let mut gas = 1u64;
    while gas <= 512 {
        let sync =
            simulate_training_fixed_micro(&spec, m, 1, mb, gas, CkptMode::Sync(strat))?;
        let pipe =
            simulate_training_fixed_micro(&spec, m, 1, mb, gas, CkptMode::Pipelined(strat))?;
        rows.push(GasRow {
            gas,
            sync_slowdown: sync.slowdown,
            pipe_slowdown: pipe.slowdown,
        });
        gas *= 2;
    }
    Ok(rows)
}

/// Simulate the per-model sweep on 8 nodes (Fig. 11b).
pub fn compute_model_sweep() -> Result<Vec<ModelRow>> {
    let spec = ClusterSpec::dgx2(8);
    let strat = WriterStrategy::PerSocket;
    let mut rows = Vec::new();
    for m in MODEL_ZOO.iter().filter(|m| m.dense) {
        let dp = 128 / m.mp();
        let sync = simulate_training(&spec, m, dp, 8, CkptMode::Sync(strat))?;
        let pipe = simulate_training(&spec, m, dp, 8, CkptMode::Pipelined(strat))?;
        rows.push(ModelRow {
            model: m.name.to_string(),
            dp,
            sync_slowdown: sync.slowdown,
            pipe_slowdown: pipe.slowdown,
        });
    }
    Ok(rows)
}

/// Print the figure and save its JSON result.
pub fn run() -> Result<()> {
    let gas_rows = compute_gas_sweep()?;
    let mut t = Table::new(vec!["GAS", "sync slowdown", "pipelined slowdown"]);
    for r in &gas_rows {
        t.row(vec![
            r.gas.to_string(),
            format!("{:.1}%", (r.sync_slowdown - 1.0) * 100.0),
            format!("{:.1}%", (r.pipe_slowdown - 1.0) * 100.0),
        ]);
    }
    println!("\n== Figure 11(a): GAS sensitivity, gpt3-1.3b DP=1 ==");
    println!("paper: pipelining better for GAS<64; ~8% slowdown at GAS=8\n{}", t.render());

    let model_rows = compute_model_sweep()?;
    let mut t2 = Table::new(vec!["model", "DP", "sync slowdown", "pipelined slowdown"]);
    for r in &model_rows {
        t2.row(vec![
            r.model.clone(),
            r.dp.to_string(),
            format!("{:.1}%", (r.sync_slowdown - 1.0) * 100.0),
            format!("{:.1}%", (r.pipe_slowdown - 1.0) * 100.0),
        ]);
    }
    println!("== Figure 11(b): per-iteration ckpt slowdown on 8 nodes ==");
    println!("paper: <5% for 1.3b-13b with pipelining\n{}", t2.render());

    let json = Json::obj(vec![
        (
            "gas_sweep",
            Json::arr(gas_rows.iter().map(|r| {
                Json::obj(vec![
                    ("gas", Json::from(r.gas as i64)),
                    ("sync_slowdown", Json::from(r.sync_slowdown)),
                    ("pipe_slowdown", Json::from(r.pipe_slowdown)),
                ])
            })),
        ),
        (
            "models",
            Json::arr(model_rows.iter().map(|r| {
                Json::obj(vec![
                    ("model", Json::str(&r.model)),
                    ("dp", Json::from(r.dp)),
                    ("sync_slowdown", Json::from(r.sync_slowdown)),
                    ("pipe_slowdown", Json::from(r.pipe_slowdown)),
                ])
            })),
        ),
    ]);
    super::save_result("fig11", &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipelining_wins_at_low_gas_converges_high() {
        let rows = compute_gas_sweep().unwrap();
        let low = &rows[0]; // GAS=1
        assert!(low.pipe_slowdown < low.sync_slowdown);
        let high = rows.last().unwrap(); // GAS=512
        assert!((high.sync_slowdown - high.pipe_slowdown).abs() < 0.05);
        // slowdown decreases monotonically with GAS
        assert!(rows.windows(2).all(|w| w[1].pipe_slowdown <= w[0].pipe_slowdown + 1e-9));
    }

    #[test]
    fn gas8_slowdown_near_paper() {
        // paper: ~8% at GAS=8 with pipelining
        let rows = compute_gas_sweep().unwrap();
        let r8 = rows.iter().find(|r| r.gas == 8).unwrap();
        assert!(
            r8.pipe_slowdown - 1.0 < 0.25,
            "gas8 pipelined slowdown {}",
            r8.pipe_slowdown
        );
    }

    #[test]
    fn models_under_5pct_with_pipelining() {
        for r in compute_model_sweep().unwrap() {
            if r.model != "gpt3-0.7b" {
                // paper's <5% claim covers 1.3b..13b
                assert!(r.pipe_slowdown < 1.05, "{}: {}", r.model, r.pipe_slowdown);
            }
            assert!(r.pipe_slowdown <= r.sync_slowdown + 1e-9);
        }
    }
}
