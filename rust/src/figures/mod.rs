//! Reproduction of every table and figure in the paper's evaluation
//! (`fastpersist repro <exp>`).
//!
//! Each module regenerates one experiment: it prints the paper's
//! rows/series next to our measured/simulated values and writes a JSON
//! result file under `results/`. Single-writer I/O experiments (Fig. 7
//! family) measure **real disk I/O**; cluster-scale experiments run on
//! the calibrated simulator (see ARCHITECTURE.md §1 for the substitution
//! argument).

pub mod fig1;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig2;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table1;

use crate::util::json::Json;
use crate::Result;

/// Where result JSON files land.
pub fn results_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("FASTPERSIST_RESULTS").unwrap_or_else(|_| "results".into()),
    )
}

/// Write one experiment's JSON result file.
pub fn save_result(name: &str, value: &Json) -> Result<()> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{name}.json")), value.to_string_pretty())?;
    Ok(())
}

/// Run every experiment (the `repro all` path).
pub fn run_all(fast: bool) -> Result<()> {
    fig1::run()?;
    fig2::run()?;
    table1::run()?;
    fig7::run(fast)?;
    fig8::run()?;
    fig9::run()?;
    fig10::run()?;
    fig11::run()?;
    fig12::run()?;
    Ok(())
}
