//! Figure 9: FastPersist on dense GPT-3 training at up to 128 GPUs —
//! (a) checkpoint speedup over baseline, (b) checkpoint throughput vs
//! DP, (c) end-to-end training speedup with per-iteration
//! checkpointing, (d) E2E speedup vs DP.
//!
//! Paper anchors @128 GPUs: ckpt speedups 28× (13b) … 116× (0.7b);
//! throughput up to 146 GB/s (80% of 8-node peak); E2E speedups 1.6×
//! (13b) … 21.8× (0.7b); speedup grows with DP.

use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::bandwidth::WritePath;
use crate::cluster::ClusterSpec;
use crate::model::gpt3::MODEL_ZOO;
use crate::sim::ckpt_sim::simulate_model_checkpoint;
use crate::sim::trainsim::{simulate_training, CkptMode};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};
use crate::Result;

/// One (model, DP) point of Figure 9.
pub struct Fig9Row {
    /// Model name.
    pub model: String,
    /// Data-parallel degree.
    pub dp: usize,
    /// Checkpoint-latency speedup over baseline.
    pub ckpt_speedup: f64,
    /// FastPersist aggregate throughput (decimal GB/s).
    pub fp_gbps: f64,
    /// End-to-end training speedup.
    pub e2e_speedup: f64,
}

/// Sweep DP degrees per model up to 128 GPUs.
pub fn compute() -> Result<Vec<Fig9Row>> {
    let spec = ClusterSpec::dgx2(8);
    let strat = WriterStrategy::PerSocket;
    let mut rows = Vec::new();
    for m in MODEL_ZOO.iter().filter(|m| m.dense) {
        let max_dp = 128 / m.mp();
        let mut dp = 1usize;
        while dp <= max_dp {
            let base = simulate_model_checkpoint(
                &spec, m, dp, WriterStrategy::Rank0, WritePath::Baseline,
            )?;
            // PerSocket writer selection: the paper's preferred subset
            // for large-scale DP (§5.3.2) — avoids the Replica
            // degradation when many ranks share a node.
            let fp = simulate_model_checkpoint(
                &spec, m, dp, WriterStrategy::PerSocket, WritePath::FastPersist,
            )?;
            let base_train = simulate_training(&spec, m, dp, 1, CkptMode::Baseline)?;
            let fp_train = simulate_training(&spec, m, dp, 1, CkptMode::Pipelined(strat))?;
            rows.push(Fig9Row {
                model: m.name.to_string(),
                dp,
                ckpt_speedup: base.result.latency_s / fp.result.latency_s,
                fp_gbps: fp.result.agg_gbps,
                e2e_speedup: base_train.iter / fp_train.iter,
            });
            dp *= 2;
        }
    }
    Ok(rows)
}

/// Print the figure and save its JSON result.
pub fn run() -> Result<()> {
    let rows = compute()?;
    let mut t = Table::new(vec!["model", "DP", "GPUs", "ckpt speedup", "FP GB/s", "E2E speedup"]);
    for r in &rows {
        let gpus = r.dp
            * MODEL_ZOO.iter().find(|m| m.name == r.model).unwrap().mp();
        t.row(vec![
            r.model.clone(),
            r.dp.to_string(),
            gpus.to_string(),
            format!("{:.1}x", r.ckpt_speedup),
            fnum(r.fp_gbps),
            format!("{:.1}x", r.e2e_speedup),
        ]);
    }
    println!("\n== Figure 9: dense models on up to 128 GPUs (simulated cluster) ==");
    println!("paper @128 GPUs: ckpt 28x..116x; up to 146 GB/s; E2E 1.6x..21.8x\n{}", t.render());
    let json = Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("model", Json::str(&r.model)),
            ("dp", Json::from(r.dp)),
            ("ckpt_speedup", Json::from(r.ckpt_speedup)),
            ("fp_gbps", Json::from(r.fp_gbps)),
            ("e2e_speedup", Json::from(r.e2e_speedup)),
        ])
    }));
    super::save_result("fig9", &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at_128(rows: &[Fig9Row], model: &str) -> Fig9Row {
        let mp = MODEL_ZOO.iter().find(|m| m.name == model).unwrap().mp();
        let dp = 128 / mp;
        rows.iter()
            .find(|r| r.model == model && r.dp == dp)
            .map(|r| Fig9Row {
                model: r.model.clone(),
                dp: r.dp,
                ckpt_speedup: r.ckpt_speedup,
                fp_gbps: r.fp_gbps,
                e2e_speedup: r.e2e_speedup,
            })
            .unwrap()
    }

    #[test]
    fn ckpt_speedups_bracket_paper_range() {
        let rows = compute().unwrap();
        let small = at_128(&rows, "gpt3-0.7b");
        let large = at_128(&rows, "gpt3-13b");
        assert!(small.ckpt_speedup > large.ckpt_speedup);
        assert!(small.ckpt_speedup > 50.0, "0.7b: {}", small.ckpt_speedup);
        assert!(large.ckpt_speedup > 10.0 && large.ckpt_speedup < 80.0,
            "13b: {}", large.ckpt_speedup);
    }

    #[test]
    fn throughput_scales_with_dp_per_model() {
        // Paper Fig. 9(b): throughput scales with DP. Our contention
        // model allows small dips while DP grows *within* one node
        // (more writers, same RAID volume — the Fig. 8 Replica effect),
        // so require near-monotonicity plus strong overall scaling.
        let rows = compute().unwrap();
        for m in MODEL_ZOO.iter().filter(|m| m.dense) {
            let series: Vec<f64> = rows
                .iter()
                .filter(|r| r.model == m.name)
                .map(|r| r.fp_gbps)
                .collect();
            assert!(
                series.windows(2).all(|w| w[1] >= w[0] * 0.8),
                "{}: {series:?}",
                m.name
            );
            if series.len() >= 3 {
                let overall = series.last().unwrap() / series.first().unwrap();
                assert!(overall > 3.0, "{}: overall scaling {overall}", m.name);
            }
        }
    }

    #[test]
    fn large_models_reach_high_throughput() {
        // paper: 146 GB/s for 13b (80% of 8-node peak)
        let rows = compute().unwrap();
        let r = at_128(&rows, "gpt3-13b");
        assert!(r.fp_gbps > 100.0, "{}", r.fp_gbps);
    }

    #[test]
    fn e2e_speedups_ordered_and_in_range() {
        let rows = compute().unwrap();
        let small = at_128(&rows, "gpt3-0.7b");
        let large = at_128(&rows, "gpt3-13b");
        assert!(small.e2e_speedup > 8.0 && small.e2e_speedup < 60.0,
            "0.7b: {}", small.e2e_speedup);
        assert!(large.e2e_speedup > 1.05 && large.e2e_speedup < 4.0,
            "13b: {}", large.e2e_speedup);
    }

    #[test]
    fn e2e_speedup_grows_with_dp() {
        let rows = compute().unwrap();
        let series: Vec<f64> = rows
            .iter()
            .filter(|r| r.model == "gpt3-0.7b")
            .map(|r| r.e2e_speedup)
            .collect();
        assert!(series.windows(2).all(|w| w[1] > w[0]), "{series:?}");
    }
}
