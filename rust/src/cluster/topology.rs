//! Rank topology: how DP/TP/PP/EP ranks map onto nodes, sockets, GPUs.
//!
//! Conventions (matching Megatron/DeepSpeed-style launchers, §2.1.1):
//! ranks are dense, consecutive ranks fill a node before spilling to the
//! next, and a model replica occupies `mp = tp*pp*ep` *consecutive*
//! ranks. Replica `d` therefore holds ranks `[d*mp, (d+1)*mp)`; the DP
//! group of model-slice `s` is `{ d*mp + s : d in 0..dp }` — one rank
//! per replica, spread across the machines. That spread is exactly the
//! parallel I/O FastPersist's write parallelism harvests (§4.2).

use crate::cluster::ClusterSpec;
use crate::{Error, Result};

/// Parallelism degrees of a training job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Data parallelism (model replicas).
    pub dp: usize,
    /// Tensor parallelism.
    pub tp: usize,
    /// Pipeline parallelism.
    pub pp: usize,
    /// Expert parallelism (MoE); 1 for dense models.
    pub ep: usize,
}

impl Parallelism {
    /// Dense-model degrees (no expert parallelism).
    pub fn dense(dp: usize, tp: usize, pp: usize) -> Parallelism {
        Parallelism { dp, tp, pp, ep: 1 }
    }

    /// Model-parallel degree: ranks per model replica.
    pub fn mp(&self) -> usize {
        self.tp * self.pp * self.ep
    }

    /// Total rank count.
    pub fn world(&self) -> usize {
        self.dp * self.mp()
    }
}

/// Physical placement of one rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankPlacement {
    /// Global rank id.
    pub rank: usize,
    /// Machine index.
    pub node: usize,
    /// CPU socket index within the node.
    pub socket: usize,
    /// GPU index within the node.
    pub local_gpu: usize,
}

/// A concrete mapping of a job's ranks onto a cluster.
#[derive(Debug, Clone)]
pub struct Topology {
    /// The physical cluster.
    pub spec: ClusterSpec,
    /// The job's parallelism degrees.
    pub par: Parallelism,
}

impl Topology {
    /// Validate that the job fits the cluster.
    pub fn new(spec: ClusterSpec, par: Parallelism) -> Result<Topology> {
        if par.dp == 0 || par.tp == 0 || par.pp == 0 || par.ep == 0 {
            return Err(Error::Config("parallelism degrees must be >= 1".into()));
        }
        if par.world() > spec.total_gpus() {
            return Err(Error::Config(format!(
                "world size {} exceeds cluster GPUs {}",
                par.world(),
                spec.total_gpus()
            )));
        }
        Ok(Topology { spec, par })
    }

    /// Total rank count of the job.
    pub fn world(&self) -> usize {
        self.par.world()
    }

    /// Physical placement of `rank` (dense fill, node-major).
    pub fn placement(&self, rank: usize) -> RankPlacement {
        assert!(rank < self.world(), "rank {rank} out of range");
        let node = rank / self.spec.gpus_per_node;
        let local_gpu = rank % self.spec.gpus_per_node;
        let socket = local_gpu / self.spec.gpus_per_socket();
        RankPlacement { rank, node, socket, local_gpu }
    }

    /// The DP group (one rank per replica) owning model slice `slice`.
    pub fn dp_group(&self, slice: usize) -> Vec<RankPlacement> {
        assert!(slice < self.par.mp(), "slice {slice} out of range");
        (0..self.par.dp)
            .map(|d| self.placement(d * self.par.mp() + slice))
            .collect()
    }

    /// Number of model slices (= checkpoint files per checkpoint).
    pub fn slices(&self) -> usize {
        self.par.mp()
    }

    /// Ranks per node that belong to the given set (node -> count).
    pub fn per_node_counts(&self, ranks: &[RankPlacement]) -> Vec<usize> {
        let mut counts = vec![0usize; self.spec.nodes];
        for r in ranks {
            counts[r.node] += 1;
        }
        counts
    }

    /// Distinct (node, socket) pairs covered by the given ranks.
    pub fn socket_coverage(&self, ranks: &[RankPlacement]) -> usize {
        let mut seen = std::collections::BTreeSet::new();
        for r in ranks {
            seen.insert((r.node, r.socket));
        }
        seen.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(nodes: usize, dp: usize, tp: usize, pp: usize) -> Topology {
        Topology::new(ClusterSpec::dgx2(nodes), Parallelism::dense(dp, tp, pp)).unwrap()
    }

    #[test]
    fn placement_dense_fill() {
        let t = topo(2, 2, 16, 1);
        let p0 = t.placement(0);
        assert_eq!((p0.node, p0.socket, p0.local_gpu), (0, 0, 0));
        let p8 = t.placement(8);
        assert_eq!((p8.node, p8.socket), (0, 1)); // second socket
        let p16 = t.placement(16);
        assert_eq!((p16.node, p16.local_gpu), (1, 0));
    }

    #[test]
    fn dp_group_is_one_rank_per_replica() {
        // gpt3-13b-like: mp=16, one replica per DGX-2 node
        let t = topo(8, 8, 16, 1);
        let g = t.dp_group(3);
        assert_eq!(g.len(), 8);
        for (d, p) in g.iter().enumerate() {
            assert_eq!(p.rank, d * 16 + 3);
            assert_eq!(p.node, d); // each replica on its own node
        }
    }

    #[test]
    fn dp_group_small_mp_shares_nodes() {
        // mp=1: all DP ranks of slice 0 = all ranks
        let t = topo(1, 16, 1, 1);
        let g = t.dp_group(0);
        assert_eq!(g.len(), 16);
        assert!(g.iter().all(|p| p.node == 0));
        assert_eq!(t.socket_coverage(&g), 2);
    }

    #[test]
    fn world_size_validation() {
        assert!(Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(2, 16, 1)).is_err());
        assert!(Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(0, 1, 1)).is_err());
    }

    #[test]
    fn moe_parallelism_counts() {
        // 1.8B-MoE: EP=16, DP<=8 on 8 nodes (paper §5.5)
        let par = Parallelism { dp: 8, tp: 1, pp: 1, ep: 16 };
        assert_eq!(par.mp(), 16);
        assert_eq!(par.world(), 128);
        let t = Topology::new(ClusterSpec::dgx2(8), par).unwrap();
        assert_eq!(t.slices(), 16);
        assert_eq!(t.dp_group(0).len(), 8);
    }

    #[test]
    fn per_node_counts_sum() {
        let t = topo(4, 4, 8, 1);
        let g = t.dp_group(5);
        let counts = t.per_node_counts(&g);
        assert_eq!(counts.iter().sum::<usize>(), g.len());
    }
}
