//! Hardware specification of the evaluation cluster (paper §5.2.1) plus
//! the calibrated I/O-path constants (ARCHITECTURE.md §8).

/// Physical description of one homogeneous cluster.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Machine count.
    pub nodes: usize,
    /// GPUs per machine.
    pub gpus_per_node: usize,
    /// CPU sockets per machine.
    pub sockets_per_node: usize,
    /// Local NVMe RAID-0 peak write bandwidth per node, GB/s (decimal).
    pub node_write_gbps: f64,
    /// NVMe SSDs per node (RAID-0 members).
    pub ssds_per_node: usize,
    // ---- calibrated write-path constants ------------------------------
    /// FastPersist single-writer asymptotic rate, GB/s — bounded by the
    /// PCIe D2H staging hop (paper Fig. 7: 10.9 GB/s at 512 MB).
    pub fp_single_max_gbps: f64,
    /// Write-size half-saturation constant, bytes: per-writer efficiency
    /// = w / (w + half). Fit to Fig. 7 (16 MB → 5.18, 512 MB → 10.9).
    pub fp_size_half: f64,
    /// Per-checkpoint fixed overhead for a FastPersist writer, seconds
    /// (launch + file create + final fsync). Fit to Fig. 8's 8-node
    /// aggregate (129.8 GB/s at 16 writers over 10 GB).
    pub fp_overhead_s: f64,
    /// Node-level contention: capacity factor 1/(1 + c*(k-1)) for k
    /// concurrent direct writers on one node. Fit to Fig. 8.
    pub fp_contention: f64,
    /// Baseline (torch.save) single-writer rate, GB/s (Fig. 2: ~3% of
    /// the 24.8 GB/s node peak).
    pub base_single_gbps: f64,
    /// Baseline per-writer degradation with k writers per node:
    /// rate / (1 + c*(k-1)). Fit to Fig. 2 (16 writers → ~7× single).
    pub base_contention: f64,
    /// Baseline fixed overhead per checkpoint, seconds (serialization
    /// setup, allocator traffic).
    pub base_overhead_s: f64,
}

impl ClusterSpec {
    /// The paper's testbed: 8× DGX-2 (16 V100-32GB each), 8 local NVMe
    /// SSDs per node in RAID-0 with 24.8 GB/s peak write.
    pub fn dgx2(nodes: usize) -> ClusterSpec {
        ClusterSpec {
            nodes,
            gpus_per_node: 16,
            sockets_per_node: 2,
            node_write_gbps: 24.8,
            ssds_per_node: 8,
            fp_single_max_gbps: 11.3,
            fp_size_half: 18.0 * 1e6,
            fp_overhead_s: 0.020,
            fp_contention: 0.04,
            base_single_gbps: 0.744, // 3% of 24.8
            base_contention: 0.085,
            base_overhead_s: 0.120,
        }
    }

    /// GPUs in the whole cluster.
    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Cluster-wide peak write bandwidth, GB/s.
    pub fn cluster_write_gbps(&self) -> f64 {
        self.nodes as f64 * self.node_write_gbps
    }

    /// GPUs attached to each CPU socket.
    pub fn gpus_per_socket(&self) -> usize {
        self.gpus_per_node / self.sockets_per_node
    }

    /// FastPersist per-writer streaming rate for one `write_size`-byte
    /// partition, GB/s, before node contention.
    pub fn fp_writer_gbps(&self, write_size: u64) -> f64 {
        let w = write_size as f64;
        self.fp_single_max_gbps * (w / (w + self.fp_size_half))
    }

    /// Node capacity with `k` concurrent FastPersist writers, GB/s.
    pub fn fp_node_capacity_gbps(&self, k: usize) -> f64 {
        if k == 0 {
            return 0.0;
        }
        self.node_write_gbps / (1.0 + self.fp_contention * (k as f64 - 1.0))
    }

    /// Baseline per-writer rate with `k` baseline writers on the node.
    pub fn base_writer_gbps(&self, k: usize) -> f64 {
        self.base_single_gbps / (1.0 + self.base_contention * (k.max(1) as f64 - 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dgx2_shape() {
        let c = ClusterSpec::dgx2(8);
        assert_eq!(c.total_gpus(), 128);
        assert_eq!(c.gpus_per_socket(), 8);
        assert!((c.cluster_write_gbps() - 198.4).abs() < 1e-9);
    }

    #[test]
    fn writer_rate_matches_fig7_anchors() {
        let c = ClusterSpec::dgx2(1);
        // 16 MB → ~5.2 GB/s, 512 MB → ~10.9 GB/s (paper Fig. 7)
        let r16 = c.fp_writer_gbps(16 * 1_000_000);
        let r512 = c.fp_writer_gbps(512 * 1_000_000);
        assert!((r16 - 5.18).abs() < 0.3, "r16={r16}");
        assert!((r512 - 10.9).abs() < 0.3, "r512={r512}");
        // monotone in write size
        assert!(c.fp_writer_gbps(1 << 20) < r16);
        assert!(r16 < r512);
    }

    #[test]
    fn baseline_matches_fig2_anchors() {
        let c = ClusterSpec::dgx2(1);
        // single writer ~3% of node peak
        assert!((c.base_writer_gbps(1) / c.node_write_gbps - 0.03).abs() < 0.005);
        // 16 writers → aggregate ~7x single (Fig. 2 gpt3-13b vs 0.7b)
        let agg16 = 16.0 * c.base_writer_gbps(16);
        let ratio = agg16 / c.base_writer_gbps(1);
        assert!((ratio - 7.0).abs() < 1.0, "ratio={ratio}");
    }

    #[test]
    fn contention_reduces_capacity() {
        let c = ClusterSpec::dgx2(1);
        assert!(c.fp_node_capacity_gbps(1) > c.fp_node_capacity_gbps(4));
        assert!(c.fp_node_capacity_gbps(4) > c.fp_node_capacity_gbps(16));
        assert_eq!(c.fp_node_capacity_gbps(0), 0.0);
    }
}
