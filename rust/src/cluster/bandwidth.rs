//! Calibrated storage-bandwidth model: checkpoint write latency for a
//! set of parallel writers on the simulated cluster.
//!
//! The model captures the three effects the paper's multi-node results
//! hinge on (§3.1, §4.2, Fig. 8):
//!
//! 1. **Write-size efficiency** — per-writer streaming rate rises with
//!    partition size (small writes are inefficient).
//! 2. **Node-level contention** — k concurrent writers on one node see
//!    the RAID volume's effective capacity shrink.
//! 3. **Fixed per-checkpoint overhead** — launch/create/fsync latency
//!    that dominates tiny partitions and caps useful parallelism.
//!
//! Checkpoint latency = max over writers of per-writer time; writers on
//! an over-subscribed node are slowed proportionally (fair sharing).

use crate::cluster::topology::RankPlacement;
use crate::cluster::ClusterSpec;

/// Which write path a simulated writer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WritePath {
    /// torch.save-class buffered writes.
    Baseline,
    /// FastPersist NVMe path (aligned direct + double buffer).
    FastPersist,
}

/// One writer's assignment: where it runs and how many bytes it writes.
#[derive(Debug, Clone, Copy)]
pub struct WriterLoad {
    /// Machine the writer runs on.
    pub node: usize,
    /// CPU socket the writer runs on.
    pub socket: usize,
    /// Bytes this writer persists.
    pub bytes: u64,
}

impl WriterLoad {
    /// A load at a rank's physical placement.
    pub fn from_placement(p: &RankPlacement, bytes: u64) -> WriterLoad {
        WriterLoad { node: p.node, socket: p.socket, bytes }
    }
}

/// Result of a simulated parallel checkpoint write.
#[derive(Debug, Clone, Copy)]
pub struct SimWrite {
    /// Wall latency of the slowest writer (checkpoint completion).
    pub latency_s: f64,
    /// Aggregate achieved throughput, GB/s.
    pub agg_gbps: f64,
    /// Fraction of the participating nodes' peak bandwidth achieved.
    pub peak_frac: f64,
}

/// Simulate one parallel checkpoint write.
///
/// `writers` may span several nodes; all are assumed to start
/// simultaneously (the paper's communication-free partitioning, §4.2).
pub fn simulate_write(spec: &ClusterSpec, path: WritePath, writers: &[WriterLoad]) -> SimWrite {
    if writers.is_empty() || writers.iter().all(|w| w.bytes == 0) {
        return SimWrite { latency_s: 0.0, agg_gbps: 0.0, peak_frac: 0.0 };
    }
    // group writers by node
    let mut by_node: std::collections::BTreeMap<usize, Vec<&WriterLoad>> = Default::default();
    for w in writers {
        by_node.entry(w.node).or_default().push(w);
    }
    let mut latency: f64 = 0.0;
    for (_node, ws) in &by_node {
        let k = ws.len();
        let node_latency = match path {
            WritePath::FastPersist => {
                // per-writer demanded rate (GB/s) from write size
                let demands: Vec<f64> =
                    ws.iter().map(|w| spec.fp_writer_gbps(w.bytes)).collect();
                let total_demand: f64 = demands.iter().sum();
                let capacity = spec.fp_node_capacity_gbps(k);
                // fair-share slowdown if the node is oversubscribed
                let scale = if total_demand > capacity { capacity / total_demand } else { 1.0 };
                ws.iter()
                    .zip(&demands)
                    .map(|(w, d)| spec.fp_overhead_s + w.bytes as f64 / 1e9 / (d * scale))
                    .fold(0.0, f64::max)
            }
            WritePath::Baseline => {
                // buffered path: contention degrades each writer directly
                let rate = spec.base_writer_gbps(k);
                ws.iter()
                    .map(|w| spec.base_overhead_s + w.bytes as f64 / 1e9 / rate)
                    .fold(0.0, f64::max)
            }
        };
        latency = latency.max(node_latency);
    }
    let total_bytes: u64 = writers.iter().map(|w| w.bytes).sum();
    let agg_gbps = total_bytes as f64 / 1e9 / latency;
    let nodes_used = by_node.len();
    let peak = nodes_used as f64 * spec.node_write_gbps;
    SimWrite { latency_s: latency, agg_gbps, peak_frac: agg_gbps / peak }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ClusterSpec {
        ClusterSpec::dgx2(8)
    }

    fn even_writers(nodes: usize, per_node: usize, total_bytes: u64) -> Vec<WriterLoad> {
        let n = nodes * per_node;
        let each = total_bytes / n as u64;
        (0..n)
            .map(|i| WriterLoad { node: i % nodes, socket: (i / nodes) % 2, bytes: each })
            .collect()
    }

    #[test]
    fn single_fastpersist_writer_near_fig7() {
        // 10 GB from one writer: dominated by the 10.9 GB/s streaming rate
        let w = [WriterLoad { node: 0, socket: 0, bytes: 10_000_000_000 }];
        let r = simulate_write(&spec(), WritePath::FastPersist, &w);
        assert!((r.agg_gbps - 11.0).abs() < 0.8, "agg={}", r.agg_gbps);
    }

    #[test]
    fn single_baseline_writer_is_3pct() {
        let w = [WriterLoad { node: 0, socket: 0, bytes: 10_000_000_000 }];
        let r = simulate_write(&spec(), WritePath::Baseline, &w);
        assert!((r.agg_gbps - 0.74).abs() < 0.05, "agg={}", r.agg_gbps);
        assert!(r.peak_frac < 0.04);
    }

    #[test]
    fn two_node_parallel_write_near_fig8() {
        // Fig. 8(a): 10 GB over 8 writers on 2 nodes → ~41.8 GB/s
        let w = even_writers(2, 4, 10_000_000_000);
        let r = simulate_write(&spec(), WritePath::FastPersist, &w);
        assert!(r.agg_gbps > 35.0 && r.agg_gbps < 50.0, "agg={}", r.agg_gbps);
        assert!(r.peak_frac > 0.7, "frac={}", r.peak_frac);
    }

    #[test]
    fn eight_node_socket_write_near_fig8() {
        // Fig. 8(b): 10 GB over 16 writers (2/node, one per socket) on 8
        // nodes → ~130 GB/s
        let w = even_writers(8, 2, 10_000_000_000);
        let r = simulate_write(&spec(), WritePath::FastPersist, &w);
        assert!(r.agg_gbps > 100.0 && r.agg_gbps < 175.0, "agg={}", r.agg_gbps);
    }

    #[test]
    fn oversubscription_degrades() {
        // 16 writers/node on 8 nodes should NOT beat 2/node on the same
        // data (Fig. 8(b): Replica declines past the sweet spot).
        let total = 10_000_000_000;
        let few = simulate_write(&spec(), WritePath::FastPersist, &even_writers(8, 2, total));
        let many = simulate_write(&spec(), WritePath::FastPersist, &even_writers(8, 16, total));
        assert!(few.agg_gbps > many.agg_gbps, "few={} many={}", few.agg_gbps, many.agg_gbps);
    }

    #[test]
    fn more_nodes_scale_throughput() {
        let total = 10_000_000_000;
        let n1 = simulate_write(&spec(), WritePath::FastPersist, &even_writers(1, 4, total));
        let n4 = simulate_write(&spec(), WritePath::FastPersist, &even_writers(4, 4, total));
        assert!(n4.agg_gbps > 2.5 * n1.agg_gbps);
    }

    #[test]
    fn empty_writers() {
        let r = simulate_write(&spec(), WritePath::FastPersist, &[]);
        assert_eq!(r.latency_s, 0.0);
    }

    #[test]
    fn prop_latency_covers_every_writer() {
        crate::prop::forall("sim latency >= any single-writer time", 64, |g| {
            let s = spec();
            let n = g.usize(1, 12);
            let writers: Vec<WriterLoad> = (0..n)
                .map(|_| WriterLoad {
                    node: g.usize(0, 7),
                    socket: g.usize(0, 1),
                    bytes: g.u64(1, 1 << 34),
                })
                .collect();
            let r = simulate_write(&s, WritePath::FastPersist, &writers);
            // a writer alone can never be slower than in the group write
            writers.iter().all(|w| {
                let solo = simulate_write(&s, WritePath::FastPersist, &[*w]);
                r.latency_s >= solo.latency_s - 1e-9
            })
        });
    }
}
