//! Cluster substrate: hardware topology + calibrated storage bandwidth
//! model for the paper-scale (8× DGX-2) experiments.
//!
//! The checkpoint engine itself only needs [`topology`] (where each rank
//! lives, for writer selection). The [`bandwidth`] model feeds the
//! discrete-event simulator ([`crate::sim`]) that reproduces the
//! multi-node figures; its constants are calibrated to numbers the paper
//! states directly (see ARCHITECTURE.md §8).

pub mod bandwidth;
pub mod spec;
pub mod topology;

pub use spec::ClusterSpec;
pub use topology::{Parallelism, RankPlacement, Topology};
