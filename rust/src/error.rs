//! Crate-wide error type.

use std::fmt;

/// Unified error for the fastpersist crate.
#[derive(Debug)]
pub enum Error {
    /// Underlying I/O failure (file, O_DIRECT, etc).
    Io(std::io::Error),
    /// PJRT / XLA runtime failure.
    Xla(String),
    /// Malformed JSON (manifest, config files).
    Json { msg: String, offset: usize },
    /// Checkpoint format violation (bad magic, truncated, digest mismatch).
    Format(String),
    /// Invalid configuration or argument.
    Config(String),
    /// Internal invariant violation.
    Internal(String),
    /// An injected fault fired (deterministic fault-injection layer,
    /// [`crate::io::fault`]); only ever produced when a `FaultPlan` is
    /// installed, i.e. under test.
    FaultTripped(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Json { msg, offset } => {
                write!(f, "json error at byte {offset}: {msg}")
            }
            Error::Format(m) => write!(f, "checkpoint format error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Internal(m) => write!(f, "internal error: {m}"),
            Error::FaultTripped(m) => write!(f, "injected fault: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// `bail!`-style helper for config errors.
#[macro_export]
macro_rules! config_err {
    ($($arg:tt)*) => {
        return Err($crate::Error::Config(format!($($arg)*)))
    };
}

/// `bail!`-style helper for internal invariant violations.
#[macro_export]
macro_rules! internal_err {
    ($($arg:tt)*) => {
        return Err($crate::Error::Internal(format!($($arg)*)))
    };
}
