//! Model zoo + analytic iteration-time model for the paper's workloads.

pub mod gpt3;

pub use gpt3::{GptModel, IterBreakdown, MODEL_ZOO};
