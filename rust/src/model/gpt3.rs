//! GPT-3 model zoo (paper Table 2) and the analytic V100 iteration-time
//! model used by the cluster simulator.
//!
//! Checkpoint sizes are the paper's Table 2 values. Iteration time uses
//! a FLOPs model (6·N·tokens) with a V100 MFU curve composed of a base
//! utilization, a small-micro-batch penalty, and the pipeline-parallel
//! bubble — fitted so that the paper's Table 1 required-bandwidth values
//! are reproduced to the right order and trend.

use crate::cluster::topology::Parallelism;

/// V100 fp16 peak, FLOPs/s.
pub const V100_PEAK_FLOPS: f64 = 125e12;
/// Base model FLOPs utilization at large batch (fitted).
pub const MFU_BASE: f64 = 0.30;
/// Micro-batch tokens-per-GPU at which MFU reaches half of base.
pub const MFU_TOKENS_HALF: f64 = 1024.0;
/// Training sequence length for all GPT-3 configs.
pub const SEQ_LEN: u64 = 2048;
/// Adam optimizer step: bytes touched per parameter (p, g, m, v r/w).
pub const OPT_BYTES_PER_PARAM: f64 = 32.0;
/// V100 HBM2 bandwidth, B/s.
pub const V100_HBM_BPS: f64 = 900e9;

/// One evaluation model (paper Table 2).
#[derive(Debug, Clone)]
pub struct GptModel {
    /// Model name (paper Table 2).
    pub name: &'static str,
    /// Total parameters.
    pub params: u64,
    /// Parameters active per token (== params for dense; for MoE, the
    /// non-expert + one-expert share).
    pub active_params: u64,
    /// True for dense models, false for MoE.
    pub dense: bool,
    /// Tensor-parallel degree.
    pub tp: usize,
    /// Pipeline-parallel degree.
    pub pp: usize,
    /// Expert-parallel degree (1 for dense).
    pub ep: usize,
    /// Published global batch size.
    pub gbs: u64,
    /// Checkpoint size, bytes (paper Table 2, decimal GB).
    pub ckpt_bytes: u64,
}

impl GptModel {
    /// Model-parallel degree: ranks per replica.
    pub fn mp(&self) -> usize {
        self.tp * self.pp * self.ep
    }

    /// The job's [`Parallelism`] at data-parallel degree `dp`.
    pub fn parallelism(&self, dp: usize) -> Parallelism {
        Parallelism { dp, tp: self.tp, pp: self.pp, ep: self.ep }
    }

    /// FLOPs for one full iteration (fwd 2·N·T + bwd 4·N·T).
    pub fn flops_per_iter(&self) -> f64 {
        6.0 * self.active_params as f64 * self.gbs as f64 * SEQ_LEN as f64
    }

    /// Effective MFU for a given micro-batch shape.
    fn mfu(&self, micro_batch: f64, ga: u64) -> f64 {
        // per-GPU tokens in one micro-batch (model split over mp GPUs)
        let tokens_per_gpu = micro_batch * SEQ_LEN as f64 / self.mp() as f64;
        let batch_penalty = tokens_per_gpu / (tokens_per_gpu + MFU_TOKENS_HALF);
        let pipe_eff = ga as f64 / (ga as f64 + self.pp as f64 - 1.0);
        MFU_BASE * batch_penalty * pipe_eff
    }

    /// Forward+backward wall time for one iteration at `dp`, `ga`.
    pub fn fb_time(&self, dp: usize, ga: u64) -> f64 {
        let micro_batch = self.gbs as f64 / dp as f64 / ga as f64;
        let gpus = (dp * self.mp()) as f64;
        self.flops_per_iter() / (gpus * V100_PEAK_FLOPS * self.mfu(micro_batch, ga))
    }

    /// Forward+backward wall time with a **fixed micro-batch** and `ga`
    /// accumulation steps (per-replica batch = mb·ga — the §5.6.1 GAS
    /// sweep, where more GAS means more compute per optimizer step).
    pub fn fb_time_fixed_micro(&self, mb: u64, ga: u64) -> f64 {
        let flops_per_micro =
            6.0 * self.active_params as f64 * mb as f64 * SEQ_LEN as f64;
        let per_gpu = flops_per_micro / self.mp() as f64;
        ga as f64 * per_gpu / (V100_PEAK_FLOPS * self.mfu(mb as f64, ga))
    }

    /// Optimizer (Adam) step wall time: HBM-bandwidth bound over the
    /// per-GPU parameter shard.
    pub fn opt_time(&self) -> f64 {
        let params_per_gpu = self.params as f64 / self.mp() as f64;
        params_per_gpu * OPT_BYTES_PER_PARAM / V100_HBM_BPS
    }

    /// Full iteration time (compute only, no checkpoint).
    pub fn iter_time(&self, dp: usize, ga: u64) -> IterBreakdown {
        let fb = self.fb_time(dp, ga);
        let opt = self.opt_time();
        IterBreakdown { fb, opt }
    }

    /// Eq. 1: minimum write bandwidth (GB/s) for checkpoint creation to
    /// hide entirely behind the next iteration's forward+backward.
    pub fn required_bc_gbps(&self, dp: usize, ga: u64) -> f64 {
        self.ckpt_bytes as f64 / 1e9 / self.fb_time(dp, ga)
    }

    /// Eq. 2: expected GPU-seconds lost per interruption when
    /// checkpointing every `n` iterations with `m` GPUs.
    pub fn recovery_cost_gpu_secs(&self, n: u64, m: usize, iter_secs: f64) -> f64 {
        n as f64 / 2.0 * m as f64 * iter_secs
    }

    /// Largest valid DP for the published GBS (micro-batch >= 1).
    pub fn max_dp(&self) -> usize {
        self.gbs as usize
    }
}

/// Compute-time breakdown of one iteration.
#[derive(Debug, Clone, Copy)]
pub struct IterBreakdown {
    /// Forward + backward seconds.
    pub fb: f64,
    /// Optimizer seconds.
    pub opt: f64,
}

impl IterBreakdown {
    /// Total iteration seconds (F+B + optimizer).
    pub fn total(&self) -> f64 {
        self.fb + self.opt
    }
}

/// The paper's Table 2 (five dense GPT-3 models + the 1.8B MoE).
pub const MODEL_ZOO: &[GptModel] = &[
    GptModel {
        name: "gpt3-0.7b",
        params: 700_000_000,
        active_params: 700_000_000,
        dense: true,
        tp: 1,
        pp: 1,
        ep: 1,
        gbs: 256,
        ckpt_bytes: 10_000_000_000,
    },
    GptModel {
        name: "gpt3-1.3b",
        params: 1_300_000_000,
        active_params: 1_300_000_000,
        dense: true,
        tp: 2,
        pp: 1,
        ep: 1,
        gbs: 512,
        ckpt_bytes: 17_000_000_000,
    },
    GptModel {
        name: "gpt3-2.7b",
        params: 2_700_000_000,
        active_params: 2_700_000_000,
        dense: true,
        tp: 4,
        pp: 1,
        ep: 1,
        gbs: 512,
        ckpt_bytes: 35_000_000_000,
    },
    GptModel {
        name: "gpt3-6.7b",
        params: 6_700_000_000,
        active_params: 6_700_000_000,
        dense: true,
        tp: 8,
        pp: 1,
        ep: 1,
        gbs: 1024,
        ckpt_bytes: 88_000_000_000,
    },
    GptModel {
        name: "gpt3-13b",
        params: 13_000_000_000,
        active_params: 13_000_000_000,
        dense: true,
        tp: 8,
        pp: 2,
        ep: 1,
        gbs: 1024,
        ckpt_bytes: 173_000_000_000,
    },
    GptModel {
        name: "gpt3-1.8b-moe",
        params: 1_800_000_000,
        // non-expert trunk + a single expert's share per token
        active_params: 450_000_000,
        dense: false,
        tp: 1,
        pp: 1,
        ep: 16,
        gbs: 256,
        ckpt_bytes: 67_000_000_000,
    },
];

/// Look up a zoo model by name.
pub fn find(name: &str) -> Option<&'static GptModel> {
    MODEL_ZOO.iter().find(|m| m.name == name)
}

/// The 13B variant with pipeline parallelism replaced by full TP over 16
/// GPUs (paper §5.7's "full TP" projection).
pub fn gpt3_13b_full_tp() -> GptModel {
    GptModel { tp: 16, pp: 1, ..find("gpt3-13b").unwrap().clone() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_matches_table2() {
        assert_eq!(MODEL_ZOO.len(), 6);
        let mps: Vec<usize> = MODEL_ZOO.iter().map(|m| m.mp()).collect();
        assert_eq!(mps, vec![1, 2, 4, 8, 16, 16]);
        let ckpt_gb: Vec<u64> = MODEL_ZOO.iter().map(|m| m.ckpt_bytes / 1_000_000_000).collect();
        assert_eq!(ckpt_gb, vec![10, 17, 35, 88, 173, 67]);
    }

    #[test]
    fn ckpt_size_tracks_14_bytes_per_param() {
        // §2.1.3: mixed-precision Adam checkpoints ≈ 14 B/param (dense).
        for m in MODEL_ZOO.iter().filter(|m| m.dense) {
            let ratio = m.ckpt_bytes as f64 / m.params as f64;
            assert!((ratio - 13.5).abs() < 1.5, "{}: {ratio}", m.name);
        }
    }

    #[test]
    fn fb_time_scales_down_with_dp() {
        let m = find("gpt3-1.3b").unwrap();
        let t8 = m.fb_time(8, 1);
        let t64 = m.fb_time(64, 1);
        // ~7x compute reduction for 8x DP (Fig. 1: "~7X Compute
        // reduction ... with DP scaling of 8 to 64") — sublinear because
        // MFU drops with the smaller micro-batch.
        let ratio = t8 / t64;
        assert!(ratio > 5.0 && ratio <= 8.0, "ratio={ratio}");
    }

    #[test]
    fn required_bc_in_table1_regime() {
        // Table 1 anchors (GB/s): 34, 59, 81, 160, 28. Our analytic model
        // reproduces the order of magnitude and the rise-then-drop trend
        // (13B drops due to PP bubble + tiny per-GPU micro-batch).
        let cases = [
            ("gpt3-0.7b", 256, 34.0),
            ("gpt3-1.3b", 512, 59.0),
            ("gpt3-2.7b", 512, 81.0),
            ("gpt3-6.7b", 1024, 160.0),
            ("gpt3-13b", 1024, 28.0),
        ];
        for (name, dp, paper) in cases {
            let m = find(name).unwrap();
            let bc = m.required_bc_gbps(dp, 1);
            assert!(
                bc > paper / 3.0 && bc < paper * 3.0,
                "{name}: model {bc:.0} vs paper {paper}"
            );
        }
        // trend: rises through 6.7B, drops at 13B
        let bcs: Vec<f64> = cases
            .iter()
            .map(|(n, dp, _)| find(n).unwrap().required_bc_gbps(*dp, 1))
            .collect();
        assert!(bcs[0] < bcs[3] && bcs[4] < bcs[3], "{bcs:?}");
    }

    #[test]
    fn ga_hides_checkpoint_cost() {
        // Higher GA → more compute per iteration → lower required B_C.
        let m = find("gpt3-1.3b").unwrap();
        assert!(m.required_bc_gbps(1, 64) < m.required_bc_gbps(1, 1));
    }

    #[test]
    fn opt_time_is_small_fraction() {
        // §1: fwd+bwd "typically account for over 90% of compute time".
        for m in MODEL_ZOO {
            let it = m.iter_time(8.min(m.max_dp()), 8);
            assert!(it.opt / it.total() < 0.1, "{}: {}", m.name, it.opt / it.total());
        }
    }

    #[test]
    fn recovery_cost_linear_in_interval() {
        let m = find("gpt3-0.7b").unwrap();
        let c1 = m.recovery_cost_gpu_secs(1, 1024, 10.0);
        let c100 = m.recovery_cost_gpu_secs(100, 1024, 10.0);
        assert!((c100 / c1 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn full_tp_variant() {
        let m = gpt3_13b_full_tp();
        assert_eq!(m.mp(), 16);
        assert_eq!(m.pp, 1);
        // no PP bubble → faster at GA=1
        let base = find("gpt3-13b").unwrap();
        assert!(m.fb_time(8, 1) < base.fb_time(8, 1));
    }
}
