//! Support substrates built from scratch (the offline environment has no
//! serde/clap/rand/criterion, so each is a small, tested, purpose-built
//! implementation).

pub mod bytes;
pub mod cli;
pub mod f16;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
