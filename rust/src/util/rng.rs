//! Deterministic PRNG (SplitMix64 seeding + xoshiro256**).
//!
//! Used by synthetic-data generation, the property-testing framework, and
//! failure injection. No external `rand` crate offline, so this is the
//! canonical randomness source for the whole repo — everything that takes
//! a seed is reproducible run-to-run.

/// xoshiro256** generator, seeded via SplitMix64 (the reference seeding
/// procedure from Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed a generator (any seed is fine, including 0).
    pub fn new(seed: u64) -> Self {
        // SplitMix64 stream to fill the state; avoids all-zero state.
        let mut x = seed;
        let mut next = move || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-rank / per-worker rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Next uniform 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next uniform 32-bit value (high bits of the 64-bit stream).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, bound);
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return hi;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        if lo == hi {
            return lo;
        }
        match (hi - lo).checked_add(1) {
            Some(span) => lo + self.below(span),
            None => self.next_u64(), // full u64 range
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }
}

#[inline]
fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let m = (a as u128) * (b as u128);
    ((m >> 64) as u64, m as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fill_bytes_all_lengths() {
        for len in 0..33 {
            let mut buf = vec![0u8; len];
            Rng::new(9).fill_bytes(&mut buf);
            if len >= 16 {
                assert!(buf.iter().any(|&b| b != 0));
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
