//! Plain-text table rendering for the figure/table reproduction output
//! (`fastpersist repro ...` prints the paper's rows with these).

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (arity must match the header).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// True when no rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to an aligned plain-text block.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i] - cell.chars().count();
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell.chars().next().map_or(false, |c| {
                    c.is_ascii_digit() || c == '-' || c == '+' || c == '.'
                });
                if numeric {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-sane digits (3 significant-ish).
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 100.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["model", "GB/s"]);
        t.row(vec!["gpt3-0.7b", "10.9"]);
        t.row(vec!["gpt3-13b", "146"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("model"));
        assert!(lines[2].contains("gpt3-0.7b"));
        // numeric right-aligned: both numbers end at same column
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(3.14159), "3.14");
        assert_eq!(fnum(42.123), "42.1");
        assert_eq!(fnum(146.4), "146");
    }
}
