//! Summary statistics over timing samples (used by benchkit and metrics).

/// Summary of a sample set (durations in seconds, throughput, etc).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median (interpolated).
    pub p50: f64,
    /// 95th percentile (interpolated).
    pub p95: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Empty input yields
    /// an all-zero summary (n = 0).
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p95: 0.0, max: 0.0 };
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of a **sorted** slice, q in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_and_empty() {
        let s = Summary::of(&[7.0]);
        assert_eq!((s.mean, s.std, s.p50), (7.0, 0.0, 7.0));
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 1.0), 40.0);
        assert!((percentile(&v, 0.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }
}
