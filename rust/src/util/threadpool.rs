//! Small fixed-size thread pool.
//!
//! Backs the async I/O engine's submission queue (the libaio/io_uring
//! analogue: submit aligned writes, poll completions) and the parallel
//! checkpoint writers. No tokio offline; plain threads + channels are
//! also closer to what the write path wants (blocking pwrite syscalls).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` workers named `{name}-{i}`.
    pub fn new(threads: usize, name: &str) -> ThreadPool {
        assert!(threads > 0, "ThreadPool requires >= 1 thread");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a job; never blocks.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Submit a job returning a value; the returned handle joins on it.
    pub fn submit<T, F>(&self, f: F) -> TaskHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        self.execute(move || {
            let _ = tx.send(f());
        });
        TaskHandle { rx }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Join handle for a submitted task.
pub struct TaskHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> TaskHandle<T> {
    /// Block until the task completes. Panics if the worker panicked.
    pub fn join(self) -> T {
        self.rx.recv().expect("task panicked")
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

/// Run a closure over each item of `items` on `threads` workers, in
/// order-preserving fashion; returns the collected outputs.
pub fn parallel_map<T, U, F>(threads: usize, items: Vec<T>, f: F) -> Vec<U>
where
    T: Send + 'static,
    U: Send + 'static,
    F: Fn(T) -> U + Send + Sync + 'static,
{
    let pool = ThreadPool::new(threads.max(1), "pmap");
    let f = Arc::new(f);
    let handles: Vec<TaskHandle<U>> = items
        .into_iter()
        .map(|item| {
            let f = Arc::clone(&f);
            pool.submit(move || f(item))
        })
        .collect();
    handles.into_iter().map(|h| h.join()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit(move || c.fetch_add(1, Ordering::SeqCst))
            })
            .collect();
        for h in handles {
            h.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_values() {
        let pool = ThreadPool::new(2, "t");
        let h = pool.submit(|| 6 * 7);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2, "t");
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(4, (0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }
}
