//! IEEE 754 half-precision (binary16) conversion.
//!
//! The checkpoint state stores model weights in fp16 (2 bytes/param,
//! §2.1.3) while the master copy and Adam moments stay fp32. Rust has no
//! native f16, so the trainer packs/unpacks with these routines; their
//! equivalence with the Pallas `pack_fp16` kernel is pinned by a runtime
//! test against the AOT-compiled HLO.

/// Convert f32 → f16 bits (round-to-nearest-even, IEEE semantics).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        let nan = if mant != 0 { 0x0200 | ((mant >> 13) as u16 & 0x03ff) } else { 0 };
        return sign | 0x7c00 | if mant != 0 && nan & 0x3ff == 0 { 1 } else { nan };
    }
    // unbiased exponent
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        // subnormal or zero
        if e < -10 {
            return sign; // underflow → signed zero
        }
        // add implicit leading 1, shift into subnormal position
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = m >> shift;
        // round to nearest even
        let rem = m & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && (half & 1) == 1) {
            half + 1
        } else {
            half
        };
        return sign | rounded as u16;
    }
    // normal: round mantissa from 23 to 10 bits, nearest even
    let half = (mant >> 13) as u16;
    let rem = mant & 0x1fff;
    let mut out = sign | ((e as u16) << 10) | half;
    if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) {
        out = out.wrapping_add(1); // may carry into exponent — correct
    }
    out
}

/// Convert f16 bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: normalize
            let mut e = 127 - 15 - 10;
            let mut m = m;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03ff;
            sign | (((e + 10 + 1) as u32) << 23) | (m << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, m) => sign | 0x7f80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Encode a f32 slice as little-endian f16 bytes.
pub fn encode_f16(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 2);
    for &v in values {
        out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
    }
    out
}

/// Decode little-endian f16 bytes to f32.
pub fn decode_f16(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.000061035156] {
            let h = f32_to_f16_bits(v);
            assert_eq!(f16_bits_to_f32(h), v, "v={v}");
        }
    }

    #[test]
    fn specials() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // overflow saturates to inf
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e30)), f32::INFINITY);
        // underflow to zero
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-30)), 0.0);
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(0.5), 0x3800);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0); // max finite
        assert_eq!(f16_bits_to_f32(0x0001), 5.9604645e-8); // min subnormal
    }

    #[test]
    fn subnormal_roundtrip() {
        for bits in [0x0001u16, 0x0010, 0x03ff, 0x8001] {
            let f = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(f), bits, "bits={bits:#x}");
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0009765625 = 1 + 2^-10 exactly representable; halfway cases
        let exact = f16_bits_to_f32(0x3c01);
        let halfway_down = (1.0 + exact) / 2.0; // halfway between 0x3c00/0x3c01
        let h = f32_to_f16_bits(halfway_down);
        assert_eq!(h, 0x3c00, "ties to even");
    }

    #[test]
    fn encode_decode_slices() {
        let vals = [1.5f32, -0.25, 3.0, 0.0];
        let bytes = encode_f16(&vals);
        assert_eq!(bytes.len(), 8);
        assert_eq!(decode_f16(&bytes), vals);
    }

    #[test]
    fn prop_all_f16_bits_roundtrip_through_f32() {
        // every finite f16 value must roundtrip bit-exactly
        for bits in 0..=0xffffu16 {
            let exp = (bits >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan handled above
            }
            let f = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(f), bits, "bits={bits:#06x} f={f}");
        }
    }

    #[test]
    fn prop_conversion_error_bounded() {
        crate::prop::forall("f16 relative error < 2^-10", 256, |g| {
            let mag = (g.f64_unit() * 8.0 - 4.0) as f32; // exponent range
            let x = 10f32.powf(mag) * if g.bool() { 1.0 } else { -1.0 };
            if !x.is_finite() || x.abs() > 65000.0 || x.abs() < 1e-4 {
                return true;
            }
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            ((y - x) / x).abs() < 1.0 / 1024.0
        });
    }
}
