//! Byte-size formatting/parsing helpers (MiB-based, matching the paper's
//! GB/sec figures which are decimal-GB per second).

/// One binary kibibyte.
pub const KIB: u64 = 1024;
/// One binary mebibyte.
pub const MIB: u64 = 1024 * 1024;
/// One binary gibibyte.
pub const GIB: u64 = 1024 * 1024 * 1024;

/// One decimal kilobyte.
pub const KB: u64 = 1000;
/// One decimal megabyte.
pub const MB: u64 = 1000 * 1000;
/// One decimal gigabyte (the paper's GB/s unit).
pub const GB: u64 = 1000 * 1000 * 1000;

/// Human-readable binary size, e.g. `512.0 MiB`.
pub fn human(bytes: u64) -> String {
    let b = bytes as f64;
    if bytes >= GIB {
        format!("{:.2} GiB", b / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.1} MiB", b / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.1} KiB", b / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Throughput in decimal GB/s (what the paper reports).
pub fn gbps(bytes: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    bytes as f64 / seconds / GB as f64
}

/// Parse sizes like `16MB`, `4MiB`, `512`, `1.5GB` (case-insensitive).
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| c.is_ascii_alphabetic()).unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let num: f64 = num.trim().parse().ok()?;
    if num < 0.0 {
        return None;
    }
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kb" => KB,
        "kib" => KIB,
        "m" | "mb" => MB,
        "mib" => MIB,
        "g" | "gb" => GB,
        "gib" => GIB,
        _ => return None,
    };
    Some((num * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_formats() {
        assert_eq!(human(512), "512 B");
        assert_eq!(human(2048), "2.0 KiB");
        assert_eq!(human(3 * MIB), "3.0 MiB");
        assert_eq!(human(5 * GIB + GIB / 2), "5.50 GiB");
    }

    #[test]
    fn gbps_math() {
        assert!((gbps(GB, 1.0) - 1.0).abs() < 1e-12);
        assert!((gbps(10 * GB, 2.0) - 5.0).abs() < 1e-12);
        assert_eq!(gbps(GB, 0.0), 0.0);
    }

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size("16MB"), Some(16 * MB));
        assert_eq!(parse_size("16MiB"), Some(16 * MIB));
        assert_eq!(parse_size("1.5gb"), Some(1_500_000_000));
        assert_eq!(parse_size("4k"), Some(4000));
        assert_eq!(parse_size("junk"), None);
        assert_eq!(parse_size("-3MB"), None);
    }
}
