//! Minimal JSON parser/emitter.
//!
//! Used for `artifacts/manifest.json` (the Python→Rust interchange) and
//! for experiment result files. Supports the full JSON grammar except
//! `\u` surrogate pairs are passed through unvalidated. Numbers are
//! parsed as f64 with an i64 fast path (manifest offsets/sizes).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value. Object keys are sorted (BTreeMap) so emission is
/// deterministic — results files diff cleanly between runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Integer (i64 fast path for offsets/sizes).
    Int(i64),
    /// Floating-point number.
    Float(f64),
    /// String.
    Str(String),
    /// Ordered array.
    Array(Vec<Json>),
    /// Object with sorted keys (deterministic emission).
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// Borrow as an object, or a type error.
    pub fn as_object(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Ok(m),
            _ => Err(type_err("object", self)),
        }
    }

    /// Borrow as an array, or a type error.
    pub fn as_array(&self) -> Result<&[Json]> {
        match self {
            Json::Array(v) => Ok(v),
            _ => Err(type_err("array", self)),
        }
    }

    /// Borrow as a string, or a type error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(type_err("string", self)),
        }
    }

    /// Integer value (accepts fraction-free floats), or a type error.
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Json::Int(i) => Ok(*i),
            Json::Float(f) if f.fract() == 0.0 => Ok(*f as i64),
            _ => Err(type_err("integer", self)),
        }
    }

    /// Non-negative integer value, or an error.
    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i)
            .map_err(|_| Error::Json { msg: format!("negative size {i}"), offset: 0 })
    }

    /// Numeric value (int or float), or a type error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Int(i) => Ok(*i as f64),
            Json::Float(f) => Ok(*f),
            _ => Err(type_err("number", self)),
        }
    }

    /// Boolean value, or a type error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(type_err("bool", self)),
        }
    }

    /// Object field lookup with a path-aware error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_object()?.get(key).ok_or_else(|| Error::Json {
            msg: format!("missing key {key:?}"),
            offset: 0,
        })
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    // ---- emission --------------------------------------------------------

    /// Emit with two-space indentation and a trailing newline.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, Some(0));
        out.push('\n');
        out
    }

    /// Emit without any whitespace.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, None);
        out
    }

    fn emit(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => emit_string(out, s),
            Json::Array(items) => emit_seq(out, indent, '[', ']', items.len(), |out, i, ind| {
                items[i].emit(out, ind);
            }),
            Json::Object(map) => {
                let keys: Vec<&String> = map.keys().collect();
                emit_seq(out, indent, '{', '}', keys.len(), |out, i, ind| {
                    emit_string(out, keys[i]);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    map[keys[i]].emit(out, ind);
                });
            }
        }
    }

    // ---- builders --------------------------------------------------------

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array from an iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Array(items.into_iter().collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Int(v as i64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Float(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}

fn type_err(want: &str, got: &Json) -> Error {
    let kind = match got {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Int(_) | Json::Float(_) => "number",
        Json::Str(_) => "string",
        Json::Array(_) => "array",
        Json::Object(_) => "object",
    };
    Error::Json { msg: format!("expected {want}, found {kind}"), offset: 0 }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn emit_seq(
    out: &mut String,
    indent: Option<usize>,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize, Option<usize>),
) {
    out.push(open);
    if len == 0 {
        out.push(close);
        return;
    }
    let inner = indent.map(|d| d + 1);
    for i in 0..len {
        if let Some(d) = inner {
            out.push('\n');
            for _ in 0..d * 2 {
                out.push(' ');
            }
        }
        item(out, i, inner);
        if i + 1 != len {
            out.push(',');
        }
    }
    if let Some(d) = indent {
        out.push('\n');
        for _ in 0..d * 2 {
            out.push(' ');
        }
    }
    out.push(close);
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { msg: msg.to_string(), offset: self.pos }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let start = self.pos + 1;
                            let hex = self
                                .bytes
                                .get(start..start + 4)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = std::str::from_utf8(hex)
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Float)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(Json::parse("2.5").unwrap(), Json::Float(2.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Float(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_i64().unwrap(), 1);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\"b\\cAü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\cAü");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("07abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"nums": [1, -2, 3.5], "s": "x", "t": true, "n": null}"#;
        let v = Json::parse(src).unwrap();
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn large_ints_survive() {
        let v = Json::parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v.as_i64().unwrap(), 9007199254740993);
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_object().is_err());
        assert!(v.get("x").is_err());
        assert!(Json::Int(-1).as_usize().is_err());
        assert!(Json::Float(1.5).as_i64().is_err());
        assert_eq!(Json::Float(2.0).as_i64().unwrap(), 2);
    }
}
