//! Minimal command-line argument parser (no clap offline).
//!
//! Supports: positional args, `--flag`, `--key value`, `--key=value`,
//! and generates usage text from declared options. Each subcommand of the
//! `fastpersist` binary builds one `ArgSpec`.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Declared option (for usage text + validation).
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name (without the `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// True for `--key value` options, false for bare flags.
    pub takes_value: bool,
    /// Default value; `None` makes the option required.
    pub default: Option<&'static str>,
}

/// Parser + registry for one (sub)command.
#[derive(Debug, Default)]
pub struct ArgSpec {
    /// Command name shown in usage text.
    pub name: &'static str,
    /// One-line command description.
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

/// Parse result: options by name, plus positionals.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional (non-option) arguments, in order.
    pub positional: Vec<String>,
}

impl ArgSpec {
    /// A new spec with no options yet.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        ArgSpec { name, about, opts: Vec::new() }
    }

    /// Declare a value option with a default.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default: Some(default) });
        self
    }

    /// Declare a required value option.
    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default: None });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    /// Generated usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let left = if o.takes_value {
                format!("  --{} <v>", o.name)
            } else {
                format!("  --{}", o.name)
            };
            s.push_str(&format!("{left:<26}{}", o.help));
            if let Some(d) = o.default {
                s.push_str(&format!(" [default: {d}]"));
            }
            s.push('\n');
        }
        s
    }

    /// Parse raw tokens. Unknown `--options` are errors; `-h/--help`
    /// yields Error::Config carrying the usage text.
    pub fn parse<I: IntoIterator<Item = String>>(&self, raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "-h" || tok == "--help" {
                return Err(Error::Config(self.usage()));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| Error::Config(format!(
                        "unknown option --{key}\n\n{}", self.usage())))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| {
                            Error::Config(format!("--{key} requires a value"))
                        })?,
                    };
                    args.values.insert(key, val);
                } else {
                    if inline.is_some() {
                        return Err(Error::Config(format!("--{key} takes no value")));
                    }
                    args.flags.push(key);
                }
            } else {
                args.positional.push(tok);
            }
        }
        // Apply defaults; check required.
        for o in &self.opts {
            if o.takes_value && !args.values.contains_key(o.name) {
                match o.default {
                    Some(d) => {
                        args.values.insert(o.name.to_string(), d.to_string());
                    }
                    None => {
                        return Err(Error::Config(format!(
                            "missing required option --{}\n\n{}", o.name, self.usage())));
                    }
                }
            }
        }
        Ok(args)
    }
}

impl Args {
    /// Value of an option (its default if not given; "" if unknown).
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(String::as_str).unwrap_or("")
    }

    /// Parse an option value as an unsigned integer.
    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name}: expected integer, got {:?}", self.get(name))))
    }

    /// Parse an option value as a float.
    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name}: expected number, got {:?}", self.get(name))))
    }

    /// Parse a size option like `16MB`.
    pub fn get_size(&self, name: &str) -> Result<u64> {
        super::bytes::parse_size(self.get(name))
            .ok_or_else(|| Error::Config(format!("--{name}: bad size {:?}", self.get(name))))
    }

    /// True when the flag was passed.
    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "a test command")
            .opt("model", "model name", "tiny")
            .opt_req("out", "output path")
            .flag("verbose", "chatty")
    }

    fn parse(toks: &[&str]) -> Result<Args> {
        spec().parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_values() {
        let a = parse(&["--out", "x.json"]).unwrap();
        assert_eq!(a.get("model"), "tiny");
        assert_eq!(a.get("out"), "x.json");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse(&["--out=y", "--model=gpt20m", "--verbose", "pos1"]).unwrap();
        assert_eq!(a.get("model"), "gpt20m");
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(parse(&[]).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&["--out", "x", "--bogus"]).is_err());
    }

    #[test]
    fn value_parsers() {
        let s = ArgSpec::new("t", "").opt("n", "", "8").opt("buf", "", "16MB");
        let a = s.parse(Vec::new()).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 8);
        assert_eq!(a.get_size("buf").unwrap(), 16_000_000);
    }

    #[test]
    fn help_is_config_error_with_usage() {
        match parse(&["--help"]) {
            Err(Error::Config(msg)) => assert!(msg.contains("--model")),
            other => panic!("expected usage error, got {other:?}"),
        }
    }
}
