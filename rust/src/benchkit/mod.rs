//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, timed sampling, trimmed statistics, and a compact
//! report format. The `benches/*.rs` targets (`harness = false`) use this
//! to regenerate the paper's tables/figures as timing runs; the same
//! harness backs `fastpersist repro` where measured (not simulated)
//! numbers are involved.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Configuration for a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Untimed iterations before sampling.
    pub warmup_iters: usize,
    /// Timed iterations collected.
    pub sample_iters: usize,
    /// Trim this fraction of the highest samples (OS noise on shared CI).
    pub trim_frac: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, sample_iters: 10, trim_frac: 0.1 }
    }
}

impl BenchConfig {
    /// Minimal sampling for CI-speed runs.
    pub fn quick() -> Self {
        BenchConfig { warmup_iters: 1, sample_iters: 5, trim_frac: 0.0 }
    }

    /// Honors FASTPERSIST_BENCH_FAST=1 for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("FASTPERSIST_BENCH_FAST").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// One benchmark measurement result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Result label (shown in reports and JSON).
    pub name: String,
    /// Trimmed timing statistics.
    pub summary: Summary,
    /// Optional bytes processed per iteration (enables GB/s reporting).
    pub bytes_per_iter: Option<u64>,
    /// Extra named scalar counters serialized alongside the timing
    /// fields (e.g. per-step `stall_s` / `drain_s` for overlap benches).
    pub extras: Vec<(String, f64)>,
}

impl BenchResult {
    /// Attach a named scalar to the result's JSON (builder-style).
    pub fn with_extra(mut self, key: &str, value: f64) -> BenchResult {
        self.extras.push((key.to_string(), value));
        self
    }
    /// Median throughput when `bytes_per_iter` is known.
    pub fn throughput_gbps(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| crate::util::bytes::gbps(b, self.summary.p50))
    }

    /// Machine-readable form for the `BENCH_*.json` trajectory files.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("n", Json::from(self.summary.n)),
            ("p50_s", Json::Float(self.summary.p50)),
            ("mean_s", Json::Float(self.summary.mean)),
            ("min_s", Json::Float(self.summary.min)),
            ("max_s", Json::Float(self.summary.max)),
            ("rsd", Json::Float(self.summary.rsd())),
        ];
        if let Some(b) = self.bytes_per_iter {
            fields.push(("bytes_per_iter", Json::from(b as i64)));
        }
        if let Some(t) = self.throughput_gbps() {
            fields.push(("gbps", Json::Float(t)));
        }
        for (k, v) in &self.extras {
            fields.push((k.as_str(), Json::Float(*v)));
        }
        Json::obj(fields)
    }

    /// One human-readable report line.
    pub fn report_line(&self) -> String {
        let mut s = format!(
            "{:<44} p50 {:>10}  mean {:>10} ±{:>5.1}%",
            self.name,
            fmt_duration(self.summary.p50),
            fmt_duration(self.summary.mean),
            self.summary.rsd() * 100.0
        );
        if let Some(t) = self.throughput_gbps() {
            s.push_str(&format!("  {t:>8.2} GB/s"));
        }
        s
    }
}

/// Time `f` under `cfg`; each call of `f` is one iteration.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    bench_with_bytes(name, cfg, None, &mut f)
}

/// Like [`bench`] but annotates bytes/iter for throughput reporting.
pub fn bench_bytes<F: FnMut()>(
    name: &str,
    cfg: &BenchConfig,
    bytes_per_iter: u64,
    mut f: F,
) -> BenchResult {
    bench_with_bytes(name, cfg, Some(bytes_per_iter), &mut f)
}

fn bench_with_bytes(
    name: &str,
    cfg: &BenchConfig,
    bytes_per_iter: Option<u64>,
    f: &mut dyn FnMut(),
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.sample_iters);
    for _ in 0..cfg.sample_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    // Trim the top tail (scheduling noise).
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let keep = samples.len()
        - ((samples.len() as f64 * cfg.trim_frac).floor() as usize).min(samples.len() - 1);
    let summary = Summary::of(&samples[..keep]);
    BenchResult { name: name.to_string(), summary, bytes_per_iter, extras: Vec::new() }
}

/// Format a duration in seconds with adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Group runner: collects results and prints a header + lines.
pub struct BenchGroup {
    /// Group title printed above the result lines.
    pub title: String,
    /// Sampling configuration shared by the group's benches.
    pub cfg: BenchConfig,
    /// Results collected so far.
    pub results: Vec<BenchResult>,
}

impl BenchGroup {
    /// A group using the environment's [`BenchConfig`].
    pub fn new(title: &str) -> BenchGroup {
        BenchGroup { title: title.to_string(), cfg: BenchConfig::from_env(), results: Vec::new() }
    }

    /// Time `f` and record the result under `name`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        let r = bench(name, &self.cfg, f);
        println!("  {}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Like [`BenchGroup::bench`], annotating bytes/iter for GB/s.
    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64, f: F) -> &BenchResult {
        let r = bench_bytes(name, &self.cfg, bytes, f);
        println!("  {}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Print the group header and return the new group.
    pub fn start(title: &str) -> BenchGroup {
        println!("\n=== {title} ===");
        BenchGroup::new(title)
    }

    /// Machine-readable form for `BENCH_*.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            ("results", Json::arr(self.results.iter().map(|r| r.to_json()))),
        ])
    }
}

/// Write the benchkit JSON for one bench target: `BENCH_<tag>.json`
/// under `FASTPERSIST_BENCH_OUT` (default: current directory). These
/// files track the performance trajectory across PRs.
pub fn write_bench_json(tag: &str, groups: &[&BenchGroup]) -> crate::Result<PathBuf> {
    let out_dir = std::env::var("FASTPERSIST_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = Path::new(&out_dir).join(format!("BENCH_{tag}.json"));
    let doc = Json::obj(vec![
        ("bench", Json::str(tag)),
        ("groups", Json::arr(groups.iter().map(|g| g.to_json()))),
    ]);
    std::fs::write(&path, doc.to_string_pretty())?;
    println!("bench json -> {}", path.display());
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig { warmup_iters: 1, sample_iters: 8, trim_frac: 0.1 };
        let r = bench("sleep50us", &cfg, || {
            std::thread::sleep(std::time::Duration::from_micros(50));
        });
        assert!(r.summary.p50 >= 40e-6, "p50={}", r.summary.p50);
        assert!(r.summary.n >= 7);
    }

    #[test]
    fn throughput_annotation() {
        let cfg = BenchConfig::quick();
        let data = vec![0u8; 1 << 20];
        let r = bench_bytes("memcpy-1MiB", &cfg, data.len() as u64, || {
            let copy = data.clone();
            std::hint::black_box(&copy);
        });
        let t = r.throughput_gbps().unwrap();
        assert!(t > 0.01, "throughput={t}");
    }

    #[test]
    fn bench_json_roundtrips() {
        let cfg = BenchConfig::quick();
        let mut g = BenchGroup { title: "t".into(), cfg, results: Vec::new() };
        g.bench_bytes("x", 1000, || {});
        let dir = crate::io::engine::scratch_dir("benchkit-json").unwrap();
        std::env::set_var("FASTPERSIST_BENCH_OUT", &dir);
        let path = write_bench_json("unit", &[&g]).unwrap();
        std::env::remove_var("FASTPERSIST_BENCH_OUT");
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str().unwrap(), "unit");
        let results = v.get("groups").unwrap().as_array().unwrap()[0]
            .get("results")
            .unwrap()
            .as_array()
            .unwrap();
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "x");
        assert_eq!(results[0].get("bytes_per_iter").unwrap().as_i64().unwrap(), 1000);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(5e-9), "5.0 ns");
    }
}
