//! Host-side tensor representation: the model state the checkpoint
//! system serializes, and the buffers the PJRT runtime feeds/reads.

pub mod dtype;
pub mod meta;
pub mod store;

pub use dtype::DType;
pub use meta::TensorMeta;
pub use store::{Tensor, TensorStore};
