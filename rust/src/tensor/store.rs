//! Named host tensors — the in-memory model state.
//!
//! A [`TensorStore`] is the unit the checkpoint engine persists: an
//! ordered collection of named tensors (parameters, Adam moments, and
//! training bookkeeping like the step counter and data-iterator cursor —
//! the paper's "checkpoint state", §2.1.3). Data lives in plain byte
//! buffers; dtype-typed views are provided for the runtime.

use std::sync::Arc;

use crate::tensor::{DType, TensorMeta};
use crate::{Error, Result};

/// One named tensor, bytes + metadata. Payload is Arc'd so checkpointing
/// can hold a zero-copy snapshot reference while training threads move on
/// (the helper thread "reads existing tensors, does not allocate", §4.3).
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Unique name within its store (serialization key).
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Dimension sizes; empty = scalar.
    pub shape: Vec<usize>,
    /// Raw little-endian payload bytes, shared with snapshots.
    pub data: Arc<Vec<u8>>,
}

impl Tensor {
    /// Build a tensor, validating that `data` matches `shape` × dtype
    /// size.
    pub fn new(name: &str, dtype: DType, shape: Vec<usize>, data: Vec<u8>) -> Result<Tensor> {
        let elems: usize = shape.iter().product::<usize>().max(usize::from(shape.is_empty()));
        if elems * dtype.size() != data.len() {
            return Err(Error::Config(format!(
                "tensor {name}: shape {shape:?} x {} B/elem != {} data bytes",
                dtype.size(),
                data.len()
            )));
        }
        Ok(Tensor { name: name.to_string(), dtype, shape, data: Arc::new(data) })
    }

    /// An f32 tensor from host values (little-endian payload).
    pub fn from_f32(name: &str, shape: Vec<usize>, values: &[f32]) -> Result<Tensor> {
        // Bulk byte view (little-endian hosts; checked in tests). The
        // element-wise to_le_bytes loop cost ~3 full passes per
        // checkpoint of the 3 flat optimizer tensors (§Perf).
        #[cfg(target_endian = "little")]
        let data = unsafe {
            std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4).to_vec()
        };
        #[cfg(target_endian = "big")]
        let data = {
            let mut data = Vec::with_capacity(values.len() * 4);
            for v in values {
                data.extend_from_slice(&v.to_le_bytes());
            }
            data
        };
        Tensor::new(name, DType::F32, shape, data)
    }

    /// An i32 tensor from host values (little-endian payload).
    pub fn from_i32(name: &str, shape: Vec<usize>, values: &[i32]) -> Result<Tensor> {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor::new(name, DType::I32, shape, data)
    }

    /// An all-zero tensor of the given shape.
    pub fn zeros(name: &str, dtype: DType, shape: Vec<usize>) -> Tensor {
        let elems: usize = shape.iter().product::<usize>().max(usize::from(shape.is_empty()));
        Tensor {
            name: name.to_string(),
            dtype,
            shape,
            data: Arc::new(vec![0u8; elems * dtype.size()]),
        }
    }

    /// Element count (1 for scalars).
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(usize::from(self.shape.is_empty()))
    }

    /// Payload size in bytes.
    pub fn nbytes(&self) -> u64 {
        self.data.len() as u64
    }

    /// f32 view (little-endian host assumed — checked in tests).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(Error::Config(format!("{}: not f32", self.name)));
        }
        #[cfg(target_endian = "little")]
        {
            // Bulk conversion (resume path handles 3 full-size tensors).
            let mut out = vec![0f32; self.data.len() / 4];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    self.data.as_ptr(),
                    out.as_mut_ptr() as *mut u8,
                    self.data.len(),
                );
            }
            Ok(out)
        }
        #[cfg(target_endian = "big")]
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// i32 view of the payload.
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            return Err(Error::Config(format!("{}: not i32", self.name)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Ordered collection of named tensors. Order defines serialization
/// layout, so it is part of the checkpoint contract.
#[derive(Debug, Clone, Default)]
pub struct TensorStore {
    tensors: Vec<Tensor>,
}

impl TensorStore {
    /// An empty store.
    pub fn new() -> TensorStore {
        TensorStore::default()
    }

    /// Append a tensor; names must be unique.
    pub fn push(&mut self, t: Tensor) -> Result<()> {
        if self.get(&t.name).is_some() {
            return Err(Error::Config(format!("duplicate tensor {}", t.name)));
        }
        self.tensors.push(t);
        Ok(())
    }

    /// Replace an existing tensor's payload (shape/dtype must match).
    pub fn update(&mut self, name: &str, data: Vec<u8>) -> Result<()> {
        let t = self
            .tensors
            .iter_mut()
            .find(|t| t.name == name)
            .ok_or_else(|| Error::Config(format!("no tensor {name}")))?;
        if data.len() != t.data.len() {
            return Err(Error::Config(format!(
                "update {name}: {} bytes != {}",
                data.len(),
                t.data.len()
            )));
        }
        t.data = Arc::new(data);
        Ok(())
    }

    /// Look a tensor up by name.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Iterate tensors in store (= serialization) order.
    pub fn iter(&self) -> impl Iterator<Item = &Tensor> {
        self.tensors.iter()
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// True when the store holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total payload bytes (the checkpoint's data-section size).
    pub fn total_bytes(&self) -> u64 {
        self.tensors.iter().map(|t| t.nbytes()).sum()
    }

    /// Metadata table with contiguous offsets in store order.
    pub fn metas(&self) -> Vec<TensorMeta> {
        let mut out = Vec::with_capacity(self.tensors.len());
        let mut off = 0u64;
        for t in &self.tensors {
            out.push(TensorMeta {
                name: t.name.clone(),
                dtype: t.dtype,
                shape: t.shape.clone(),
                offset: off,
            });
            off += t.nbytes();
        }
        out
    }

    /// Cheap snapshot: clones Arcs, not payloads. This is what the
    /// pipelined checkpointer captures at optimizer time.
    pub fn snapshot(&self) -> TensorStore {
        self.clone()
    }

    /// Exact content equality (names, shapes, dtypes, bytes).
    pub fn content_eq(&self, other: &TensorStore) -> bool {
        self.tensors.len() == other.tensors.len()
            && self.tensors.iter().zip(other.tensors.iter()).all(|(a, b)| {
                a.name == b.name
                    && a.dtype == b.dtype
                    && a.shape == b.shape
                    && a.data == b.data
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_size_validation() {
        assert!(Tensor::new("x", DType::F32, vec![2, 2], vec![0; 16]).is_ok());
        assert!(Tensor::new("x", DType::F32, vec![2, 2], vec![0; 15]).is_err());
        assert!(Tensor::new("s", DType::F32, vec![], vec![0; 4]).is_ok()); // scalar
    }

    #[test]
    fn f32_roundtrip() {
        let vals = [1.0f32, -2.5, 3.25];
        let t = Tensor::from_f32("x", vec![3], &vals).unwrap();
        assert_eq!(t.as_f32().unwrap(), vals);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn store_ordering_and_offsets() {
        let mut s = TensorStore::new();
        s.push(Tensor::zeros("a", DType::F32, vec![4])).unwrap();
        s.push(Tensor::zeros("b", DType::F16, vec![8])).unwrap();
        let metas = s.metas();
        assert_eq!(metas[0].offset, 0);
        assert_eq!(metas[1].offset, 16);
        assert_eq!(s.total_bytes(), 32);
        assert!(s.push(Tensor::zeros("a", DType::U8, vec![1])).is_err());
    }

    #[test]
    fn snapshot_is_isolated_from_updates() {
        let mut s = TensorStore::new();
        s.push(Tensor::from_f32("w", vec![2], &[1.0, 2.0]).unwrap()).unwrap();
        let snap = s.snapshot();
        s.update("w", vec![0u8; 8]).unwrap();
        // snapshot still sees the old payload
        assert_eq!(snap.get("w").unwrap().as_f32().unwrap(), vec![1.0, 2.0]);
        assert_eq!(s.get("w").unwrap().as_f32().unwrap(), vec![0.0, 0.0]);
        assert!(!s.content_eq(&snap));
    }

    #[test]
    fn update_validates() {
        let mut s = TensorStore::new();
        s.push(Tensor::zeros("w", DType::F32, vec![2])).unwrap();
        assert!(s.update("w", vec![0; 4]).is_err());
        assert!(s.update("nope", vec![0; 8]).is_err());
        assert!(s.update("w", vec![1; 8]).is_ok());
    }
}
