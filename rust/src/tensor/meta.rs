//! Tensor metadata — the serialization-side description of each tensor
//! (name, dtype, shape, byte span). This is the analogue of the metadata
//! torch.save attaches to each serialized tensor (§2.1.3 of the paper):
//! checkpoint creation is a *sequence* of writes of serialized tensors,
//! each carrying its own header, not one flat blob.

use crate::tensor::DType;
use crate::util::json::Json;
use crate::{Error, Result};

/// Serialized description of one tensor in the checkpoint header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    /// Tensor name (unique within the checkpoint).
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Dimension sizes; empty = scalar.
    pub shape: Vec<usize>,
    /// Byte offset of this tensor's payload within the checkpoint *data
    /// section* (not counting container header/index).
    pub offset: u64,
}

impl TensorMeta {
    /// Element count (1 for scalars).
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(if self.shape.is_empty() { 1 } else { 0 })
    }

    /// Payload size in bytes.
    pub fn nbytes(&self) -> u64 {
        (self.elems() * self.dtype.size()) as u64
    }

    /// Serialize to the header JSON representation.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("dtype", Json::str(self.dtype.name())),
            ("shape", Json::arr(self.shape.iter().map(|&s| Json::from(s)))),
            ("offset", Json::from(self.offset as i64)),
        ])
    }

    /// Parse from the header JSON representation.
    pub fn from_json(v: &Json) -> Result<TensorMeta> {
        let shape = v
            .get("shape")?
            .as_array()?
            .iter()
            .map(|s| s.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorMeta {
            name: v.get("name")?.as_str()?.to_string(),
            dtype: DType::parse(v.get("dtype")?.as_str()?)?,
            shape,
            offset: v.get("offset")?.as_i64()? as u64,
        })
    }

    /// Validate that a list of metas tile a data section contiguously.
    pub fn check_contiguous(metas: &[TensorMeta]) -> Result<u64> {
        let mut off = 0u64;
        for m in metas {
            if m.offset != off {
                return Err(Error::Format(format!(
                    "tensor {} at offset {} but expected {off}",
                    m.name, m.offset
                )));
            }
            off += m.nbytes();
        }
        Ok(off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(name: &str, shape: &[usize], offset: u64) -> TensorMeta {
        TensorMeta { name: name.into(), dtype: DType::F32, shape: shape.to_vec(), offset }
    }

    #[test]
    fn elems_and_bytes() {
        assert_eq!(meta("a", &[2, 3], 0).elems(), 6);
        assert_eq!(meta("a", &[2, 3], 0).nbytes(), 24);
        // scalar (rank-0) has one element
        assert_eq!(meta("s", &[], 0).elems(), 1);
    }

    #[test]
    fn json_roundtrip() {
        let m = TensorMeta { name: "w".into(), dtype: DType::F16, shape: vec![4, 8], offset: 128 };
        let j = m.to_json();
        assert_eq!(TensorMeta::from_json(&j).unwrap(), m);
    }

    #[test]
    fn contiguity_check() {
        let ok = vec![meta("a", &[2], 0), meta("b", &[3], 8)];
        assert_eq!(TensorMeta::check_contiguous(&ok).unwrap(), 20);
        let bad = vec![meta("a", &[2], 0), meta("b", &[3], 12)];
        assert!(TensorMeta::check_contiguous(&bad).is_err());
    }
}
