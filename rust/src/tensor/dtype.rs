//! Element dtypes shared by the checkpoint format, the runtime, and the
//! manifest (which uses the JAX-side short names "f32"/"f16"/"i32").

use crate::{Error, Result};

/// Element type of a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit IEEE float.
    F32,
    /// 16-bit IEEE float (storage only on the host side).
    F16,
    /// 32-bit signed integer.
    I32,
    /// Raw byte.
    U8,
}

impl DType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::U8 => 1,
        }
    }

    /// Manifest/JAX short name.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::I32 => "i32",
            DType::U8 => "u8",
        }
    }

    /// Parse a short or long dtype name (`f32`/`float32`, ...).
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" | "float32" => Ok(DType::F32),
            "f16" | "float16" => Ok(DType::F16),
            "i32" | "int32" => Ok(DType::I32),
            "u8" | "uint8" => Ok(DType::U8),
            other => Err(Error::Config(format!("unknown dtype {other:?}"))),
        }
    }

    /// Stable on-disk tag for the checkpoint format.
    pub fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::F16 => 1,
            DType::I32 => 2,
            DType::U8 => 3,
        }
    }

    /// Inverse of [`DType::tag`].
    pub fn from_tag(tag: u8) -> Result<DType> {
        match tag {
            0 => Ok(DType::F32),
            1 => Ok(DType::F16),
            2 => Ok(DType::I32),
            3 => Ok(DType::U8),
            other => Err(Error::Format(format!("bad dtype tag {other}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::F16.size(), 2);
        assert_eq!(DType::I32.size(), 4);
        assert_eq!(DType::U8.size(), 1);
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for d in [DType::F32, DType::F16, DType::I32, DType::U8] {
            assert_eq!(DType::parse(d.name()).unwrap(), d);
            assert_eq!(DType::from_tag(d.tag()).unwrap(), d);
        }
        assert!(DType::parse("bf16").is_err());
        assert!(DType::from_tag(9).is_err());
    }
}
