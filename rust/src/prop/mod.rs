//! Minimal property-based testing framework (proptest is unavailable
//! offline).
//!
//! Deterministic by default: every case derives from a fixed master seed,
//! so failures reproduce. On failure the runner performs greedy input
//! shrinking for the common generator types (integers shrink toward the
//! minimum, vectors shrink by halving) and reports the seed + shrunken
//! case.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this image)
//! use fastpersist::prop::forall;
//! forall("addition commutes", 256, |g| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     a + b == b + a
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case generator context. Records draws so the shrinker can replay
/// with reduced values.
pub struct Gen {
    rng: Rng,
    /// Recorded draw log: (lo, hi, value) for integer draws.
    log: Vec<(u64, u64, u64)>,
    /// When Some, draws replay from this override log instead of the rng.
    replay: Option<Vec<u64>>,
    replay_idx: usize,
    /// Failure message recorded by the runner (for reporting).
    pub failure: Option<String>,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), log: Vec::new(), replay: None, replay_idx: 0, failure: None }
    }

    fn with_replay(seed: u64, replay: Vec<u64>) -> Gen {
        Gen { replay: Some(replay), ..Gen::new(seed) }
    }

    /// Draw a u64 in [lo, hi] inclusive.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = if let Some(replay) = &self.replay {
            let v = replay.get(self.replay_idx).copied().unwrap_or(lo);
            self.replay_idx += 1;
            v.clamp(lo, hi)
        } else {
            self.rng.range_u64(lo, hi)
        };
        self.log.push((lo, hi, v));
        v
    }

    /// Draw a usize in [lo, hi] inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Draw a uniform boolean.
    pub fn bool(&mut self) -> bool {
        self.u64(0, 1) == 1
    }

    /// f64 in [0,1) with 2^20 granularity (keeps draws shrinkable).
    pub fn f64_unit(&mut self) -> f64 {
        self.u64(0, (1 << 20) - 1) as f64 / (1u64 << 20) as f64
    }

    /// Vec of u64 draws with length in [0, max_len].
    pub fn vec_u64(&mut self, max_len: usize, lo: u64, hi: u64) -> Vec<u64> {
        let len = self.usize(0, max_len);
        (0..len).map(|_| self.u64(lo, hi)).collect()
    }

    /// Choose uniformly from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }

    /// Record a failure message (used by `prop_assert!`).
    pub fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
    }
}

/// Assert inside a property; records the message and returns `false` from
/// the enclosing closure.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($arg:tt)*) => {
        if !$cond {
            $g.fail(format!($($arg)*));
            return false;
        }
    };
}

/// Run `cases` random cases of `prop`. Panics (with seed + shrunken input
/// info) if any case returns false or records a failure.
pub fn forall<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> bool,
{
    let master = master_seed();
    for i in 0..cases {
        let seed = master.wrapping_add(i).wrapping_mul(0x9e3779b97f4a7c15) ^ i;
        let mut g = Gen::new(seed);
        let ok = prop(&mut g) && g.failure.is_none();
        if !ok {
            let draws: Vec<u64> = g.log.iter().map(|&(_, _, v)| v).collect();
            let shrunk = shrink(&prop, seed, draws);
            let mut g2 = Gen::with_replay(seed, shrunk.clone());
            let _ = prop(&mut g2);
            panic!(
                "property `{name}` failed (case {i}, seed {seed:#x})\n  \
                 shrunken draws: {shrunk:?}\n  failure: {}",
                g2.failure.unwrap_or_else(|| "returned false".to_string())
            );
        }
    }
}

/// Greedy shrink: try lowering each draw toward its minimum and halving,
/// keeping changes that still fail. Bounded passes for determinism.
fn shrink<F>(prop: &F, seed: u64, mut draws: Vec<u64>) -> Vec<u64>
where
    F: Fn(&mut Gen) -> bool,
{
    let fails = |candidate: &Vec<u64>| {
        let mut g = Gen::with_replay(seed, candidate.clone());
        let ok = prop(&mut g) && g.failure.is_none();
        !ok
    };
    for _pass in 0..4 {
        let mut changed = false;
        for i in 0..draws.len() {
            let original = draws[i];
            if original == 0 {
                continue;
            }
            // Binary-search the smallest replacement that still fails
            // (greedy: assumes local monotonicity, which is the common
            // case for size/count draws; harmless otherwise).
            let mut lo = 0u64;
            let mut hi = original;
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                draws[i] = mid;
                if fails(&draws) {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            draws[i] = hi;
            if !fails(&draws) {
                draws[i] = original; // non-monotone region: give up here
            } else if hi < original {
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    draws
}

/// Master seed: fixed unless FASTPERSIST_PROP_SEED overrides it.
fn master_seed() -> u64 {
    std::env::var("FASTPERSIST_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xfa57_9e51_57e0_0001)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum symmetric", 128, |g| {
            let a = g.u64(0, 100);
            let b = g.u64(0, 100);
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_name() {
        forall("always fails", 16, |g| {
            let _ = g.u64(0, 10);
            false
        });
    }

    #[test]
    fn shrinks_toward_minimum() {
        // Property "x < 50" fails for x >= 50; the shrinker should find a
        // small counterexample (50 exactly under greedy halving/decrement).
        let prop = |g: &mut Gen| {
            let x = g.u64(0, 1000);
            x < 50
        };
        // find a failing seed first
        let mut failing = None;
        for seed in 0..200u64 {
            let mut g = Gen::new(seed);
            if !prop(&mut g) {
                failing = Some((seed, g.log.iter().map(|&(_, _, v)| v).collect::<Vec<_>>()));
                break;
            }
        }
        let (seed, draws) = failing.expect("should find failing case");
        let shrunk = shrink(&prop, seed, draws);
        assert_eq!(shrunk, vec![50]);
    }

    #[test]
    fn generators_respect_bounds() {
        forall("bounds", 256, |g| {
            let v = g.u64(10, 20);
            let u = g.usize(0, 5);
            let f = g.f64_unit();
            (10..=20).contains(&v) && u <= 5 && (0.0..1.0).contains(&f)
        });
    }

    #[test]
    fn vec_gen_and_choose() {
        forall("vec/choose", 64, |g| {
            let v = g.vec_u64(16, 0, 9);
            if v.is_empty() {
                return true;
            }
            let c = *g.choose(&v);
            v.contains(&c) && v.len() <= 16 && v.iter().all(|&x| x <= 9)
        });
    }
}
