//! Per-checkpoint manifest: ties partition files back into one logical
//! serialized stream.
//!
//! Parallel checkpoints are written as one file per writer (the ranks'
//! local SSDs in the paper). The manifest — written by partition 0's
//! writer after all partitions are durable — records the stream length,
//! the partition table, the digest, and each partition's **device
//! assignment** (the [`crate::io::DeviceMap`] mount point it was striped
//! onto), so loading can verify, locate, and reassemble (allgather) the
//! full checkpoint state.

use std::path::{Path, PathBuf};

use crate::checkpoint::plan::{Partition, WritePlan};
use crate::util::json::Json;
use crate::{Error, Result};

pub const MANIFEST_FILE: &str = "checkpoint.json";

/// Manifest schema version. v2 = composite stream digest
/// ([`crate::serialize::format::combine_digests`] over header‖data
/// halves) + optional per-partition device assignments. v1 manifests
/// (whole-stream `checksum64_slice` digest, no device field) are
/// rejected with a clear incompatibility error rather than a misleading
/// digest mismatch.
pub const MANIFEST_VERSION: i64 = 2;

#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointManifest {
    pub total_len: u64,
    pub digest: u64,
    pub step: u64,
    pub partitions: Vec<PartitionEntry>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct PartitionEntry {
    pub file: String,
    pub writer_rank: usize,
    pub start: u64,
    pub end: u64,
    /// Mount-point root of the device this partition was striped onto;
    /// `None` means the partition lives in the checkpoint directory
    /// itself (single-device layout). Loaders resolve the actual path
    /// via [`crate::io::DeviceMap::resolve_in`].
    pub device: Option<String>,
}

impl CheckpointManifest {
    pub fn from_plan(plan: &WritePlan, digest: u64, step: u64) -> CheckpointManifest {
        let unrouted: Vec<Option<String>> = vec![None; plan.partitions.len()];
        Self::from_routed_plan(plan, &unrouted, digest, step)
    }

    /// Build a manifest from a plan plus per-partition device roots (as
    /// recorded by the write path's routing).
    pub fn from_routed_plan(
        plan: &WritePlan,
        devices: &[Option<String>],
        digest: u64,
        step: u64,
    ) -> CheckpointManifest {
        debug_assert_eq!(devices.len(), plan.partitions.len());
        CheckpointManifest {
            total_len: plan.total_len,
            digest,
            step,
            partitions: plan
                .partitions
                .iter()
                .zip(devices)
                .map(|(p, device)| PartitionEntry {
                    file: Self::partition_file(p),
                    writer_rank: p.writer_rank,
                    start: p.start,
                    end: p.end,
                    device: device.clone(),
                })
                .collect(),
        }
    }

    /// Distinct device roots referenced by this checkpoint (empty for
    /// single-device layouts).
    pub fn devices(&self) -> Vec<&str> {
        let mut seen = std::collections::BTreeSet::new();
        self.partitions
            .iter()
            .filter_map(|p| p.device.as_deref())
            .filter(|d| seen.insert(*d))
            .collect()
    }

    /// Canonical partition filename for a plan entry.
    pub fn partition_file(p: &Partition) -> String {
        format!("part-{:04}-rank{:05}.fpck", p.index, p.writer_rank)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("manifest_version", Json::from(MANIFEST_VERSION)),
            ("total_len", Json::from(self.total_len as i64)),
            ("digest_hi", Json::from((self.digest >> 32) as i64)),
            ("digest_lo", Json::from((self.digest & 0xffff_ffff) as i64)),
            ("step", Json::from(self.step as i64)),
            (
                "partitions",
                Json::arr(self.partitions.iter().map(|p| {
                    let mut fields = vec![
                        ("file", Json::str(&p.file)),
                        ("writer_rank", Json::from(p.writer_rank)),
                        ("start", Json::from(p.start as i64)),
                        ("end", Json::from(p.end as i64)),
                    ];
                    if let Some(device) = &p.device {
                        fields.push(("device", Json::str(device)));
                    }
                    Json::obj(fields)
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CheckpointManifest> {
        let version = v.opt("manifest_version").map(Json::as_i64).transpose()?.unwrap_or(1);
        if version != MANIFEST_VERSION {
            return Err(Error::Format(format!(
                "checkpoint manifest is v{version}, this build reads v{MANIFEST_VERSION} \
                 (the stream-digest algorithm changed); re-create the checkpoint"
            )));
        }
        let hi = v.get("digest_hi")?.as_i64()? as u64;
        let lo = v.get("digest_lo")?.as_i64()? as u64;
        let partitions = v
            .get("partitions")?
            .as_array()?
            .iter()
            .map(|p| {
                let device = match p.opt("device") {
                    Some(d) => Some(d.as_str()?.to_string()),
                    None => None,
                };
                Ok(PartitionEntry {
                    file: p.get("file")?.as_str()?.to_string(),
                    writer_rank: p.get("writer_rank")?.as_usize()?,
                    start: p.get("start")?.as_i64()? as u64,
                    end: p.get("end")?.as_i64()? as u64,
                    device,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(CheckpointManifest {
            total_len: v.get("total_len")?.as_i64()? as u64,
            digest: (hi << 32) | (lo & 0xffff_ffff),
            step: v.get("step")?.as_i64()? as u64,
            partitions,
        })
    }

    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(MANIFEST_FILE);
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, self.to_json().to_string_pretty())?;
        // atomic publish: the manifest appearing means the checkpoint is
        // complete and durable
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    pub fn load(dir: &Path) -> Result<CheckpointManifest> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Format(format!("manifest {}: {e}", path.display())))?;
        let m = Self::from_json(&Json::parse(&text)?)?;
        m.validate()?;
        Ok(m)
    }

    /// Partition table must tile [0, total_len) contiguously.
    pub fn validate(&self) -> Result<()> {
        let mut pos = 0u64;
        for p in &self.partitions {
            if p.start != pos || p.end < p.start {
                return Err(Error::Format(format!(
                    "partition {} not contiguous (start {} expected {pos})",
                    p.file, p.start
                )));
            }
            pos = p.end;
        }
        if pos != self.total_len {
            return Err(Error::Format(format!(
                "partitions cover {pos} of {} bytes",
                self.total_len
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> CheckpointManifest {
        let plan = WritePlan::balanced(100, &[0, 5, 9]).unwrap();
        CheckpointManifest::from_plan(&plan, 0xabcd_ef01_2345_6789, 7)
    }

    #[test]
    fn json_roundtrip() {
        let m = manifest();
        let j = m.to_json();
        let back = CheckpointManifest::from_json(&j).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::io::engine::scratch_dir("manifest").unwrap();
        let m = manifest();
        m.save(&dir).unwrap();
        let back = CheckpointManifest::load(&dir).unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v1_manifest_rejected_with_clear_error() {
        let m = manifest();
        let Json::Object(mut fields) = m.to_json() else { panic!("manifest json is an object") };
        fields.remove("manifest_version");
        match CheckpointManifest::from_json(&Json::Object(fields)) {
            Err(Error::Format(msg)) => assert!(msg.contains("manifest is v1"), "{msg}"),
            other => panic!("expected version error, got {other:?}"),
        }
    }

    #[test]
    fn device_assignments_roundtrip() {
        let plan = WritePlan::balanced(1000, &[0, 1, 2, 3]).unwrap();
        let devices = vec![
            Some("/mnt/ssd0".to_string()),
            Some("/mnt/ssd1".to_string()),
            Some("/mnt/ssd0".to_string()),
            Some("/mnt/ssd1".to_string()),
        ];
        let m = CheckpointManifest::from_routed_plan(&plan, &devices, 0x1234, 3);
        assert_eq!(m.devices(), vec!["/mnt/ssd0", "/mnt/ssd1"]);
        let back = CheckpointManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.partitions[1].device.as_deref(), Some("/mnt/ssd1"));
        // single-device manifests carry no device fields
        let single = manifest();
        assert!(single.partitions.iter().all(|p| p.device.is_none()));
        assert!(single.devices().is_empty());
        let back = CheckpointManifest::from_json(&single.to_json()).unwrap();
        assert_eq!(back, single);
    }

    #[test]
    fn validate_catches_gaps() {
        let mut m = manifest();
        m.partitions[1].start += 1;
        assert!(m.validate().is_err());
        let mut m2 = manifest();
        m2.total_len += 5;
        assert!(m2.validate().is_err());
    }

    #[test]
    fn filenames_are_unique_and_ordered() {
        let m = manifest();
        let names: std::collections::BTreeSet<_> =
            m.partitions.iter().map(|p| &p.file).collect();
        assert_eq!(names.len(), m.partitions.len());
        assert!(m.partitions[0].file.starts_with("part-0000"));
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = crate::io::engine::scratch_dir("manifest-miss").unwrap();
        assert!(CheckpointManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
