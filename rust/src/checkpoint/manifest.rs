//! Per-checkpoint manifest: ties partition (or chunk) files back into
//! one logical serialized stream.
//!
//! Parallel checkpoints are written as one file per writer (the ranks'
//! local SSDs in the paper). The manifest — written by partition 0's
//! writer after all partitions are durable — records the stream length,
//! the partition table, the digest, and each partition's **device
//! assignment** (the [`crate::io::DeviceMap`] mount point it was striped
//! onto), so loading can verify, locate, and reassemble (allgather) the
//! full checkpoint state.
//!
//! Since manifest **v3** the same file also describes *incremental*
//! checkpoints (see [`crate::checkpoint::delta`]): instead of a
//! partition table, a delta manifest carries a [`DeltaSection`] — the
//! base-checkpoint reference plus a per-chunk table whose entries say,
//! for every fixed-size chunk of the stream, which sibling checkpoint
//! directory holds the chunk's bytes and what the chunk's content hash
//! is. Exactly one of the two tables is populated: `partitions` for
//! full (partitioned) checkpoints, `delta` for chunked ones. The
//! manifest is always published last, via atomic rename, so its
//! presence means the checkpoint — and, for deltas, every chunk it
//! references — is complete and durable.
//!
//! Manifest **v4** changes where a chunk's *bytes* live: instead of one
//! file per chunk, chunks are packed into a small number of large
//! **segment files** (see the segment store in
//! [`crate::checkpoint::delta`]), and each [`ChunkEntry`] carries a
//! [`SegmentRef`] addressing `(segment id, byte offset)` inside the
//! source checkpoint's segment store. v4 also splits the chunk grid at
//! the header boundary ([`DeltaSection::header_len`]): chunk 0 is the
//! whole encoded header, chunks 1.. tile the data section — which is
//! what lets serialization hash the grid in its single payload pass.
//! v3 manifests (per-chunk files, uniform whole-stream grid) are still
//! read; v1 is rejected with a clear incompatibility error. See
//! `docs/FORMATS.md` for the full version history.
//!
//! Manifest **v5** changes how the chunk *table* is encoded: instead of
//! one JSON object per chunk (which dominates manifest size and parse
//! time at ~100k chunks), the table is a **binary blob of fixed-width
//! little-endian records** ([`CHUNK_RECORD_LEN`] bytes per chunk,
//! hex-encoded into the `chunk_table` field) plus two small string
//! tables (`sources`, `devices`) the records index into. The blob
//! carries its own `checksum64` digest and is parsed **fail-closed**:
//! record count, digest, string-table indices, non-zero lengths,
//! in-bounds segment offsets, and per-segment extent monotonicity are
//! all validated before a single chunk entry is accepted — a flipped or
//! truncated byte yields a typed error, never a garbage table. v2–v4
//! JSON chunk arrays are still read.
//!
//! Manifest **v6** widens the binary record from 36 to
//! [`CHUNK_RECORD_LEN_V6`] bytes to carry the **codec stage** (see
//! [`crate::checkpoint::codec`]): each chunk records which codec
//! encoded its stored bytes, the encoded length (the stored footprint —
//! `len` stays the *raw* length and `hash` the *raw* content hash, so
//! dirty detection and post-decode verification are codec-blind), and,
//! for quantized-delta chunks, the segment address of the raw **base**
//! chunk the diff was taken against. The codec fields are validated
//! fail-closed exactly like the v5 fields: unknown codec ids, nonzero
//! pad bytes, encoded lengths inconsistent with the codec, and missing
//! or malformed base references are all typed errors. v2–v5 manifests
//! are still read (v5's 36-byte records parse as codec `none`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::SystemTime;

use crate::checkpoint::codec::CodecKind;
use crate::checkpoint::plan::{Partition, WritePlan};
use crate::serialize::format::checksum64_slice;
use crate::util::json::Json;
use crate::{Error, Result};

/// File name of the manifest inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "checkpoint.json";

/// Manifest schema version. v6 = v5 with the binary chunk record
/// widened to carry the codec stage (codec id, encoded length, and the
/// quantized-delta base reference — see [`CHUNK_RECORD_LEN_V6`]). v5
/// (36-byte binary records, codec-free), v4 (JSON chunk array with
/// segment addressing), v3 (per-chunk-file deltas) and v2 (composite
/// stream digest, optional device assignments, no delta section)
/// manifests are still read. v1 manifests (whole-stream
/// `checksum64_slice` digest, no device field) are rejected with a
/// clear incompatibility error rather than a misleading digest
/// mismatch. The evolution table lives in `docs/FORMATS.md`.
pub const MANIFEST_VERSION: i64 = 6;

/// First manifest version carrying the binary chunk table.
pub const MANIFEST_BINARY_TABLE_VERSION: i64 = 5;

/// First manifest version whose binary records carry codec fields
/// ([`CHUNK_RECORD_LEN_V6`]-byte records).
pub const MANIFEST_CODEC_VERSION: i64 = 6;

/// Fixed width in bytes of one binary chunk-table record as written by
/// manifest **v5** (still read). Layout, all little-endian:
///
/// ```text
/// offset 0   chunk content hash          u64
/// offset 8   chunk length in bytes       u64
/// offset 16  source string-table index   u32  (0xffff_ffff = own dir)
/// offset 20  device string-table index   u32  (0xffff_ffff = none)
/// offset 24  segment index               u32  (0xffff_ffff = v3 chunk file)
/// offset 28  segment byte offset         u64  (0 when no segment)
/// ```
pub const CHUNK_RECORD_LEN: usize = 36;

/// Fixed width in bytes of one binary chunk-table record (manifest v6):
/// the v5 layout above followed by the codec fields. `hash` and `len`
/// always describe the chunk's **raw** bytes; `encoded len` is the
/// stored footprint inside the segment. The base fields address the raw
/// base chunk a `qdelta` diff was taken against and are the sentinel
/// (`0xffff_ffff` indices, zero offset/length) for every other codec.
/// Layout of the tail, all little-endian:
///
/// ```text
/// offset 36  codec id                    u8   (0 none, 1 lz4, 2 qdelta)
/// offset 37  reserved pad                3 bytes, must be zero
/// offset 40  encoded length in bytes     u64  (== len when codec 0)
/// offset 48  base source index           u32  (0xffff_ffff = none/own)
/// offset 52  base device index           u32  (0xffff_ffff = none)
/// offset 56  base segment index          u32  (0xffff_ffff = no base)
/// offset 60  base segment byte offset    u64
/// offset 68  base length in bytes        u64  (== len for qdelta)
/// ```
pub const CHUNK_RECORD_LEN_V6: usize = 76;

/// String-table sentinel for "no entry" in binary chunk records.
const NO_INDEX: u32 = u32::MAX;

/// Oldest manifest version this build can still read (v2: same digest
/// algorithm as v4, no delta section).
pub const MANIFEST_MIN_READ_VERSION: i64 = 2;

/// The per-checkpoint manifest: stream length + digest + exactly one of
/// a partition table (full checkpoint) or a [`DeltaSection`] (chunked
/// incremental checkpoint).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointManifest {
    /// Length in bytes of the logical serialized stream.
    pub total_len: u64,
    /// Composite stream digest (header‖data halves, see
    /// [`crate::serialize::format::combine_digests`]).
    pub digest: u64,
    /// Training step this checkpoint captures.
    pub step: u64,
    /// Partition table of a full checkpoint; empty for delta manifests.
    pub partitions: Vec<PartitionEntry>,
    /// Chunk table of an incremental checkpoint; `None` for full ones.
    pub delta: Option<DeltaSection>,
    /// Submission backend that drained this checkpoint's bytes
    /// (`"sync"` or `"ring"`) — runtime info recorded like device
    /// striping, so `fault_matrix` and restore logs can report which
    /// path produced the checkpoint. `None` on manifests written before
    /// the field existed (readers treat that as "sync"-era unknown);
    /// optional in the JSON, so v2–v5 documents keep parsing.
    pub io_backend: Option<String>,
}

/// One partition file of a full (non-delta) checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionEntry {
    /// Partition file name (see [`CheckpointManifest::partition_file`]).
    pub file: String,
    /// DP rank that wrote this partition.
    pub writer_rank: usize,
    /// First byte (inclusive) of the stream range this file holds.
    pub start: u64,
    /// One past the last byte of the stream range this file holds.
    pub end: u64,
    /// Mount-point root of the device this partition was striped onto;
    /// `None` means the partition lives in the checkpoint directory
    /// itself (single-device layout). Loaders resolve the actual path
    /// via [`crate::io::DeviceMap::resolve_in`].
    pub device: Option<String>,
}

/// Incremental-checkpoint extension of the manifest (v3): the chunk
/// table plus the chain linkage that lets
/// [`crate::checkpoint::load::load_checkpoint`] rebuild the stream from
/// a base + delta chain.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaSection {
    /// Directory *name* (not path) of the immediately preceding
    /// checkpoint in the chain — a sibling of this checkpoint's
    /// directory. `None` marks a base checkpoint (all chunks local).
    pub base: Option<String>,
    /// Number of deltas since the chain's base (0 for the base itself).
    pub chain_len: u64,
    /// Fixed chunk size in bytes; the final chunk may be shorter.
    pub chunk_size: u64,
    /// Length of the header chunk (chunk 0) for the v4 header-split
    /// grid: chunk 0 covers the encoded header, chunks 1.. tile the
    /// data section in `chunk_size` steps. `0` marks the legacy v3
    /// uniform grid over the whole stream (header and data mixed).
    pub header_len: u64,
    /// One entry per chunk of the stream, in stream order. The table is
    /// fully *resolved*: each entry names the checkpoint directory that
    /// physically holds the chunk's bytes, so loading never walks
    /// ancestor manifests.
    pub chunks: Vec<ChunkEntry>,
}

/// Address of a chunk's bytes inside a segment store (manifest v4): the
/// segment file id within the source checkpoint, and the absolute byte
/// offset of the chunk's payload inside that file (past the segment
/// header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentRef {
    /// Segment index within the source checkpoint (names the file via
    /// [`DeltaSection::segment_file`]).
    pub seg: u32,
    /// Absolute byte offset of the chunk payload inside the segment
    /// file (≥ the segment header length).
    pub offset: u64,
}

/// One fixed-size chunk of an incremental checkpoint's stream.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkEntry {
    /// Content hash of the chunk's **raw** bytes
    /// ([`crate::serialize::format::checksum64_slice`]), used for dirty
    /// detection when the *next* delta diffs against this table, and to
    /// verify the *decoded* bytes on restore — codec-blind either way.
    pub hash: u64,
    /// Raw chunk length in bytes (== `chunk_size` except for the last).
    pub len: u64,
    /// Sibling directory name holding the chunk's bytes; `None` means
    /// this checkpoint's own directory (the chunk was written by this
    /// checkpoint — a *dirty* chunk).
    pub source: Option<String>,
    /// Device root the chunk's store was striped onto (resolved against
    /// the *source* checkpoint directory); `None` = no device routing.
    pub device: Option<String>,
    /// Segment-store address of the chunk's bytes (v4). `None` marks
    /// the legacy v3 layout: one `chunk-NNNNNN.fpck` file per chunk,
    /// named by the chunk's index via [`DeltaSection::chunk_file`].
    pub seg: Option<SegmentRef>,
    /// Codec that encoded the stored bytes (v6;
    /// [`CodecKind::None`] for every pre-v6 manifest).
    pub codec: CodecKind,
    /// Stored (encoded) length in bytes — the chunk's footprint inside
    /// its segment file. Equal to `len` when `codec` is `None`.
    pub enc_len: u64,
    /// For [`CodecKind::QuantDelta`] chunks: where the raw **base**
    /// bytes the diff was taken against live. `None` for every other
    /// codec. The base is always stored raw (diffs are depth-1), so
    /// decoding never recurses.
    pub base: Option<ChunkBaseRef>,
}

impl ChunkEntry {
    /// A raw (codec-`None`) entry — the v5-and-earlier shape.
    pub fn raw(
        hash: u64,
        len: u64,
        source: Option<String>,
        device: Option<String>,
        seg: Option<SegmentRef>,
    ) -> ChunkEntry {
        ChunkEntry {
            hash,
            len,
            source,
            device,
            seg,
            codec: CodecKind::None,
            enc_len: len,
            base: None,
        }
    }

    /// Bytes this chunk occupies on disk (the encoded length).
    pub fn stored_len(&self) -> u64 {
        self.enc_len
    }
}

/// Segment address of the raw base chunk a quantized-delta chunk was
/// diffed against (manifest v6). Mirrors the `source`/`device`/`seg`
/// triple of a [`ChunkEntry`], resolved the same way.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkBaseRef {
    /// Sibling directory holding the base bytes; `None` = own dir.
    pub source: Option<String>,
    /// Device root of the base chunk's segment store.
    pub device: Option<String>,
    /// Segment address of the base chunk's raw bytes.
    pub seg: SegmentRef,
    /// Raw length of the base chunk — must equal the chunk's `len`
    /// (the quantized diff is positionwise).
    pub len: u64,
}

impl DeltaSection {
    /// Canonical chunk file name for chunk `index` (legacy v3 layout).
    pub fn chunk_file(index: usize) -> String {
        format!("chunk-{index:06}.fpck")
    }

    /// Canonical segment file name for segment `index` (v4 layout).
    pub fn segment_file(index: usize) -> String {
        format!("seg-{index:06}.fpseg")
    }

    /// Distinct sibling directory names this manifest's chunk table
    /// references (not including the checkpoint's own directory) — the
    /// ancestors that must stay alive for this checkpoint to load.
    pub fn required_dirs(&self) -> Vec<&str> {
        let mut seen = std::collections::BTreeSet::new();
        self.chunks
            .iter()
            .filter_map(|c| c.source.as_deref())
            .filter(|d| seen.insert(*d))
            .collect()
    }

    /// Bytes held in *this* checkpoint's directory (the dirty chunks),
    /// counted at **raw** (decoded) length — codec-blind, like `hash`
    /// and `len` themselves. The on-disk footprint of an encoded chunk
    /// is its (smaller) `enc_len`.
    pub fn local_bytes(&self) -> u64 {
        self.chunks.iter().filter(|c| c.source.is_none()).map(|c| c.len).sum()
    }

    /// Chunk table tiles `[0, total_len)`. Legacy grid
    /// (`header_len == 0`): every chunk is `chunk_size` bytes except a
    /// shorter final chunk. Header-split grid (`header_len > 0`): chunk
    /// 0 is exactly `header_len` bytes, chunks 1.. tile the rest in
    /// `chunk_size` steps with a shorter final chunk allowed.
    pub fn validate(&self, total_len: u64) -> Result<()> {
        if self.chunk_size == 0 {
            return Err(Error::Format("delta manifest has chunk_size 0".into()));
        }
        let mut pos = 0u64;
        for (i, c) in self.chunks.iter().enumerate() {
            let last = i + 1 == self.chunks.len();
            let ok = if self.header_len > 0 && i == 0 {
                c.len == self.header_len
            } else if last {
                c.len > 0 && c.len <= self.chunk_size
            } else {
                c.len == self.chunk_size
            };
            if !ok {
                return Err(Error::Format(format!(
                    "chunk {i} has length {} (chunk_size {}, header_len {})",
                    c.len, self.chunk_size, self.header_len
                )));
            }
            match c.codec {
                CodecKind::None => {
                    if c.enc_len != c.len {
                        return Err(Error::Format(format!(
                            "chunk {i} is codec none but stores {} of {} bytes",
                            c.enc_len, c.len
                        )));
                    }
                    if c.base.is_some() {
                        return Err(Error::Format(format!(
                            "chunk {i} is codec none but carries a base reference"
                        )));
                    }
                }
                CodecKind::Lz4 => {
                    if c.enc_len == 0 || c.seg.is_none() || c.base.is_some() {
                        return Err(Error::Format(format!(
                            "chunk {i} has a malformed lz4 entry \
                             (enc_len {}, seg {:?}, base {:?})",
                            c.enc_len, c.seg, c.base
                        )));
                    }
                }
                CodecKind::QuantDelta => {
                    let base_ok = c
                        .base
                        .as_ref()
                        .map(|b| b.len == c.len)
                        .unwrap_or(false);
                    if c.enc_len == 0 || c.seg.is_none() || !base_ok {
                        return Err(Error::Format(format!(
                            "chunk {i} has a malformed qdelta entry \
                             (enc_len {}, seg {:?}, base {:?})",
                            c.enc_len, c.seg, c.base
                        )));
                    }
                }
            }
            pos += c.len;
        }
        if pos != total_len {
            return Err(Error::Format(format!(
                "chunks cover {pos} of {total_len} bytes"
            )));
        }
        if self.base.is_none() {
            if let Some(i) = self.chunks.iter().position(|c| c.source.is_some()) {
                return Err(Error::Format(format!(
                    "base checkpoint references foreign chunk {i}"
                )));
            }
        }
        Ok(())
    }

    /// Serialize the delta section at [`MANIFEST_VERSION`]: the chunk
    /// table as the v6 binary record blob plus its string tables and
    /// digest.
    fn to_json(&self) -> Json {
        let mut sources: Vec<&str> = Vec::new();
        let mut devices: Vec<&str> = Vec::new();
        let mut intern = |table: &mut Vec<&str>, s| -> u32 {
            match table.iter().position(|t| *t == s) {
                Some(i) => i as u32,
                None => {
                    table.push(s);
                    (table.len() - 1) as u32
                }
            }
        };
        let mut records = Vec::with_capacity(self.chunks.len() * CHUNK_RECORD_LEN_V6);
        for c in &self.chunks {
            let src = c.source.as_deref().map_or(NO_INDEX, |s| intern(&mut sources, s));
            let dev = c.device.as_deref().map_or(NO_INDEX, |d| intern(&mut devices, d));
            let (seg, off) = c.seg.map_or((NO_INDEX, 0), |r| (r.seg, r.offset));
            records.extend_from_slice(&c.hash.to_le_bytes());
            records.extend_from_slice(&c.len.to_le_bytes());
            records.extend_from_slice(&src.to_le_bytes());
            records.extend_from_slice(&dev.to_le_bytes());
            records.extend_from_slice(&seg.to_le_bytes());
            records.extend_from_slice(&off.to_le_bytes());
            // v6 codec tail
            records.push(c.codec.as_u8());
            records.extend_from_slice(&[0u8; 3]);
            records.extend_from_slice(&c.enc_len.to_le_bytes());
            let (bsrc, bdev, bseg, boff, blen) = match &c.base {
                Some(b) => (
                    b.source.as_deref().map_or(NO_INDEX, |s| intern(&mut sources, s)),
                    b.device.as_deref().map_or(NO_INDEX, |d| intern(&mut devices, d)),
                    b.seg.seg,
                    b.seg.offset,
                    b.len,
                ),
                None => (NO_INDEX, NO_INDEX, NO_INDEX, 0, 0),
            };
            records.extend_from_slice(&bsrc.to_le_bytes());
            records.extend_from_slice(&bdev.to_le_bytes());
            records.extend_from_slice(&bseg.to_le_bytes());
            records.extend_from_slice(&boff.to_le_bytes());
            records.extend_from_slice(&blen.to_le_bytes());
        }
        let digest = checksum64_slice(&records);
        let mut fields = vec![
            ("chain_len", Json::from(self.chain_len as i64)),
            ("chunk_size", Json::from(self.chunk_size as i64)),
            ("chunk_count", Json::from(self.chunks.len() as i64)),
            ("table_digest_hi", Json::from((digest >> 32) as i64)),
            ("table_digest_lo", Json::from((digest & 0xffff_ffff) as i64)),
            ("chunk_table", Json::str(&hex_encode(&records))),
        ];
        if !sources.is_empty() {
            fields.push(("sources", Json::arr(sources.iter().map(|s| Json::str(s)))));
        }
        if !devices.is_empty() {
            fields.push(("devices", Json::arr(devices.iter().map(|d| Json::str(d)))));
        }
        if self.header_len > 0 {
            fields.push(("header_len", Json::from(self.header_len as i64)));
        }
        if let Some(base) = &self.base {
            fields.push(("base", Json::str(base)));
        }
        Json::obj(fields)
    }

    fn from_json(v: &Json, version: i64) -> Result<DeltaSection> {
        let base = match v.opt("base") {
            Some(b) => Some(b.as_str()?.to_string()),
            None => None,
        };
        let header_len = match v.opt("header_len") {
            Some(h) => h.as_i64()? as u64,
            None => 0,
        };
        // Fail closed on mixed encodings: a v5 manifest must carry the
        // binary table and nothing else; v2–v4 the JSON array.
        let binary = version >= MANIFEST_BINARY_TABLE_VERSION;
        if binary && v.opt("chunks").is_some() {
            return Err(Error::Format(format!(
                "manifest v{version} must encode its chunk table as `chunk_table`, \
                 found a JSON `chunks` array"
            )));
        }
        if !binary && v.opt("chunk_table").is_some() {
            return Err(Error::Format(format!(
                "manifest v{version} predates the binary chunk table, \
                 found a `chunk_table` field"
            )));
        }
        let chunks = if binary {
            Self::chunks_from_binary(v, version)?
        } else {
            Self::chunks_from_json_array(v)?
        };
        Ok(DeltaSection {
            base,
            chain_len: v.get("chain_len")?.as_i64()? as u64,
            chunk_size: v.get("chunk_size")?.as_i64()? as u64,
            header_len,
            chunks,
        })
    }

    /// Legacy (v2–v4) chunk table: one JSON object per chunk.
    fn chunks_from_json_array(v: &Json) -> Result<Vec<ChunkEntry>> {
        v.get("chunks")?
            .as_array()?
            .iter()
            .map(|c| {
                let hi = c.get("hash_hi")?.as_i64()? as u64;
                let lo = c.get("hash_lo")?.as_i64()? as u64;
                let source = match c.opt("source") {
                    Some(s) => Some(s.as_str()?.to_string()),
                    None => None,
                };
                let device = match c.opt("device") {
                    Some(d) => Some(d.as_str()?.to_string()),
                    None => None,
                };
                let seg = match c.opt("seg") {
                    Some(s) => Some(SegmentRef {
                        seg: s.as_i64()? as u32,
                        offset: c.get("off")?.as_i64()? as u64,
                    }),
                    None => None,
                };
                Ok(ChunkEntry::raw(
                    (hi << 32) | (lo & 0xffff_ffff),
                    c.get("len")?.as_i64()? as u64,
                    source,
                    device,
                    seg,
                ))
            })
            .collect::<Result<Vec<_>>>()
    }

    /// Parse the binary chunk table (v5's 36-byte records or v6's
    /// 76-byte records, selected by the manifest version),
    /// **fail-closed**: every invariant is checked before any entry is
    /// returned — record count and exact blob length, table digest,
    /// string-table indices, non-zero chunk lengths, segment offsets
    /// past the segment header with no arithmetic overflow, per-segment
    /// extent monotonicity (no two chunks of one segment may overlap),
    /// and (v6) codec-id validity, zero pad bytes, codec-consistent
    /// encoded lengths and base references. A corrupted table yields a
    /// typed [`Error::Format`], never a partial or garbage table.
    fn chunks_from_binary(v: &Json, version: i64) -> Result<Vec<ChunkEntry>> {
        let fail =
            |detail: String| Error::Format(format!("manifest v{version} chunk table: {detail}"));
        let record_len = if version >= MANIFEST_CODEC_VERSION {
            CHUNK_RECORD_LEN_V6
        } else {
            CHUNK_RECORD_LEN
        };
        let count = v.get("chunk_count")?.as_i64()?;
        if count < 0 {
            return Err(fail(format!("negative chunk_count {count}")));
        }
        let strings = |key: &str| -> Result<Vec<String>> {
            match v.opt(key) {
                Some(arr) => arr
                    .as_array()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect(),
                None => Ok(Vec::new()),
            }
        };
        let sources = strings("sources")?;
        let devices = strings("devices")?;
        let bytes = hex_decode(v.get("chunk_table")?.as_str()?)
            .map_err(|e| fail(format!("{e}")))?;
        let expect = (count as usize)
            .checked_mul(record_len)
            .ok_or_else(|| fail(format!("chunk_count {count} overflows")))?;
        if bytes.len() != expect {
            return Err(fail(format!(
                "blob is {} bytes, chunk_count {count} needs exactly {expect}",
                bytes.len()
            )));
        }
        let hi = v.get("table_digest_hi")?.as_i64()? as u64;
        let lo = v.get("table_digest_lo")?.as_i64()? as u64;
        let want = (hi << 32) | (lo & 0xffff_ffff);
        let got = checksum64_slice(&bytes);
        if got != want {
            return Err(fail(format!("digest mismatch: computed {got:#x}, manifest {want:#x}")));
        }
        let u32_at = |rec: &[u8], off: usize| {
            u32::from_le_bytes(rec[off..off + 4].try_into().unwrap())
        };
        let u64_at = |rec: &[u8], off: usize| {
            u64::from_le_bytes(rec[off..off + 8].try_into().unwrap())
        };
        let lookup = |table: &[String], idx: u32, what: &str, i: usize| -> Result<Option<String>> {
            match idx {
                NO_INDEX => Ok(None),
                n => table.get(n as usize).cloned().map(Some).ok_or_else(|| {
                    fail(format!("record {i} {what} index {n} out of range ({})", table.len()))
                }),
            }
        };
        let header_len = crate::checkpoint::delta::SEGMENT_HEADER_LEN as u64;
        let mut chunks = Vec::with_capacity(count as usize);
        // (source index, segment, offset, stored len) of every
        // segment-addressed record, for the monotonicity check below.
        // Base references are *aliases* of extents some manifest already
        // owns, so they are bounds-checked but not entered here.
        let mut extents: Vec<(u32, u32, u64, u64)> = Vec::new();
        for (i, rec) in bytes.chunks_exact(record_len).enumerate() {
            let hash = u64_at(rec, 0);
            let len = u64_at(rec, 8);
            if len == 0 {
                return Err(fail(format!("record {i} has zero length")));
            }
            let src_idx = u32_at(rec, 16);
            let source = lookup(&sources, src_idx, "source", i)?;
            let device = lookup(&devices, u32_at(rec, 20), "device", i)?;
            let seg_idx = u32_at(rec, 24);
            let offset = u64_at(rec, 28);
            // v6 codec tail (pre-v6 records are implicitly raw)
            let (codec, enc_len, base) = if record_len == CHUNK_RECORD_LEN_V6 {
                let codec = CodecKind::from_u8(rec[36])
                    .map_err(|_| fail(format!("record {i} has unknown codec id {}", rec[36])))?;
                if rec[37..40] != [0u8; 3] {
                    return Err(fail(format!("record {i} has nonzero pad bytes")));
                }
                let enc_len = u64_at(rec, 40);
                let bsrc = u32_at(rec, 48);
                let bdev = u32_at(rec, 52);
                let bseg = u32_at(rec, 56);
                let boff = u64_at(rec, 60);
                let blen = u64_at(rec, 68);
                let base = if bseg == NO_INDEX {
                    if bsrc != NO_INDEX || bdev != NO_INDEX || boff != 0 || blen != 0 {
                        return Err(fail(format!(
                            "record {i} has no base segment but nonzero base fields"
                        )));
                    }
                    None
                } else {
                    if boff < header_len {
                        return Err(fail(format!(
                            "record {i} base offset {boff} lands inside the segment header"
                        )));
                    }
                    if boff.checked_add(blen).is_none() {
                        return Err(fail(format!("record {i} base extent overflows")));
                    }
                    Some(ChunkBaseRef {
                        source: lookup(&sources, bsrc, "base source", i)?,
                        device: lookup(&devices, bdev, "base device", i)?,
                        seg: SegmentRef { seg: bseg, offset: boff },
                        len: blen,
                    })
                };
                match codec {
                    CodecKind::None => {
                        if enc_len != len {
                            return Err(fail(format!(
                                "record {i} is codec none but encoded length {enc_len} \
                                 != raw length {len}"
                            )));
                        }
                        if base.is_some() {
                            return Err(fail(format!(
                                "record {i} is codec none but carries a base reference"
                            )));
                        }
                    }
                    CodecKind::Lz4 => {
                        if enc_len == 0 {
                            return Err(fail(format!(
                                "record {i} is codec lz4 with zero encoded length"
                            )));
                        }
                        if base.is_some() {
                            return Err(fail(format!(
                                "record {i} is codec lz4 but carries a base reference"
                            )));
                        }
                        if seg_idx == NO_INDEX {
                            return Err(fail(format!(
                                "record {i} is codec lz4 without segment addressing"
                            )));
                        }
                    }
                    CodecKind::QuantDelta => {
                        if enc_len == 0 {
                            return Err(fail(format!(
                                "record {i} is codec qdelta with zero encoded length"
                            )));
                        }
                        if seg_idx == NO_INDEX {
                            return Err(fail(format!(
                                "record {i} is codec qdelta without segment addressing"
                            )));
                        }
                        match &base {
                            None => {
                                return Err(fail(format!(
                                    "record {i} is codec qdelta without a base reference"
                                )));
                            }
                            Some(b) if b.len != len => {
                                return Err(fail(format!(
                                    "record {i} base length {} != raw length {len}",
                                    b.len
                                )));
                            }
                            Some(_) => {}
                        }
                    }
                }
                (codec, enc_len, base)
            } else {
                (CodecKind::None, len, None)
            };
            let seg = if seg_idx == NO_INDEX {
                if offset != 0 {
                    return Err(fail(format!(
                        "record {i} has no segment but a nonzero offset {offset}"
                    )));
                }
                None
            } else {
                if offset < header_len {
                    return Err(fail(format!(
                        "record {i} segment offset {offset} lands inside the segment header"
                    )));
                }
                if offset.checked_add(enc_len).is_none() {
                    return Err(fail(format!("record {i} segment extent overflows")));
                }
                extents.push((src_idx, seg_idx, offset, enc_len));
                Some(SegmentRef { seg: seg_idx, offset })
            };
            chunks.push(ChunkEntry { hash, len, source, device, seg, codec, enc_len, base });
        }
        // Segment extents must be monotone: sorted by offset within one
        // (source, segment) file, consecutive extents never overlap.
        extents.sort_unstable();
        for w in extents.windows(2) {
            let ((s0, g0, off0, len0), (s1, g1, off1, _)) = (w[0], w[1]);
            if s0 == s1 && g0 == g1 && off0 + len0 > off1 {
                return Err(fail(format!(
                    "segment {g0} extents overlap: [{off0}, {}) and offset {off1}",
                    off0 + len0
                )));
            }
        }
        Ok(chunks)
    }
}

/// Lowercase hex encoding of the binary chunk table.
fn hex_encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        out.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        out.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    out
}

/// Strict inverse of [`hex_encode`]: even length, `[0-9a-fA-F]` only.
fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(Error::Format(format!("odd hex length {}", s.len())));
    }
    let digit = |c: char| {
        c.to_digit(16)
            .ok_or_else(|| Error::Format(format!("invalid hex byte {c:?}")))
    };
    let mut out = Vec::with_capacity(s.len() / 2);
    let mut chars = s.chars();
    while let (Some(a), Some(b)) = (chars.next(), chars.next()) {
        out.push(((digit(a)? as u8) << 4) | digit(b)? as u8);
    }
    Ok(out)
}

impl CheckpointManifest {
    /// Build a full-checkpoint manifest from a plan with no device
    /// routing (single-device layout).
    pub fn from_plan(plan: &WritePlan, digest: u64, step: u64) -> CheckpointManifest {
        let unrouted: Vec<Option<String>> = vec![None; plan.partitions.len()];
        Self::from_routed_plan(plan, &unrouted, digest, step)
    }

    /// Build a manifest from a plan plus per-partition device roots (as
    /// recorded by the write path's routing).
    pub fn from_routed_plan(
        plan: &WritePlan,
        devices: &[Option<String>],
        digest: u64,
        step: u64,
    ) -> CheckpointManifest {
        debug_assert_eq!(devices.len(), plan.partitions.len());
        CheckpointManifest {
            total_len: plan.total_len,
            digest,
            step,
            partitions: plan
                .partitions
                .iter()
                .zip(devices)
                .map(|(p, device)| PartitionEntry {
                    file: Self::partition_file(p),
                    writer_rank: p.writer_rank,
                    start: p.start,
                    end: p.end,
                    device: device.clone(),
                })
                .collect(),
            delta: None,
            io_backend: None,
        }
    }

    /// Build an incremental-checkpoint manifest around a chunk table.
    pub fn from_delta(
        total_len: u64,
        digest: u64,
        step: u64,
        delta: DeltaSection,
    ) -> CheckpointManifest {
        CheckpointManifest {
            total_len,
            digest,
            step,
            partitions: Vec::new(),
            delta: Some(delta),
            io_backend: None,
        }
    }

    /// Stamp the submission backend that drained this checkpoint
    /// (`"sync"` / `"ring"` — see
    /// [`crate::io::runtime::IoRuntime::submit_backend_name`]).
    pub fn with_io_backend(mut self, backend: &str) -> CheckpointManifest {
        self.io_backend = Some(backend.to_string());
        self
    }

    /// True if this manifest describes a chunked incremental checkpoint.
    pub fn is_delta(&self) -> bool {
        self.delta.is_some()
    }

    /// Distinct device roots referenced by this checkpoint — partition
    /// or chunk entries (empty for single-device layouts).
    pub fn devices(&self) -> Vec<&str> {
        let mut seen = std::collections::BTreeSet::new();
        self.partitions
            .iter()
            .filter_map(|p| p.device.as_deref())
            .chain(
                self.delta
                    .iter()
                    .flat_map(|d| d.chunks.iter())
                    .filter_map(|c| c.device.as_deref()),
            )
            .filter(|d| seen.insert(*d))
            .collect()
    }

    /// Canonical partition filename for a plan entry.
    pub fn partition_file(p: &Partition) -> String {
        format!("part-{:04}-rank{:05}.fpck", p.index, p.writer_rank)
    }

    /// Serialize to the manifest JSON document (always written at
    /// [`MANIFEST_VERSION`]).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("manifest_version", Json::from(MANIFEST_VERSION)),
            ("total_len", Json::from(self.total_len as i64)),
            ("digest_hi", Json::from((self.digest >> 32) as i64)),
            ("digest_lo", Json::from((self.digest & 0xffff_ffff) as i64)),
            ("step", Json::from(self.step as i64)),
            (
                "partitions",
                Json::arr(self.partitions.iter().map(|p| {
                    let mut fields = vec![
                        ("file", Json::str(&p.file)),
                        ("writer_rank", Json::from(p.writer_rank)),
                        ("start", Json::from(p.start as i64)),
                        ("end", Json::from(p.end as i64)),
                    ];
                    if let Some(device) = &p.device {
                        fields.push(("device", Json::str(device)));
                    }
                    Json::obj(fields)
                })),
            ),
        ];
        if let Some(backend) = &self.io_backend {
            fields.push(("io_backend", Json::str(backend)));
        }
        if let Some(delta) = &self.delta {
            fields.push(("delta", delta.to_json()));
        }
        Json::obj(fields)
    }

    /// Parse a manifest JSON document (v2 or v3; older rejected).
    pub fn from_json(v: &Json) -> Result<CheckpointManifest> {
        let version = v.opt("manifest_version").map(Json::as_i64).transpose()?.unwrap_or(1);
        if !(MANIFEST_MIN_READ_VERSION..=MANIFEST_VERSION).contains(&version) {
            return Err(Error::Format(format!(
                "checkpoint manifest is v{version}, this build reads \
                 v{MANIFEST_MIN_READ_VERSION}..v{MANIFEST_VERSION} \
                 (the stream-digest algorithm changed); re-create the checkpoint"
            )));
        }
        let hi = v.get("digest_hi")?.as_i64()? as u64;
        let lo = v.get("digest_lo")?.as_i64()? as u64;
        let partitions = v
            .get("partitions")?
            .as_array()?
            .iter()
            .map(|p| {
                let device = match p.opt("device") {
                    Some(d) => Some(d.as_str()?.to_string()),
                    None => None,
                };
                Ok(PartitionEntry {
                    file: p.get("file")?.as_str()?.to_string(),
                    writer_rank: p.get("writer_rank")?.as_usize()?,
                    start: p.get("start")?.as_i64()? as u64,
                    end: p.get("end")?.as_i64()? as u64,
                    device,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let delta = match v.opt("delta") {
            Some(d) => Some(DeltaSection::from_json(d, version)?),
            None => None,
        };
        let io_backend = match v.opt("io_backend") {
            Some(b) => Some(b.as_str()?.to_string()),
            None => None,
        };
        Ok(CheckpointManifest {
            total_len: v.get("total_len")?.as_i64()? as u64,
            digest: (hi << 32) | (lo & 0xffff_ffff),
            step: v.get("step")?.as_i64()? as u64,
            partitions,
            delta,
            io_backend,
        })
    }

    /// Write the manifest into `dir` (atomic: temp file + rename).
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        self.save_with(dir, None)
    }

    /// [`CheckpointManifest::save`] with a fault-injection hook at the
    /// publish boundary ([`crate::io::fault::FaultSite::Publish`] — the
    /// rename that commits the checkpoint). An abort fires *before* the
    /// rename, so the checkpoint never publishes; a stale-manifest fault
    /// suppresses the rename but reports success, leaving the temp file
    /// and whatever manifest was previously in place.
    pub fn save_with(
        &self,
        dir: &Path,
        fault: Option<&crate::io::fault::FaultPlan>,
    ) -> Result<PathBuf> {
        let path = dir.join(MANIFEST_FILE);
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, self.to_json().to_string_pretty())?;
        if let Some(f) = fault {
            use crate::io::fault::PublishDecision;
            if f.on_publish()? == PublishDecision::Suppress {
                return Ok(path);
            }
        }
        // atomic publish: the manifest appearing means the checkpoint is
        // complete and durable
        std::fs::rename(&tmp, &path)?;
        // drop any cached parse of the overwritten file (a same-second
        // rewrite could otherwise serve the stale parse)
        invalidate_cached(&path);
        // a (re)published manifest may redefine what this checkpoint's
        // segments mean — drop the serve layer's cached images too
        crate::checkpoint::serve::invalidate_checkpoint(dir);
        Ok(path)
    }

    /// Like [`CheckpointManifest::load`], backed by a small process-wide
    /// LRU of parsed manifests keyed by `(path, mtime, file length)`.
    ///
    /// Steady-state [`crate::checkpoint::delta::prune_chain`] calls
    /// re-examine the same `keep_last` kept manifests every iteration;
    /// the cache makes those re-parses free while a changed file (new
    /// mtime or length) always re-parses. Paths are compared verbatim —
    /// callers should address a manifest through one spelling.
    pub fn load_cached(dir: &Path) -> Result<Arc<CheckpointManifest>> {
        let path = dir.join(MANIFEST_FILE);
        let meta = std::fs::metadata(&path)
            .map_err(|e| Error::Format(format!("manifest {}: {e}", path.display())))?;
        let mtime = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
        let len = meta.len();
        {
            let mut cache = manifest_cache().lock().unwrap();
            if let Some(i) = cache
                .iter()
                .position(|c| c.path == path && c.mtime == mtime && c.len == len)
            {
                let hit = cache.remove(i);
                let parsed = Arc::clone(&hit.parsed);
                cache.push(hit); // most-recently-used at the back
                CACHE_HITS.fetch_add(1, Ordering::Relaxed);
                return Ok(parsed);
            }
        }
        CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
        let parsed = Arc::new(Self::load(dir)?);
        let mut cache = manifest_cache().lock().unwrap();
        cache.retain(|c| c.path != path);
        if cache.len() >= MANIFEST_CACHE_CAP {
            cache.remove(0); // least-recently-used at the front
        }
        cache.push(CachedManifest { path, mtime, len, parsed: Arc::clone(&parsed) });
        Ok(parsed)
    }

    /// Read and validate the manifest of the checkpoint in `dir`.
    pub fn load(dir: &Path) -> Result<CheckpointManifest> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Format(format!("manifest {}: {e}", path.display())))?;
        let m = Self::from_json(&Json::parse(&text)?)?;
        m.validate()?;
        Ok(m)
    }

    /// Whichever table is present must tile [0, total_len) contiguously
    /// (partition table for full checkpoints, chunk table for deltas).
    pub fn validate(&self) -> Result<()> {
        if let Some(delta) = &self.delta {
            if !self.partitions.is_empty() {
                return Err(Error::Format(
                    "manifest has both a partition table and a delta section".into(),
                ));
            }
            return delta.validate(self.total_len);
        }
        let mut pos = 0u64;
        for p in &self.partitions {
            if p.start != pos || p.end < p.start {
                return Err(Error::Format(format!(
                    "partition {} not contiguous (start {} expected {pos})",
                    p.file, p.start
                )));
            }
            pos = p.end;
        }
        if pos != self.total_len {
            return Err(Error::Format(format!(
                "partitions cover {pos} of {} bytes",
                self.total_len
            )));
        }
        Ok(())
    }
}

/// Capacity of the process-wide parsed-manifest LRU (a few chains'
/// worth of kept manifests; entries are small relative to chunk tables
/// being re-parsed every prune).
const MANIFEST_CACHE_CAP: usize = 32;

static CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static CACHE_MISSES: AtomicU64 = AtomicU64::new(0);

struct CachedManifest {
    path: PathBuf,
    mtime: SystemTime,
    len: u64,
    parsed: Arc<CheckpointManifest>,
}

fn manifest_cache() -> &'static Mutex<Vec<CachedManifest>> {
    static CACHE: OnceLock<Mutex<Vec<CachedManifest>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(Vec::new()))
}

fn invalidate_cached(path: &Path) {
    if let Ok(mut cache) = manifest_cache().lock() {
        cache.retain(|c| c.path != path);
    }
}

/// Drop any cached parse for the manifest of the checkpoint at `dir` —
/// call when deleting or demoting a checkpoint so the (possibly large)
/// parsed chunk table doesn't stay pinned in the process-wide LRU.
pub(crate) fn evict_cached(dir: &Path) {
    invalidate_cached(&dir.join(MANIFEST_FILE));
}

/// Process-wide `(hits, misses)` of the parsed-manifest cache —
/// instrumentation for tests and prune diagnostics.
pub fn manifest_cache_stats() -> (u64, u64) {
    (CACHE_HITS.load(Ordering::Relaxed), CACHE_MISSES.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> CheckpointManifest {
        let plan = WritePlan::balanced(100, &[0, 5, 9]).unwrap();
        CheckpointManifest::from_plan(&plan, 0xabcd_ef01_2345_6789, 7)
    }

    #[test]
    fn json_roundtrip() {
        let m = manifest();
        let j = m.to_json();
        let back = CheckpointManifest::from_json(&j).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::io::engine::scratch_dir("manifest").unwrap();
        let m = manifest();
        m.save(&dir).unwrap();
        let back = CheckpointManifest::load(&dir).unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn io_backend_stamp_roundtrips_and_stays_optional() {
        let m = manifest().with_io_backend("ring");
        assert_eq!(m.io_backend.as_deref(), Some("ring"));
        let back = CheckpointManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        // unstamped manifests (and every pre-field fixture) omit the key
        let bare = manifest();
        assert!(bare.io_backend.is_none());
        let Json::Object(fields) = bare.to_json() else { panic!("manifest json is an object") };
        assert!(!fields.contains_key("io_backend"));
        let back = CheckpointManifest::from_json(&Json::Object(fields)).unwrap();
        assert!(back.io_backend.is_none());
    }

    #[test]
    fn v1_manifest_rejected_with_clear_error() {
        let m = manifest();
        let Json::Object(mut fields) = m.to_json() else { panic!("manifest json is an object") };
        fields.remove("manifest_version");
        match CheckpointManifest::from_json(&Json::Object(fields)) {
            Err(Error::Format(msg)) => assert!(msg.contains("manifest is v1"), "{msg}"),
            other => panic!("expected version error, got {other:?}"),
        }
    }

    #[test]
    fn device_assignments_roundtrip() {
        let plan = WritePlan::balanced(1000, &[0, 1, 2, 3]).unwrap();
        let devices = vec![
            Some("/mnt/ssd0".to_string()),
            Some("/mnt/ssd1".to_string()),
            Some("/mnt/ssd0".to_string()),
            Some("/mnt/ssd1".to_string()),
        ];
        let m = CheckpointManifest::from_routed_plan(&plan, &devices, 0x1234, 3);
        assert_eq!(m.devices(), vec!["/mnt/ssd0", "/mnt/ssd1"]);
        let back = CheckpointManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.partitions[1].device.as_deref(), Some("/mnt/ssd1"));
        // single-device manifests carry no device fields
        let single = manifest();
        assert!(single.partitions.iter().all(|p| p.device.is_none()));
        assert!(single.devices().is_empty());
        let back = CheckpointManifest::from_json(&single.to_json()).unwrap();
        assert_eq!(back, single);
    }

    #[test]
    fn validate_catches_gaps() {
        let mut m = manifest();
        m.partitions[1].start += 1;
        assert!(m.validate().is_err());
        let mut m2 = manifest();
        m2.total_len += 5;
        assert!(m2.validate().is_err());
    }

    #[test]
    fn filenames_are_unique_and_ordered() {
        let m = manifest();
        let names: std::collections::BTreeSet<_> =
            m.partitions.iter().map(|p| &p.file).collect();
        assert_eq!(names.len(), m.partitions.len());
        assert!(m.partitions[0].file.starts_with("part-0000"));
    }

    /// Legacy (v3-shaped) delta section: uniform grid, per-chunk files.
    fn delta_manifest() -> CheckpointManifest {
        let delta = DeltaSection {
            base: Some("step-00000003".into()),
            chain_len: 2,
            chunk_size: 64,
            header_len: 0,
            chunks: vec![
                ChunkEntry::raw(0x11, 64, Some("step-00000001".into()), None, None),
                ChunkEntry::raw(0x22, 64, None, Some("/mnt/ssd1".into()), None),
                ChunkEntry::raw(0x33, 10, None, None, None),
            ],
        };
        CheckpointManifest::from_delta(138, 0xfeed_f00d, 4, delta)
    }

    /// v4-shaped delta section: header-split grid, segment-store refs.
    fn segment_manifest() -> CheckpointManifest {
        let delta = DeltaSection {
            base: Some("step-00000003".into()),
            chain_len: 1,
            chunk_size: 64,
            header_len: 100,
            chunks: vec![
                ChunkEntry::raw(
                    0xaa,
                    100, // header chunk: its own (padded) length
                    None,
                    None,
                    Some(SegmentRef { seg: 0, offset: 4096 }),
                ),
                ChunkEntry::raw(
                    0xbb,
                    64,
                    Some("step-00000003".into()),
                    Some("/mnt/ssd0".into()),
                    Some(SegmentRef { seg: 1, offset: 4096 + 640 }),
                ),
                ChunkEntry::raw(0xcc, 30, None, None, Some(SegmentRef { seg: 0, offset: 4196 })),
            ],
        };
        CheckpointManifest::from_delta(194, 0xdead_0001, 9, delta)
    }

    /// v6-shaped delta section exercising all three codecs: a raw header
    /// chunk, an lz4-compressed chunk, and a qdelta chunk whose base
    /// lives in a sibling checkpoint's segment store.
    fn codec_manifest() -> CheckpointManifest {
        let delta = DeltaSection {
            base: Some("step-00000003".into()),
            chain_len: 1,
            chunk_size: 64,
            header_len: 100,
            chunks: vec![
                ChunkEntry::raw(0xaa, 100, None, None, Some(SegmentRef { seg: 0, offset: 4096 })),
                ChunkEntry {
                    hash: 0xbb,
                    len: 64,
                    source: None,
                    device: Some("/mnt/ssd0".into()),
                    seg: Some(SegmentRef { seg: 0, offset: 4196 }),
                    codec: CodecKind::Lz4,
                    enc_len: 20,
                    base: None,
                },
                ChunkEntry {
                    hash: 0xcc,
                    len: 30,
                    source: None,
                    device: None,
                    seg: Some(SegmentRef { seg: 0, offset: 4216 }),
                    codec: CodecKind::QuantDelta,
                    enc_len: 9,
                    base: Some(ChunkBaseRef {
                        source: Some("step-00000003".into()),
                        device: None,
                        seg: SegmentRef { seg: 1, offset: 4096 },
                        len: 30,
                    }),
                },
            ],
        };
        CheckpointManifest::from_delta(194, 0xdead_0002, 10, delta)
    }

    #[test]
    fn delta_json_roundtrip() {
        let m = delta_manifest();
        m.validate().unwrap();
        let back = CheckpointManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert!(back.is_delta());
        assert_eq!(back.devices(), vec!["/mnt/ssd1"]);
        let d = back.delta.as_ref().unwrap();
        assert_eq!(d.required_dirs(), vec!["step-00000001"]);
        assert_eq!(d.local_bytes(), 74);
    }

    #[test]
    fn v2_manifest_without_delta_still_reads() {
        let m = manifest();
        let Json::Object(mut fields) = m.to_json() else { panic!("manifest json is an object") };
        fields.insert("manifest_version".into(), Json::Int(2));
        let back = CheckpointManifest::from_json(&Json::Object(fields)).unwrap();
        assert_eq!(back, m);
        assert!(!back.is_delta());
    }

    #[test]
    fn segment_manifest_roundtrip_and_validation() {
        let m = segment_manifest();
        m.validate().unwrap();
        let back = CheckpointManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        let d = back.delta.as_ref().unwrap();
        assert_eq!(d.header_len, 100);
        assert_eq!(d.chunks[1].seg, Some(SegmentRef { seg: 1, offset: 4096 + 640 }));
        // header chunk must be exactly header_len bytes
        let mut bad = segment_manifest();
        bad.delta.as_mut().unwrap().chunks[0].len = 64;
        bad.total_len -= 36;
        assert!(bad.validate().is_err(), "header chunk length must equal header_len");
        // legacy manifests parse with header_len 0 and no seg refs
        let legacy = CheckpointManifest::from_json(&delta_manifest().to_json()).unwrap();
        let ld = legacy.delta.as_ref().unwrap();
        assert_eq!(ld.header_len, 0);
        assert!(ld.chunks.iter().all(|c| c.seg.is_none()));
    }

    #[test]
    fn delta_validation_catches_bad_tables() {
        // wrong coverage
        let mut m = delta_manifest();
        m.total_len += 1;
        assert!(m.validate().is_err());
        // interior chunk shorter than chunk_size
        let mut m = delta_manifest();
        m.delta.as_mut().unwrap().chunks[0].len = 63;
        assert!(m.validate().is_err());
        // both tables populated
        let mut m = delta_manifest();
        m.partitions = manifest().partitions;
        assert!(m.validate().is_err());
        // a base checkpoint must be self-contained
        let mut m = delta_manifest();
        m.delta.as_mut().unwrap().base = None;
        assert!(m.validate().is_err(), "foreign chunk in a base must fail validation");
        // chunk_size 0
        let mut m = delta_manifest();
        m.delta.as_mut().unwrap().chunk_size = 0;
        assert!(m.validate().is_err());
    }

    /// Re-encode a binary-table manifest after mutating the raw
    /// chunk-table bytes, restoring a valid digest so the per-record
    /// checks are reached. Record width follows the written version
    /// (v6 unless the caller rewrites `manifest_version` afterwards).
    fn rewrite_table(m: &CheckpointManifest, f: impl FnOnce(&mut Vec<u8>)) -> Json {
        let Json::Object(mut fields) = m.to_json() else { panic!("manifest json is an object") };
        let Some(Json::Object(delta)) = fields.get_mut("delta") else { panic!("delta section") };
        let hex = match delta.get("chunk_table") {
            Some(Json::Str(s)) => s.clone(),
            other => panic!("chunk_table missing: {other:?}"),
        };
        let mut bytes = hex_decode(&hex).unwrap();
        f(&mut bytes);
        let digest = checksum64_slice(&bytes);
        delta
            .insert("chunk_count".into(), Json::Int((bytes.len() / CHUNK_RECORD_LEN_V6) as i64));
        delta.insert("table_digest_hi".into(), Json::Int((digest >> 32) as i64));
        delta.insert("table_digest_lo".into(), Json::Int((digest & 0xffff_ffff) as i64));
        delta.insert("chunk_table".into(), Json::Str(hex_encode(&bytes)));
        Json::Object(fields)
    }

    fn expect_table_reject(j: &Json, needle: &str) {
        match CheckpointManifest::from_json(j) {
            Err(Error::Format(msg)) => {
                assert!(msg.contains(needle), "error {msg:?} missing {needle:?}")
            }
            other => panic!("expected fail-closed table error with {needle:?}, got {other:?}"),
        }
    }

    #[test]
    fn table_digest_mismatch_fails_closed() {
        let m = segment_manifest();
        let Json::Object(mut fields) = m.to_json() else { panic!("manifest json is an object") };
        let Some(Json::Object(delta)) = fields.get_mut("delta") else { panic!("delta section") };
        let hex = match delta.get("chunk_table") {
            Some(Json::Str(s)) => s.clone(),
            other => panic!("chunk_table missing: {other:?}"),
        };
        // flip one nibble without updating the recorded digest
        let mut flipped = hex.into_bytes();
        flipped[3] = if flipped[3] == b'0' { b'1' } else { b'0' };
        delta.insert("chunk_table".into(), Json::Str(String::from_utf8(flipped).unwrap()));
        expect_table_reject(&Json::Object(fields), "digest mismatch");
    }

    #[test]
    fn binary_table_rejects_wrong_table_kind() {
        // a binary-table manifest carrying the legacy JSON array must
        // not parse
        let m = delta_manifest();
        let Json::Object(mut fields) = m.to_json() else { panic!("manifest json is an object") };
        let Some(Json::Object(delta)) = fields.get_mut("delta") else { panic!("delta section") };
        let legacy_chunks = Json::arr(std::iter::once(Json::obj(vec![
            ("hash_hi", Json::Int(0)),
            ("hash_lo", Json::Int(0x11)),
            ("len", Json::Int(64)),
        ])));
        delta.insert("chunks".into(), legacy_chunks);
        expect_table_reject(&Json::Object(fields.clone()), "found a JSON `chunks` array");
        // and a v4 manifest carrying a binary table must not parse either
        fields.insert("manifest_version".into(), Json::Int(4));
        let Some(Json::Object(delta)) = fields.get_mut("delta") else { panic!("delta section") };
        delta.remove("chunks");
        match CheckpointManifest::from_json(&Json::Object(fields)) {
            Err(Error::Format(msg)) => {
                assert!(msg.contains("predates the binary chunk table"), "{msg}")
            }
            other => panic!("expected v4/chunk_table rejection, got {other:?}"),
        }
    }

    #[test]
    fn record_invariants_fail_closed() {
        let m = segment_manifest();
        // zero chunk length
        let j = rewrite_table(&m, |b| b[8..16].fill(0));
        expect_table_reject(&j, "zero length");
        // source index out of range (record 1 carries the only source)
        let j = rewrite_table(&m, |b| {
            b[CHUNK_RECORD_LEN_V6 + 16..CHUNK_RECORD_LEN_V6 + 20]
                .copy_from_slice(&7u32.to_le_bytes());
        });
        expect_table_reject(&j, "source index 7 out of range");
        // segment offset inside the segment header
        let j = rewrite_table(&m, |b| b[28..36].copy_from_slice(&17u64.to_le_bytes()));
        expect_table_reject(&j, "inside the segment header");
        // segment extent overflowing u64
        let j = rewrite_table(&m, |b| b[28..36].copy_from_slice(&u64::MAX.to_le_bytes()));
        expect_table_reject(&j, "overflows");
        // no segment but a nonzero offset
        let j = rewrite_table(&m, |b| {
            b[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
            b[28..36].copy_from_slice(&4096u64.to_le_bytes());
        });
        expect_table_reject(&j, "no segment but a nonzero offset");
        // overlapping extents within one segment: move record 2 (seg 0,
        // off 4196) back so it overlaps record 0's [4096, 4196)
        let j = rewrite_table(&m, |b| {
            let off = 2 * CHUNK_RECORD_LEN_V6 + 28;
            b[off..off + 8].copy_from_slice(&4150u64.to_le_bytes());
        });
        expect_table_reject(&j, "extents overlap");
        // truncated blob vs chunk_count
        let j = rewrite_table(&m, |b| {
            b.truncate(b.len() - 1);
        });
        expect_table_reject(&j, "manifest v6 chunk table");
    }

    #[test]
    fn v6_codec_fields_roundtrip() {
        let m = codec_manifest();
        m.validate().unwrap();
        let back = CheckpointManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        let d = back.delta.as_ref().unwrap();
        assert_eq!(d.chunks[1].codec, CodecKind::Lz4);
        assert_eq!(d.chunks[1].enc_len, 20);
        assert_eq!(d.chunks[2].codec, CodecKind::QuantDelta);
        let b = d.chunks[2].base.as_ref().unwrap();
        assert_eq!(b.source.as_deref(), Some("step-00000003"));
        assert_eq!(b.seg, SegmentRef { seg: 1, offset: 4096 });
        assert_eq!(b.len, 30);
        // stored footprint is the encoded length
        assert_eq!(d.chunks[1].stored_len(), 20);
        assert_eq!(d.chunks[0].stored_len(), 100);
    }

    #[test]
    fn v6_codec_invariants_fail_closed() {
        let m = codec_manifest();
        let r1 = CHUNK_RECORD_LEN_V6; // lz4 record
        let r2 = 2 * CHUNK_RECORD_LEN_V6; // qdelta record
        // unknown codec id
        let j = rewrite_table(&m, |b| b[36] = 9);
        expect_table_reject(&j, "unknown codec id 9");
        // nonzero pad bytes
        let j = rewrite_table(&m, |b| b[37] = 1);
        expect_table_reject(&j, "nonzero pad");
        // codec none with encoded length != raw length
        let j = rewrite_table(&m, |b| b[40..48].copy_from_slice(&99u64.to_le_bytes()));
        expect_table_reject(&j, "codec none but encoded length");
        // codec none carrying base fields
        let j = rewrite_table(&m, |b| {
            b[56..60].copy_from_slice(&0u32.to_le_bytes()); // base seg
            b[60..68].copy_from_slice(&4096u64.to_le_bytes()); // base off
            b[68..76].copy_from_slice(&100u64.to_le_bytes()); // base len
        });
        expect_table_reject(&j, "codec none but carries a base reference");
        // lz4 with zero encoded length
        let j = rewrite_table(&m, |b| b[r1 + 40..r1 + 48].fill(0));
        expect_table_reject(&j, "zero encoded length");
        // lz4 carrying a base reference
        let j = rewrite_table(&m, |b| {
            b[r1 + 56..r1 + 60].copy_from_slice(&0u32.to_le_bytes());
            b[r1 + 60..r1 + 68].copy_from_slice(&4096u64.to_le_bytes());
            b[r1 + 68..r1 + 76].copy_from_slice(&64u64.to_le_bytes());
        });
        expect_table_reject(&j, "codec lz4 but carries a base reference");
        // qdelta without a base (clear the base segment index)
        let j = rewrite_table(&m, |b| {
            b[r2 + 48..r2 + 56].copy_from_slice(&[0xff; 8]); // base src+dev
            b[r2 + 56..r2 + 60].copy_from_slice(&u32::MAX.to_le_bytes());
            b[r2 + 60..r2 + 76].fill(0);
        });
        expect_table_reject(&j, "qdelta without a base");
        // base offset inside the segment header
        let j = rewrite_table(&m, |b| b[r2 + 60..r2 + 68].copy_from_slice(&5u64.to_le_bytes()));
        expect_table_reject(&j, "base offset 5 lands inside the segment header");
        // base length disagreeing with the raw length
        let j = rewrite_table(&m, |b| b[r2 + 68..r2 + 76].copy_from_slice(&7u64.to_le_bytes()));
        expect_table_reject(&j, "base length 7 != raw length 30");
        // sentinel base segment but leftover base fields
        let j = rewrite_table(&m, |b| {
            b[r2 + 56..r2 + 60].copy_from_slice(&u32::MAX.to_le_bytes());
        });
        expect_table_reject(&j, "no base segment but nonzero base fields");
    }

    /// A v5 document (36-byte records, no codec fields) must still parse
    /// — with every entry implicitly raw. Serializes segment_manifest's
    /// entries at the v5 record width by hand.
    #[test]
    fn v5_records_still_parse_as_codec_none() {
        let m = segment_manifest();
        let Json::Object(mut fields) = m.to_json() else { panic!("manifest json is an object") };
        fields.insert("manifest_version".into(), Json::Int(5));
        let Some(Json::Object(delta)) = fields.get_mut("delta") else { panic!("delta section") };
        // rebuild the blob with 36-byte records (drop each codec tail)
        let hex = match delta.get("chunk_table") {
            Some(Json::Str(s)) => s.clone(),
            other => panic!("chunk_table missing: {other:?}"),
        };
        let v6 = hex_decode(&hex).unwrap();
        let mut v5 = Vec::new();
        for rec in v6.chunks_exact(CHUNK_RECORD_LEN_V6) {
            v5.extend_from_slice(&rec[..CHUNK_RECORD_LEN]);
        }
        let digest = checksum64_slice(&v5);
        delta.insert("table_digest_hi".into(), Json::Int((digest >> 32) as i64));
        delta.insert("table_digest_lo".into(), Json::Int((digest & 0xffff_ffff) as i64));
        delta.insert("chunk_table".into(), Json::Str(hex_encode(&v5)));
        let back = CheckpointManifest::from_json(&Json::Object(fields)).unwrap();
        assert_eq!(back, m, "v5 records must parse to the same (raw) entries");
        let d = back.delta.as_ref().unwrap();
        assert!(d.chunks.iter().all(|c| c.codec == CodecKind::None && c.enc_len == c.len));
        // and a v5 document must reject v6-width records (blob length)
        let Json::Object(mut fields) = m.to_json() else { panic!("manifest json is an object") };
        fields.insert("manifest_version".into(), Json::Int(5));
        expect_table_reject(&Json::Object(fields), "manifest v5 chunk table");
    }

    #[test]
    fn v5_hex_round_trips_and_rejects_junk() {
        let bytes: Vec<u8> = (0u16..=255).map(|b| b as u8).collect();
        assert_eq!(hex_decode(&hex_encode(&bytes)).unwrap(), bytes);
        assert!(hex_decode("abc").is_err(), "odd length");
        assert!(hex_decode("zz").is_err(), "non-hex digit");
    }

    #[test]
    fn chunk_file_names_are_ordered() {
        assert_eq!(DeltaSection::chunk_file(0), "chunk-000000.fpck");
        assert!(DeltaSection::chunk_file(1) < DeltaSection::chunk_file(10));
        assert_eq!(DeltaSection::segment_file(0), "seg-000000.fpseg");
        assert!(DeltaSection::segment_file(1) < DeltaSection::segment_file(10));
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = crate::io::engine::scratch_dir("manifest-miss").unwrap();
        assert!(CheckpointManifest::load(&dir).is_err());
        assert!(CheckpointManifest::load_cached(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The LRU key is `(path, mtime, len)`. **Documented limitation:**
    /// an *external* rewrite that preserves the file's byte length
    /// within mtime granularity is invisible to the key and serves the
    /// stale parse — the cache trusts metadata, by design. The reason
    /// this cannot bite across the v6 codec bump: every in-repo publish
    /// goes through [`CheckpointManifest::save_with`], which drops the
    /// cached parse *explicitly* (content-blind), so a manifest
    /// rewritten in place through the real path always re-parses — new
    /// codec fields and all — even when mtime and length collide.
    #[test]
    fn cache_serves_stale_on_external_rewrite_but_never_through_publish() {
        let dir = crate::io::engine::scratch_dir("manifest-codec-cache").unwrap();
        let m = codec_manifest();
        let path = m.save(&dir).unwrap();
        let first = CheckpointManifest::load_cached(&dir).unwrap();
        assert_eq!(first.delta.as_ref().unwrap().chunks[2].codec, CodecKind::QuantDelta);
        let meta = std::fs::metadata(&path).unwrap();
        let (mtime, len) = (meta.modified().unwrap(), meta.len());
        // external rewrite: same byte length (flip hex digits inside the
        // chunk table), mtime forced back — the cache cannot see it
        let mut bytes = std::fs::read(&path).unwrap();
        let pos = {
            let text = String::from_utf8_lossy(&bytes);
            text.find("chunk_table").expect("table field present") + 20
        };
        bytes[pos] = if bytes[pos] == b'0' { b'1' } else { b'0' };
        std::fs::write(&path, &bytes).unwrap();
        let f = std::fs::File::options().write(true).open(&path).unwrap();
        f.set_modified(mtime).unwrap();
        drop(f);
        let meta2 = std::fs::metadata(&path).unwrap();
        assert_eq!((meta2.modified().unwrap(), meta2.len()), (mtime, len));
        let stale = CheckpointManifest::load_cached(&dir).unwrap();
        assert_eq!(
            *stale, *first,
            "equal (path, mtime, len) serves the cached parse — documented limitation"
        );
        // ...but the publish path invalidates content-blind: a rewrite
        // through save() re-parses even if we force the old mtime back
        let mut m2 = codec_manifest();
        m2.delta.as_mut().unwrap().chunks[2].enc_len = 11;
        m2.save(&dir).unwrap();
        let f = std::fs::File::options().write(true).open(&path).unwrap();
        f.set_modified(mtime).unwrap();
        drop(f);
        let fresh = CheckpointManifest::load_cached(&dir).unwrap();
        assert_eq!(
            fresh.delta.as_ref().unwrap().chunks[2].enc_len,
            11,
            "published rewrite must never serve a stale parse"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cached_load_hits_and_invalidates_on_save() {
        let dir = crate::io::engine::scratch_dir("manifest-cache").unwrap();
        let m = manifest();
        m.save(&dir).unwrap();
        let first = CheckpointManifest::load_cached(&dir).unwrap();
        assert_eq!(*first, m);
        let (hits0, _) = manifest_cache_stats();
        let second = CheckpointManifest::load_cached(&dir).unwrap();
        assert_eq!(*second, m);
        let (hits1, _) = manifest_cache_stats();
        assert!(hits1 > hits0, "unchanged manifest must be served from cache");
        // overwriting through save() must invalidate, even within mtime
        // granularity: the fresh parse reflects the new content
        let mut m2 = manifest();
        m2.step = 99;
        m2.save(&dir).unwrap();
        let third = CheckpointManifest::load_cached(&dir).unwrap();
        assert_eq!(third.step, 99, "stale cached parse served after overwrite");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
