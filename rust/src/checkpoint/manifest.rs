//! Per-checkpoint manifest: ties partition files back into one logical
//! serialized stream.
//!
//! Parallel checkpoints are written as one file per writer (the ranks'
//! local SSDs in the paper). The manifest — written by partition 0's
//! writer after all partitions are durable — records the stream length,
//! the partition table, and the digest, so loading can verify and
//! reassemble (allgather) the full checkpoint state.

use std::path::{Path, PathBuf};

use crate::checkpoint::plan::{Partition, WritePlan};
use crate::util::json::Json;
use crate::{Error, Result};

pub const MANIFEST_FILE: &str = "checkpoint.json";

#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointManifest {
    pub total_len: u64,
    pub digest: u64,
    pub step: u64,
    pub partitions: Vec<PartitionEntry>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct PartitionEntry {
    pub file: String,
    pub writer_rank: usize,
    pub start: u64,
    pub end: u64,
}

impl CheckpointManifest {
    pub fn from_plan(plan: &WritePlan, digest: u64, step: u64) -> CheckpointManifest {
        CheckpointManifest {
            total_len: plan.total_len,
            digest,
            step,
            partitions: plan
                .partitions
                .iter()
                .map(|p| PartitionEntry {
                    file: Self::partition_file(p),
                    writer_rank: p.writer_rank,
                    start: p.start,
                    end: p.end,
                })
                .collect(),
        }
    }

    /// Canonical partition filename for a plan entry.
    pub fn partition_file(p: &Partition) -> String {
        format!("part-{:04}-rank{:05}.fpck", p.index, p.writer_rank)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_len", Json::from(self.total_len as i64)),
            ("digest_hi", Json::from((self.digest >> 32) as i64)),
            ("digest_lo", Json::from((self.digest & 0xffff_ffff) as i64)),
            ("step", Json::from(self.step as i64)),
            (
                "partitions",
                Json::arr(self.partitions.iter().map(|p| {
                    Json::obj(vec![
                        ("file", Json::str(&p.file)),
                        ("writer_rank", Json::from(p.writer_rank)),
                        ("start", Json::from(p.start as i64)),
                        ("end", Json::from(p.end as i64)),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CheckpointManifest> {
        let hi = v.get("digest_hi")?.as_i64()? as u64;
        let lo = v.get("digest_lo")?.as_i64()? as u64;
        let partitions = v
            .get("partitions")?
            .as_array()?
            .iter()
            .map(|p| {
                Ok(PartitionEntry {
                    file: p.get("file")?.as_str()?.to_string(),
                    writer_rank: p.get("writer_rank")?.as_usize()?,
                    start: p.get("start")?.as_i64()? as u64,
                    end: p.get("end")?.as_i64()? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(CheckpointManifest {
            total_len: v.get("total_len")?.as_i64()? as u64,
            digest: (hi << 32) | (lo & 0xffff_ffff),
            step: v.get("step")?.as_i64()? as u64,
            partitions,
        })
    }

    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(MANIFEST_FILE);
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, self.to_json().to_string_pretty())?;
        // atomic publish: the manifest appearing means the checkpoint is
        // complete and durable
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    pub fn load(dir: &Path) -> Result<CheckpointManifest> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Format(format!("manifest {}: {e}", path.display())))?;
        let m = Self::from_json(&Json::parse(&text)?)?;
        m.validate()?;
        Ok(m)
    }

    /// Partition table must tile [0, total_len) contiguously.
    pub fn validate(&self) -> Result<()> {
        let mut pos = 0u64;
        for p in &self.partitions {
            if p.start != pos || p.end < p.start {
                return Err(Error::Format(format!(
                    "partition {} not contiguous (start {} expected {pos})",
                    p.file, p.start
                )));
            }
            pos = p.end;
        }
        if pos != self.total_len {
            return Err(Error::Format(format!(
                "partitions cover {pos} of {} bytes",
                self.total_len
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> CheckpointManifest {
        let plan = WritePlan::balanced(100, &[0, 5, 9]).unwrap();
        CheckpointManifest::from_plan(&plan, 0xabcd_ef01_2345_6789, 7)
    }

    #[test]
    fn json_roundtrip() {
        let m = manifest();
        let j = m.to_json();
        let back = CheckpointManifest::from_json(&j).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::io::engine::scratch_dir("manifest").unwrap();
        let m = manifest();
        m.save(&dir).unwrap();
        let back = CheckpointManifest::load(&dir).unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn validate_catches_gaps() {
        let mut m = manifest();
        m.partitions[1].start += 1;
        assert!(m.validate().is_err());
        let mut m2 = manifest();
        m2.total_len += 5;
        assert!(m2.validate().is_err());
    }

    #[test]
    fn filenames_are_unique_and_ordered() {
        let m = manifest();
        let names: std::collections::BTreeSet<_> =
            m.partitions.iter().map(|p| &p.file).collect();
        assert_eq!(names.len(), m.partitions.len());
        assert!(m.partitions[0].file.starts_with("part-0000"));
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = crate::io::engine::scratch_dir("manifest-miss").unwrap();
        assert!(CheckpointManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
