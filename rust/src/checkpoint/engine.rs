//! Parallel checkpoint write coordinator (paper §4.2).
//!
//! Given a model-state snapshot and the DP group holding replicas of it,
//! the engine: (1) serializes once (header + zero-copy payload refs),
//! (2) derives the byte-granularity [`WritePlan`] from the configured
//! [`WriterStrategy`], (3) runs each selected writer concurrently — each
//! writes only its partition, through its own NVMe-optimized sink, with
//! no inter-writer communication — and (4) publishes the manifest once
//! every partition is durable.
//!
//! Writers are threads here (simulated ranks); the per-writer code path
//! is exactly what a real rank process would run.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::checkpoint::manifest::CheckpointManifest;
use crate::checkpoint::plan::WritePlan;
use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::topology::RankPlacement;
use crate::io::engine::{build_engine, IoConfig, WriteStats};
use crate::serialize::writer::SerializedCheckpoint;
use crate::tensor::TensorStore;
use crate::util::json::Json;
use crate::{Error, Result};

/// Result of one completed checkpoint.
#[derive(Debug)]
pub struct CheckpointOutcome {
    pub manifest: CheckpointManifest,
    /// Per-partition write stats, plan order.
    pub stats: Vec<WriteStats>,
    /// Wall latency: serialize start → manifest durable.
    pub latency: Duration,
    pub total_bytes: u64,
}

impl CheckpointOutcome {
    pub fn gbps(&self) -> f64 {
        crate::util::bytes::gbps(self.total_bytes, self.latency.as_secs_f64())
    }
}

/// The FastPersist checkpoint engine.
pub struct CheckpointEngine {
    pub io_cfg: IoConfig,
    pub strategy: WriterStrategy,
    pub sockets_per_node: usize,
}

impl CheckpointEngine {
    pub fn new(io_cfg: IoConfig, strategy: WriterStrategy) -> CheckpointEngine {
        CheckpointEngine { io_cfg, strategy, sockets_per_node: 2 }
    }

    /// The torch.save-equivalent configuration: single writer, buffered.
    pub fn baseline() -> CheckpointEngine {
        CheckpointEngine::new(IoConfig::baseline(), WriterStrategy::Rank0)
    }

    /// Default FastPersist configuration.
    pub fn fastpersist(strategy: WriterStrategy) -> CheckpointEngine {
        CheckpointEngine::new(IoConfig::fastpersist(), strategy)
    }

    /// Write a checkpoint of `store` into `dir` using the DP `group`.
    ///
    /// `extra` is free-form training state recorded in the stream header
    /// (step counter, data cursor, LR schedule — §2.1.3).
    pub fn write(
        &self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
        group: &[RankPlacement],
    ) -> Result<CheckpointOutcome> {
        let start = Instant::now();
        std::fs::create_dir_all(dir)?;
        let step = extra
            .get("step")
            .and_then(|j| j.as_i64().ok())
            .unwrap_or(0) as u64;
        let ser = Arc::new(SerializedCheckpoint::new(store, extra));
        let plan =
            WritePlan::from_strategy(ser.total_len(), group, self.strategy, self.sockets_per_node)?;
        plan.validate()?;

        // Stream digest (over header+data) for reassembly verification —
        // streaming, zero-copy (§Perf: the original collected the whole
        // stream into Vecs, a full extra copy per checkpoint).
        let mut hasher = crate::serialize::format::Checksum64::new();
        ser.emit_range(0, ser.total_len(), &mut |p| {
            hasher.update(p);
            Ok(())
        })?;
        let digest = hasher.finalize();

        // Concurrent partition writers (one thread per simulated rank).
        let results: Vec<Result<WriteStats>> = std::thread::scope(|scope| {
            let handles: Vec<_> = plan
                .partitions
                .iter()
                .map(|p| {
                    let ser = Arc::clone(&ser);
                    let io_cfg = self.io_cfg.clone();
                    let path = dir.join(CheckpointManifest::partition_file(p));
                    let (s, e) = (p.start, p.end);
                    scope.spawn(move || -> Result<WriteStats> {
                        let engine = build_engine(&io_cfg);
                        let mut sink = engine.create(&path, Some(e - s))?;
                        ser.write_range_to(s, e, sink.as_mut())?;
                        sink.finish()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(Error::Internal("writer panicked".into())))
                })
                .collect()
        });
        let stats: Vec<WriteStats> = results.into_iter().collect::<Result<Vec<_>>>()?;

        // All partitions durable → publish the manifest (atomic rename).
        let manifest = CheckpointManifest::from_plan(&plan, digest, step);
        manifest.save(dir)?;

        Ok(CheckpointOutcome {
            total_bytes: ser.total_len(),
            manifest,
            stats,
            latency: start.elapsed(),
        })
    }

    /// Single-writer convenience (DP=1 / quickstart): rank 0 only.
    pub fn write_single(
        &self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
    ) -> Result<CheckpointOutcome> {
        let solo = [RankPlacement { rank: 0, node: 0, socket: 0, local_gpu: 0 }];
        self.write(store, extra, dir, &solo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::load::load_checkpoint;
    use crate::cluster::{ClusterSpec, Parallelism, Topology};
    use crate::io::engine::scratch_dir;
    use crate::tensor::{DType, Tensor};
    use crate::util::rng::Rng;

    fn sample_store(bytes_per_tensor: usize, n: usize) -> TensorStore {
        let mut rng = Rng::new(11);
        let mut s = TensorStore::new();
        for i in 0..n {
            let mut data = vec![0u8; bytes_per_tensor];
            rng.fill_bytes(&mut data);
            s.push(Tensor::new(&format!("t{i}"), DType::U8, vec![bytes_per_tensor], data).unwrap())
                .unwrap();
        }
        s
    }

    fn group(dp: usize) -> Vec<RankPlacement> {
        let t = Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(dp, 1, 1)).unwrap();
        t.dp_group(0)
    }

    fn extra(step: i64) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("step".to_string(), Json::Int(step));
        m
    }

    #[test]
    fn parallel_write_then_load_roundtrip() {
        let dir = scratch_dir("engine-rt").unwrap();
        let store = sample_store(50_000, 7);
        for dp in [1, 2, 4, 8] {
            let ckdir = dir.join(format!("dp{dp}"));
            let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
            let out = engine.write(&store, extra(3), &ckdir, &group(dp)).unwrap();
            assert_eq!(out.stats.len(), dp);
            assert_eq!(out.manifest.step, 3);
            let (loaded, header, _) = load_checkpoint(&ckdir, 4).unwrap();
            assert!(loaded.content_eq(&store), "dp={dp}");
            assert_eq!(header.extra["step"], Json::Int(3));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn baseline_engine_single_partition() {
        let dir = scratch_dir("engine-base").unwrap();
        let store = sample_store(10_000, 3);
        let out = CheckpointEngine::baseline()
            .write(&store, extra(0), &dir, &group(8))
            .unwrap();
        assert_eq!(out.stats.len(), 1); // rank0 strategy
        let (loaded, _, _) = load_checkpoint(&dir, 1).unwrap();
        assert!(loaded.content_eq(&store));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn socket_strategy_on_single_node() {
        let dir = scratch_dir("engine-socket").unwrap();
        let store = sample_store(8_000, 4);
        let engine = CheckpointEngine::fastpersist(WriterStrategy::PerSocket);
        let out = engine.write(&store, extra(1), &dir, &group(16)).unwrap();
        assert_eq!(out.stats.len(), 2); // 2 sockets on a DGX-2 node
        let (loaded, _, _) = load_checkpoint(&dir, 2).unwrap();
        assert!(loaded.content_eq(&store));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn overwrite_same_dir_is_clean() {
        let dir = scratch_dir("engine-ow").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let s1 = sample_store(5000, 2);
        engine.write(&s1, extra(1), &dir, &group(4)).unwrap();
        let s2 = sample_store(5000, 2);
        engine.write(&s2, extra(2), &dir, &group(4)).unwrap();
        let (loaded, _, manifest) = load_checkpoint(&dir, 2).unwrap();
        assert_eq!(manifest.step, 2);
        assert!(loaded.content_eq(&s2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_store_checkpoint() {
        let dir = scratch_dir("engine-empty").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let out = engine
            .write(&TensorStore::new(), extra(0), &dir, &group(4))
            .unwrap();
        assert!(out.total_bytes > 0); // header still exists
        let (loaded, _, _) = load_checkpoint(&dir, 2).unwrap();
        assert!(loaded.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
