//! Parallel checkpoint write coordinator (paper §4.2).
//!
//! Given a model-state snapshot and the DP group holding replicas of it,
//! the engine: (1) serializes once (header + zero-copy payload refs,
//! with the stream digest folded into that single pass), (2) derives the
//! byte-granularity [`WritePlan`] from the configured [`WriterStrategy`],
//! (3) routes each partition onto a device of the runtime's
//! [`crate::io::DeviceMap`] and submits it to the persistent writer pool
//! — each [`crate::io::Ticket`] completes when that partition is
//! durable, with no inter-writer communication — and (4) publishes the
//! manifest once every ticket has completed.
//!
//! The engine owns **no** I/O resources: staging buffers, drain workers
//! and writer threads all belong to the long-lived
//! [`IoRuntime`], shared across checkpoints (and across engines — the
//! pipelined helper and direct `write` calls feed one submission queue).
//! `CheckpointEngine::new` spins up a private runtime for drop-in
//! compatibility; `CheckpointEngine::with_runtime` shares one.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::checkpoint::manifest::CheckpointManifest;
use crate::checkpoint::plan::WritePlan;
use crate::checkpoint::strategy::WriterStrategy;
use crate::cluster::topology::RankPlacement;
use crate::io::engine::{EngineKind, IoConfig, WriteStats};
use crate::io::runtime::{IoRuntime, IoRuntimeConfig, Ticket, WriteJob};
use crate::serialize::writer::SerializedCheckpoint;
use crate::tensor::TensorStore;
use crate::util::json::Json;
use crate::Result;

/// Result of one completed checkpoint.
#[derive(Debug)]
pub struct CheckpointOutcome {
    /// The published manifest.
    pub manifest: CheckpointManifest,
    /// Per-partition (full) or per-segment (delta) write stats, plan
    /// order.
    pub stats: Vec<WriteStats>,
    /// Wall latency: serialize start → manifest durable.
    pub latency: Duration,
    /// Logical stream length in bytes.
    pub total_bytes: u64,
    /// Payload bytes actually written: the whole stream for a full
    /// checkpoint, dirty chunks only (excluding segment headers) for a
    /// delta — the same quantity in both modes, so metrics comparing
    /// them stay consistent.
    pub written_bytes: u64,
    /// Raw payload bytes of what this checkpoint persisted — what an
    /// uncompressed write of the same dirty set would have written.
    /// Equals `written_bytes` when no codec is active.
    pub bytes_raw: u64,
    /// Stored payload bytes after the codec stage (equals
    /// `written_bytes`; kept explicit so the codec ratio
    /// `bytes_encoded / bytes_raw` reads directly off the outcome).
    pub bytes_encoded: u64,
    /// CPU time spent in the per-chunk codec encode stage (zero when no
    /// codec is active).
    pub encode: Duration,
}

impl CheckpointOutcome {
    /// Effective checkpoint throughput in decimal GB/s.
    pub fn gbps(&self) -> f64 {
        crate::util::bytes::gbps(self.total_bytes, self.latency.as_secs_f64())
    }

    /// Aligned extents drained through an O_DIRECT descriptor, summed
    /// over every partition/segment write (0 under a probed fallback —
    /// the trainer's `ckpt_direct_extents` metric).
    pub fn direct_extents(&self) -> u64 {
        self.stats.iter().map(|s| s.direct_extents).sum()
    }

    /// Sub-alignment bytes routed through zeroed bounce buffers, summed
    /// over every partition/segment write (the trainer's
    /// `ckpt_bounce_bytes` metric).
    pub fn bounce_bytes(&self) -> u64 {
        self.stats.iter().map(|s| s.bounce_bytes).sum()
    }

    /// Batched ring submission syscalls, summed over every
    /// partition/segment write (0 end to end on the sync backend — the
    /// trainer's `ckpt_batched_submissions` metric and the proof of
    /// which submission path ran).
    pub fn batched_submissions(&self) -> u64 {
        self.stats.iter().map(|s| s.batched_submissions).sum()
    }

    /// High-water count of sqes handed to the kernel in one submission
    /// syscall, across every partition/segment write.
    pub fn sqes_per_submit_max(&self) -> u64 {
        self.stats.iter().map(|s| s.sqes_per_submit_max).max().unwrap_or(0)
    }

    /// Ring completions reaped, summed over every partition/segment
    /// write (the trainer's `ckpt_completions_reaped` metric).
    pub fn completions_reaped(&self) -> u64 {
        self.stats.iter().map(|s| s.completions_reaped).sum()
    }
}

/// The FastPersist checkpoint engine: a thin coordinator over a shared
/// [`IoRuntime`]. Cloning shares the runtime (cheap).
#[derive(Clone)]
pub struct CheckpointEngine {
    /// Write-path configuration for this engine's submissions.
    pub io_cfg: IoConfig,
    /// Writer-subset selection strategy.
    pub strategy: WriterStrategy,
    /// Sockets per node assumed by socket-aware strategies.
    pub sockets_per_node: usize,
    runtime: Arc<IoRuntime>,
}

impl CheckpointEngine {
    /// Drop-in constructor: builds a private runtime from `io_cfg`.
    /// Prefer [`CheckpointEngine::with_runtime`] to share one runtime
    /// across engines and checkpoints.
    pub fn new(io_cfg: IoConfig, strategy: WriterStrategy) -> CheckpointEngine {
        let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: io_cfg,
            ..IoRuntimeConfig::default()
        }));
        Self::with_runtime(runtime, strategy)
    }

    /// An engine submitting into an existing shared runtime.
    pub fn with_runtime(runtime: Arc<IoRuntime>, strategy: WriterStrategy) -> CheckpointEngine {
        CheckpointEngine {
            io_cfg: runtime.io_config().clone(),
            strategy,
            sockets_per_node: 2,
            runtime,
        }
    }

    /// Override the engine kind for this engine's submissions (e.g. a
    /// buffered baseline sharing a FastPersist runtime).
    pub fn with_kind(mut self, kind: EngineKind) -> CheckpointEngine {
        self.io_cfg.kind = kind;
        self
    }

    /// The torch.save-equivalent configuration: single writer, buffered.
    pub fn baseline() -> CheckpointEngine {
        CheckpointEngine::new(IoConfig::baseline(), WriterStrategy::Rank0)
    }

    /// Default FastPersist configuration.
    pub fn fastpersist(strategy: WriterStrategy) -> CheckpointEngine {
        CheckpointEngine::new(IoConfig::fastpersist(), strategy)
    }

    /// The runtime this engine submits into.
    pub fn runtime(&self) -> &Arc<IoRuntime> {
        &self.runtime
    }

    /// Write a checkpoint of `store` into `dir` using the DP `group`.
    ///
    /// `extra` is free-form training state recorded in the stream header
    /// (step counter, data cursor, LR schedule — §2.1.3). Partition
    /// files land in `dir`, or striped across the runtime's device map
    /// with their assignment recorded in the manifest.
    pub fn write(
        &self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
        group: &[RankPlacement],
    ) -> Result<CheckpointOutcome> {
        let start = Instant::now();
        std::fs::create_dir_all(dir)?;
        let step = extra
            .get("step")
            .and_then(|j| j.as_i64().ok())
            .unwrap_or(0) as u64;
        // One serialization pass: header, payload refs, stream digest.
        let ser = Arc::new(SerializedCheckpoint::new(store, extra));
        let digest = ser.stream_digest();
        let plan =
            WritePlan::from_strategy(ser.total_len(), group, self.strategy, self.sockets_per_node)?;
        plan.validate()?;

        // Route partitions across devices and submit them all to the
        // persistent writer pool; tickets complete as partitions become
        // durable. No engine construction, no thread spawn, no staging
        // allocation happens past this point — only submissions.
        let devices = self.runtime.devices();
        let mut routed: Vec<Option<String>> = Vec::with_capacity(plan.partitions.len());
        let tickets: Vec<Ticket> = plan
            .partitions
            .iter()
            .map(|p| {
                let file = CheckpointManifest::partition_file(p);
                let path = match devices.partition_dir(dir, p.index) {
                    Some((device_dir, root)) => {
                        routed.push(Some(root));
                        device_dir.join(file)
                    }
                    None => {
                        routed.push(None);
                        dir.join(file)
                    }
                };
                self.runtime
                    .submit(WriteJob::range(Arc::clone(&ser), p.start, p.end, path)
                        .with_kind(self.io_cfg.kind))
            })
            .collect();
        let stats: Vec<WriteStats> =
            tickets.into_iter().map(Ticket::wait).collect::<Result<Vec<_>>>()?;

        // All partitions durable → publish the manifest (atomic rename;
        // fault-aware so an injected crash can land between segment
        // durability and the commit point).
        let manifest = CheckpointManifest::from_routed_plan(&plan, &routed, digest, step)
            .with_io_backend(self.runtime.submit_backend_name(dir));
        manifest.save_with(dir, self.runtime.io_config().fault.as_ref())?;

        Ok(CheckpointOutcome {
            total_bytes: ser.total_len(),
            written_bytes: ser.total_len(),
            bytes_raw: ser.total_len(),
            bytes_encoded: ser.total_len(),
            encode: Duration::ZERO,
            manifest,
            stats,
            latency: start.elapsed(),
        })
    }

    /// Single-writer convenience (DP=1 / quickstart): rank 0 only.
    pub fn write_single(
        &self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
    ) -> Result<CheckpointOutcome> {
        let solo = [RankPlacement { rank: 0, node: 0, socket: 0, local_gpu: 0 }];
        self.write(store, extra, dir, &solo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::load::load_checkpoint;
    use crate::cluster::{ClusterSpec, Parallelism, Topology};
    use crate::io::device::DeviceMap;
    use crate::io::engine::scratch_dir;
    use crate::tensor::{DType, Tensor};
    use crate::util::rng::Rng;

    fn sample_store(bytes_per_tensor: usize, n: usize) -> TensorStore {
        let mut rng = Rng::new(11);
        let mut s = TensorStore::new();
        for i in 0..n {
            let mut data = vec![0u8; bytes_per_tensor];
            rng.fill_bytes(&mut data);
            s.push(Tensor::new(&format!("t{i}"), DType::U8, vec![bytes_per_tensor], data).unwrap())
                .unwrap();
        }
        s
    }

    fn group(dp: usize) -> Vec<RankPlacement> {
        let t = Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(dp, 1, 1)).unwrap();
        t.dp_group(0)
    }

    fn extra(step: i64) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("step".to_string(), Json::Int(step));
        m
    }

    #[test]
    fn parallel_write_then_load_roundtrip() {
        let dir = scratch_dir("engine-rt").unwrap();
        let store = sample_store(50_000, 7);
        for dp in [1, 2, 4, 8] {
            let ckdir = dir.join(format!("dp{dp}"));
            let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
            let out = engine.write(&store, extra(3), &ckdir, &group(dp)).unwrap();
            assert_eq!(out.stats.len(), dp);
            assert_eq!(out.manifest.step, 3);
            let (loaded, header, _) = load_checkpoint(&ckdir, engine.runtime()).unwrap();
            assert!(loaded.content_eq(&store), "dp={dp}");
            assert_eq!(header.extra["step"], Json::Int(3));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn baseline_engine_single_partition() {
        let dir = scratch_dir("engine-base").unwrap();
        let store = sample_store(10_000, 3);
        let engine = CheckpointEngine::baseline();
        let out = engine.write(&store, extra(0), &dir, &group(8)).unwrap();
        assert_eq!(out.stats.len(), 1); // rank0 strategy
        let (loaded, _, _) = load_checkpoint(&dir, engine.runtime()).unwrap();
        assert!(loaded.content_eq(&store));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn socket_strategy_on_single_node() {
        let dir = scratch_dir("engine-socket").unwrap();
        let store = sample_store(8_000, 4);
        let engine = CheckpointEngine::fastpersist(WriterStrategy::PerSocket);
        let out = engine.write(&store, extra(1), &dir, &group(16)).unwrap();
        assert_eq!(out.stats.len(), 2); // 2 sockets on a DGX-2 node
        let (loaded, _, _) = load_checkpoint(&dir, engine.runtime()).unwrap();
        assert!(loaded.content_eq(&store));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn overwrite_same_dir_is_clean() {
        let dir = scratch_dir("engine-ow").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let s1 = sample_store(5000, 2);
        engine.write(&s1, extra(1), &dir, &group(4)).unwrap();
        let s2 = sample_store(5000, 2);
        engine.write(&s2, extra(2), &dir, &group(4)).unwrap();
        let (loaded, _, manifest) = load_checkpoint(&dir, engine.runtime()).unwrap();
        assert_eq!(manifest.step, 2);
        assert!(loaded.content_eq(&s2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_store_checkpoint() {
        let dir = scratch_dir("engine-empty").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let out = engine
            .write(&TensorStore::new(), extra(0), &dir, &group(4))
            .unwrap();
        assert!(out.total_bytes > 0); // header still exists
        let (loaded, _, _) = load_checkpoint(&dir, engine.runtime()).unwrap();
        assert!(loaded.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn steady_state_checkpoints_allocate_zero_staging_buffers() {
        // Acceptance: across N consecutive checkpoints through one
        // engine, the staging pool performs ZERO allocations after
        // warm-up — buffer acquisition is off the hot path, engines are
        // built once, sinks only recycle.
        let dir = scratch_dir("engine-steady").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let store = sample_store(200_000, 3);
        // warm-up: one checkpoint plus a deterministic pool prewarm
        engine.write(&store, extra(0), &dir.join("warm"), &group(4)).unwrap();
        engine.runtime().staging().prewarm();
        let allocs = engine.runtime().staging().allocations();
        let acquires = engine.runtime().staging().acquires();
        for i in 1..=3i64 {
            let out = engine
                .write(&store, extra(i), &dir.join(format!("s{i}")), &group(4))
                .unwrap();
            assert_eq!(out.manifest.step, i as u64);
        }
        assert_eq!(
            engine.runtime().staging().allocations(),
            allocs,
            "steady-state checkpoints must not allocate staging buffers"
        );
        assert!(
            engine.runtime().staging().acquires() > acquires,
            "checkpoints must recycle pool buffers (acquires should climb)"
        );
        for i in 1..=3 {
            let (loaded, _, _) =
                load_checkpoint(&dir.join(format!("s{i}")), engine.runtime()).unwrap();
            assert!(loaded.content_eq(&store));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn multi_device_write_records_assignments_and_reloads() {
        let base = scratch_dir("engine-devmap").unwrap();
        let dir = base.join("ckpt");
        let devices = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            devices,
            ..IoRuntimeConfig::default()
        }));
        let engine = CheckpointEngine::with_runtime(runtime, WriterStrategy::AllReplicas);
        let store = sample_store(40_000, 5);
        let out = engine.write(&store, extra(7), &dir, &group(4)).unwrap();
        // every partition recorded on exactly one of the two devices
        assert_eq!(out.manifest.devices().len(), 2);
        for p in &out.manifest.partitions {
            assert!(p.device.is_some());
            assert!(
                !dir.join(&p.file).exists(),
                "device-routed partition must not land in the checkpoint dir"
            );
        }
        let (loaded, header, _) = load_checkpoint(&dir, engine.runtime()).unwrap();
        assert!(loaded.content_eq(&store));
        assert_eq!(header.extra["step"], Json::Int(7));
        std::fs::remove_dir_all(&base).unwrap();
    }
}
