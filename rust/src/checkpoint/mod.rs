//! The FastPersist checkpoint engine (paper §4) — parallel, pipelined,
//! NVMe-optimized checkpoint creation and loading.
//!
//! * [`plan`] — byte-granularity partitioning of the serialized stream
//!   over DP writers (load imbalance ≤ 1 byte, §4.2).
//! * [`strategy`] — writer-subset selection: rank 0 only (baseline), all
//!   replicas, one writer per CPU socket, or a fixed count, chosen to
//!   maximize I/O-hardware utilization while minimizing contention.
//! * [`engine`] — the parallel write coordinator: each selected writer's
//!   partition is submitted to the persistent
//!   [`crate::io::IoRuntime`] writer pool (one ticket per partition),
//!   striped across the runtime's [`crate::io::DeviceMap`],
//!   communication-free.
//! * [`pipeline`] — the decoupled executor overlapping checkpoint writes
//!   with the next iteration's forward/backward (§4.3).
//! * [`lazy`] — the capture/flush split on top of it: generation-tagged
//!   memcpy capture into pooled staging buffers at step end, a flush
//!   scheduler draining generations across following iterations, and
//!   staged backpressure (staging budget + max generations in flight)
//!   as the only trainer stall.
//! * [`load`] — parallel checkpoint loading + allgather reassembly.
//! * [`manifest`] — the per-checkpoint manifest tying partitions back
//!   into one logical stream.
//! * [`delta`] — chunk-granular incremental checkpointing: diff the
//!   serialized stream against the previous checkpoint's chunk table
//!   (hashed inside the serialization pass), pack dirty chunks into
//!   device-striped segment files through the shared runtime, reference
//!   the rest; with chain compaction and segment-granular garbage
//!   collection.
//! * [`codec`] — the pluggable per-chunk codec stage (identity, in-repo
//!   LZ77 block compression, quantized delta encoding) applied between
//!   serialization and segment packing, with exact-byte decoding
//!   verified by the read path's chunk hashes.
//! * [`serve`] — restore-at-scale: concurrent multi-tenant restore
//!   sessions over one shared runtime, with fair read scheduling, a
//!   byte-budgeted segment cache (mmap zero-copy with buffered
//!   fallback), and GC-wired invalidation.

pub mod codec;
pub mod delta;
pub mod engine;
pub mod lazy;
pub mod load;
pub mod manifest;
pub mod pipeline;
pub mod plan;
pub mod serve;
pub mod strategy;

pub use codec::CodecKind;
pub use delta::{CheckpointStrategy, DeltaCheckpointer, DeltaConfig, DeltaOutcome};
pub use engine::{CheckpointEngine, CheckpointOutcome};
pub use lazy::{LazyCheckpointer, LazyConfig, LazyOutcome};
pub use load::load_checkpoint;
pub use manifest::CheckpointManifest;
pub use pipeline::PipelinedCheckpointer;
pub use plan::{Partition, WritePlan};
pub use serve::{CacheStats, RestoreService, RestoreSession, ServeConfig};
pub use strategy::WriterStrategy;
