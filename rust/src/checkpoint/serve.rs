//! Restore-at-scale serving: concurrent multi-tenant restores over one
//! shared [`IoRuntime`], backed by a byte-budgeted segment read cache.
//!
//! FastPersist's write path assumes checkpoints are consumed as fast as
//! they are produced — by fault-tolerant resume *and* by downstream
//! serving (evaluation workers, inference warm-up) fanning in on the
//! same step directories. The loader ([`crate::checkpoint::load`])
//! restores one checkpoint at a time; this module turns it into a
//! service:
//!
//! * **[`RestoreService`]** owns the shared pieces: the I/O runtime,
//!   the [`SegmentCache`], and a fair scheduler. Each consumer takes a
//!   per-tenant [`RestoreSession`] handle and calls
//!   [`RestoreSession::restore`] from its own thread.
//! * **Fair scheduling.** Disk [`ReadJob`]s from all sessions funnel
//!   through one round-robin scheduler that dispatches at most
//!   `reader_threads` jobs at a time, one job per tenant per rotation —
//!   a 16-segment restore cannot monopolize the reader pool while a
//!   one-segment tenant starves. The dispatch order is recorded
//!   ([`RestoreService::dispatch_log`]) so fairness is testable.
//! * **Segment read cache.** Immutable `.fpseg` files are admitted
//!   whole once they have been read [`ServeConfig::admit_after`] times,
//!   held under a byte budget with LRU eviction, and served zero-copy
//!   via mmap ([`crate::io::device::MappedFile`]) with a buffered
//!   `Vec<u8>` fallback. Cache service runs the **same validation** as
//!   a disk read ([`ReadJob::serve_from`]): container prefix, run
//!   bounds, and every chunk hash — a poisoned or stale image can never
//!   reach the caller; it is dropped and the job falls back to disk.
//! * **Invalidation.** Segment GC ([`crate::checkpoint::delta`]) and
//!   manifest publication call [`invalidate_path`] /
//!   [`invalidate_checkpoint`], which fan out over every live cache via
//!   a process-wide registry, so a pruned or rewritten segment is
//!   dropped promptly. Freshness is additionally validated per hit
//!   against the file's `(mtime, length)`, and correctness per chunk
//!   hash — three independent layers.
//!
//! Restores that race GC are safe by construction: a cached image
//! serves the pre-prune bytes (still hash-verified against the
//! manifest being restored), a dropped entry falls through to the disk
//! path, and a deleted file there yields a clean error — never a torn
//! mix.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant, SystemTime};

use crate::checkpoint::load::{finish_restore, plan_restore_jobs, LoadedCheckpoint};
use crate::checkpoint::manifest::CheckpointManifest;
use crate::io::device::{DeviceMap, MappedFile};
use crate::io::read::{ReadJob, ReadStats};
use crate::io::runtime::{IoRuntime, ReadTicket};
use crate::{Error, Result};

/// Tuning knobs of one [`RestoreService`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Segment-cache byte budget; `0` disables the cache entirely
    /// (every job goes to disk through the fair scheduler).
    pub cache_bytes: u64,
    /// Accesses to one segment file before it is admitted (fetched
    /// whole into the cache). `1` admits on first touch.
    pub admit_after: u32,
    /// Serve admitted segments from an mmap of the file (zero-copy)
    /// instead of a heap snapshot. Falls back to the heap snapshot
    /// where mmap is unavailable.
    pub mmap: bool,
    /// Coalesce byte-adjacent chunk reads in the planned jobs
    /// (mirrors [`crate::checkpoint::load::RestoreOptions::coalesce`]).
    pub coalesce: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { cache_bytes: 0, admit_after: 2, mmap: true, coalesce: true }
    }
}

impl ServeConfig {
    /// Default config with the cache enabled at `bytes` budget.
    pub fn with_cache(bytes: u64) -> ServeConfig {
        ServeConfig { cache_bytes: bytes, ..ServeConfig::default() }
    }
}

/// Point-in-time counters of one [`SegmentCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Segment jobs served from a cached image.
    pub hits: u64,
    /// Segment jobs that found no (valid) cached image.
    pub misses: u64,
    /// Segment files fetched whole into the cache.
    pub admitted: u64,
    /// Entries evicted by the byte-budget LRU.
    pub evicted: u64,
    /// Entries dropped by invalidation (GC hooks, stale validators,
    /// or a failed cache service).
    pub invalidated: u64,
    /// Admissions refused (file over budget, every resident entry
    /// pinned, or the fetched image failed the job's validation).
    pub rejected: u64,
    /// Bytes fetched from disk into cache images (admission traffic).
    pub fetched_bytes: u64,
    /// Bytes currently held by resident entries.
    pub bytes_held: u64,
    /// Resident entries.
    pub entries: u64,
    /// The configured byte budget.
    pub budget: u64,
}

/// Backing storage of one cached segment image.
enum SegmentBytes {
    /// Zero-copy mapping of the (immutable) segment file.
    Mapped(MappedFile),
    /// Heap snapshot — the portable fallback.
    Heap(Vec<u8>),
}

impl SegmentBytes {
    fn as_slice(&self) -> &[u8] {
        match self {
            SegmentBytes::Mapped(m) => m.bytes(),
            SegmentBytes::Heap(v) => v,
        }
    }
}

/// One resident cache entry: the whole segment file image plus the
/// freshness validator captured when it was fetched.
struct Entry {
    bytes: Arc<SegmentBytes>,
    len: u64,
    mtime: SystemTime,
    file_len: u64,
    last_use: u64,
    pins: u32,
}

struct CacheInner {
    entries: HashMap<PathBuf, Entry>,
    bytes_held: u64,
    tick: u64,
    /// Per-path access counts driving admission. Bounded: cleared
    /// wholesale past [`ACCESS_MAP_CAP`] (admission restarts counting —
    /// an availability knob, never a correctness one).
    accesses: HashMap<PathBuf, u32>,
}

/// Upper bound on the admission-counting map before it is reset.
const ACCESS_MAP_CAP: usize = 1 << 16;

/// Decrements its entry's pin count on drop. Held across a cache
/// service so LRU eviction cannot drop the bytes an in-flight restore
/// is copying from.
struct PinGuard<'a> {
    cache: &'a SegmentCache,
    path: PathBuf,
}

impl Drop for PinGuard<'_> {
    fn drop(&mut self) {
        let mut inner = self.cache.inner.lock().unwrap();
        if let Some(e) = inner.entries.get_mut(&self.path) {
            e.pins = e.pins.saturating_sub(1);
        }
    }
}

/// Whole-file segment read cache: access-count admission, byte-budget
/// LRU eviction that skips pinned entries, `(mtime, length)` freshness
/// validation per hit, and registry-fanned invalidation.
pub struct SegmentCache {
    budget: u64,
    admit_after: u32,
    mmap: bool,
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    admitted: AtomicU64,
    evicted: AtomicU64,
    invalidated: AtomicU64,
    rejected: AtomicU64,
    fetched_bytes: AtomicU64,
}

impl SegmentCache {
    fn new(cfg: &ServeConfig) -> SegmentCache {
        SegmentCache {
            budget: cfg.cache_bytes,
            admit_after: cfg.admit_after.max(1),
            mmap: cfg.mmap,
            inner: Mutex::new(CacheInner {
                entries: HashMap::new(),
                bytes_held: 0,
                tick: 0,
                accesses: HashMap::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            invalidated: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            fetched_bytes: AtomicU64::new(0),
        }
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> CacheStats {
        let (bytes_held, entries) = {
            let inner = self.inner.lock().unwrap();
            (inner.bytes_held, inner.entries.len() as u64)
        };
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            invalidated: self.invalidated.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            fetched_bytes: self.fetched_bytes.load(Ordering::Relaxed),
            bytes_held,
            entries,
            budget: self.budget,
        }
    }

    /// `true` when `entry` still describes the file at `path` — the
    /// per-hit freshness validator. A missing or rewritten file (new
    /// length or mtime) invalidates the image.
    fn still_valid(path: &Path, entry: &Entry) -> bool {
        match std::fs::metadata(path) {
            Ok(m) => {
                m.len() == entry.file_len
                    && m.modified().unwrap_or(SystemTime::UNIX_EPOCH) == entry.mtime
            }
            Err(_) => false,
        }
    }

    /// Hit path: a valid resident image for `path`, pinned against
    /// eviction until the returned guard drops.
    fn lookup(&self, path: &Path) -> Option<(Arc<SegmentBytes>, PinGuard<'_>)> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let valid = inner.entries.get(path).map(|e| Self::still_valid(path, e));
        match valid {
            Some(true) => {
                let e = inner.entries.get_mut(path).expect("entry just checked");
                e.last_use = tick;
                e.pins += 1;
                let bytes = Arc::clone(&e.bytes);
                Some((bytes, PinGuard { cache: self, path: path.to_path_buf() }))
            }
            Some(false) => {
                // stale image: drop it now (an Arc held by a concurrent
                // reader keeps serving the old — still hash-verified —
                // bytes; this entry just stops being findable)
                let e = inner.entries.remove(path).expect("entry just checked");
                inner.bytes_held -= e.len;
                self.invalidated.fetch_add(1, Ordering::Relaxed);
                None
            }
            None => None,
        }
    }

    /// Miss path: count the access and, at the admission threshold,
    /// fetch the whole file. Returns the fetched image (not yet
    /// resident — [`SegmentCache::insert`] follows a successful serve).
    fn note_miss_and_maybe_fetch(
        &self,
        path: &Path,
    ) -> Option<(Arc<SegmentBytes>, SystemTime, u64)> {
        let count = {
            let mut inner = self.inner.lock().unwrap();
            if inner.accesses.len() >= ACCESS_MAP_CAP {
                inner.accesses.clear();
            }
            let c = inner.accesses.entry(path.to_path_buf()).or_insert(0);
            *c = c.saturating_add(1);
            *c
        };
        if count < self.admit_after {
            return None;
        }
        let meta = std::fs::metadata(path).ok()?;
        let file_len = meta.len();
        if file_len == 0 || file_len > self.budget {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let mtime = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
        let bytes = self.fetch(path)?;
        Some((bytes, mtime, file_len))
    }

    /// Read the whole file: mmap when configured and available, heap
    /// snapshot otherwise.
    fn fetch(&self, path: &Path) -> Option<Arc<SegmentBytes>> {
        if self.mmap {
            if let Ok(Some(m)) = MappedFile::map(path) {
                self.fetched_bytes.fetch_add(m.bytes().len() as u64, Ordering::Relaxed);
                return Some(Arc::new(SegmentBytes::Mapped(m)));
            }
        }
        let v = std::fs::read(path).ok()?;
        self.fetched_bytes.fetch_add(v.len() as u64, Ordering::Relaxed);
        Some(Arc::new(SegmentBytes::Heap(v)))
    }

    /// Make a fetched image resident, evicting LRU **unpinned** entries
    /// until it fits the budget. Refused (counted in `rejected`) when
    /// the pinned residue leaves no room — bytes held never exceed the
    /// budget, and a pinned entry is never the victim.
    fn insert(&self, path: PathBuf, bytes: Arc<SegmentBytes>, mtime: SystemTime, file_len: u64) {
        let len = bytes.as_slice().len() as u64;
        let mut inner = self.inner.lock().unwrap();
        if inner.entries.contains_key(&path) {
            return; // raced with another admission of the same file
        }
        while inner.bytes_held + len > self.budget {
            let victim = inner
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_use)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let e = inner.entries.remove(&k).expect("victim just found");
                    inner.bytes_held -= e.len;
                    self.evicted.fetch_add(1, Ordering::Relaxed);
                }
                None => {
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
        }
        inner.tick += 1;
        let last_use = inner.tick;
        inner.bytes_held += len;
        inner
            .entries
            .insert(path, Entry { bytes, len, mtime, file_len, last_use, pins: 0 });
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Serve `job` from the cache if possible. `Err(job)` hands the job
    /// back for the disk path — on a plain miss, a refused admission,
    /// or a cached/fetched image that failed the job's validation
    /// (which also drops the offending entry).
    fn try_serve(&self, job: ReadJob) -> std::result::Result<ReadStats, ReadJob> {
        if self.budget == 0 {
            return Err(job);
        }
        if let Some((bytes, _pin)) = self.lookup(&job.path) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            match job.serve_from(bytes.as_slice()) {
                Ok(stats) => return Ok(stats),
                Err(_) => {
                    // poisoned or outdated image: drop it and let the
                    // disk read decide (it re-verifies every chunk)
                    drop(_pin);
                    self.invalidate(&job.path);
                    return Err(job);
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let Some((bytes, mtime, file_len)) = self.note_miss_and_maybe_fetch(&job.path) else {
            return Err(job);
        };
        // Correctness gate before residency: the image must satisfy
        // this job (prefix, bounds, chunk hashes) to be cached at all.
        match job.serve_from(bytes.as_slice()) {
            Ok(stats) => {
                self.insert(job.path.clone(), bytes, mtime, file_len);
                Ok(stats)
            }
            Err(_) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Err(job)
            }
        }
    }

    /// Drop the entry for `path` (regardless of pins — concurrent
    /// readers keep their `Arc` to the old image) and its admission
    /// count.
    fn invalidate(&self, path: &Path) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.entries.remove(path) {
            inner.bytes_held -= e.len;
            self.invalidated.fetch_add(1, Ordering::Relaxed);
        }
        inner.accesses.remove(path);
    }

    /// Drop every entry belonging to the checkpoint at `dir`: paths
    /// under `dir` itself and paths under its device-side
    /// `fpck-<tag>` directories.
    fn invalidate_dir(&self, dir: &Path, tag: &str) {
        let mut inner = self.inner.lock().unwrap();
        let matches = |p: &Path| {
            p.starts_with(dir) || p.iter().any(|c| c.to_str() == Some(tag))
        };
        let victims: Vec<PathBuf> =
            inner.entries.keys().filter(|p| matches(p)).cloned().collect();
        for k in victims {
            let e = inner.entries.remove(&k).expect("victim just listed");
            inner.bytes_held -= e.len;
            self.invalidated.fetch_add(1, Ordering::Relaxed);
        }
        inner.accesses.retain(|p, _| !matches(p));
    }
}

/// Process-wide registry of live caches, so GC and manifest publication
/// can invalidate across every service without owning one.
fn registry() -> &'static Mutex<Vec<Weak<SegmentCache>>> {
    static REG: OnceLock<Mutex<Vec<Weak<SegmentCache>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

/// Drop the cached image of the segment file at `path` in every live
/// cache. Called by segment GC right after a `.fpseg` is removed or
/// rewritten. Paths are compared verbatim (same caveat as the manifest
/// LRU); the per-hit `(mtime, length)` validator and the per-chunk
/// hashes independently stop a differently-spelled stale path from
/// serving wrong bytes.
pub fn invalidate_path(path: &Path) {
    let mut reg = registry().lock().unwrap();
    reg.retain(|w| match w.upgrade() {
        Some(c) => {
            c.invalidate(path);
            true
        }
        None => false,
    });
}

/// Drop every cached image belonging to the checkpoint at `dir` (its
/// own segment files and its device-side `fpck-<tag>` directories) in
/// every live cache. Called when a checkpoint directory is pruned and
/// when a manifest is (re)published into `dir`.
pub fn invalidate_checkpoint(dir: &Path) {
    let tag = DeviceMap::checkpoint_tag(dir);
    let mut reg = registry().lock().unwrap();
    reg.retain(|w| match w.upgrade() {
        Some(c) => {
            c.invalidate_dir(dir, &tag);
            true
        }
        None => false,
    });
}

/// One queued disk job awaiting fair dispatch.
struct Pending {
    job: ReadJob,
    tx: Sender<Result<ReadStats>>,
}

/// One dispatched job whose ticket is being polled by the pump.
struct Inflight {
    ticket: ReadTicket,
    tx: Sender<Result<ReadStats>>,
}

struct SchedState {
    /// Per-session FIFO queues of undispatched jobs.
    queues: BTreeMap<u64, VecDeque<Pending>>,
    /// Round-robin rotation of session ids with queued work.
    order: VecDeque<u64>,
    /// Dispatched, incomplete jobs (bounded by the reader-thread cap).
    inflight: Vec<Inflight>,
    /// Session id per dispatch, in dispatch order (fairness
    /// instrumentation; capped at [`DISPATCH_LOG_CAP`]).
    dispatch_log: Vec<u64>,
}

/// Upper bound on the recorded dispatch log.
const DISPATCH_LOG_CAP: usize = 1 << 16;

/// Cooperative fair scheduler: sessions enqueue their jobs and then
/// pump the shared state — completing finished tickets and dispatching
/// one job per session with work, round-robin, while fewer than
/// `reader_threads` jobs are in flight. There is no dedicated scheduler
/// thread; any waiting session drives progress for all of them.
struct FairScheduler {
    state: Mutex<SchedState>,
}

impl FairScheduler {
    fn new() -> FairScheduler {
        FairScheduler {
            state: Mutex::new(SchedState {
                queues: BTreeMap::new(),
                order: VecDeque::new(),
                inflight: Vec::new(),
                dispatch_log: Vec::new(),
            }),
        }
    }

    /// One pump round: retire completed tickets, then dispatch up to
    /// the reader-thread cap, one job per session per rotation.
    fn pump(&self, runtime: &IoRuntime) {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        let mut i = 0;
        while i < st.inflight.len() {
            match st.inflight[i].ticket.try_wait() {
                Some(res) => {
                    let inf = st.inflight.swap_remove(i);
                    let _ = inf.tx.send(res);
                }
                None => i += 1,
            }
        }
        let cap = runtime.reader_threads().max(1);
        while st.inflight.len() < cap {
            let rotation = st.order.len();
            let mut dispatched = false;
            for _ in 0..rotation {
                let Some(sid) = st.order.pop_front() else { break };
                let Some(q) = st.queues.get_mut(&sid) else { continue };
                let Some(p) = q.pop_front() else {
                    st.queues.remove(&sid);
                    continue;
                };
                if q.is_empty() {
                    st.queues.remove(&sid);
                } else {
                    st.order.push_back(sid);
                }
                let ticket = runtime.submit_read(p.job);
                st.inflight.push(Inflight { ticket, tx: p.tx });
                if st.dispatch_log.len() < DISPATCH_LOG_CAP {
                    st.dispatch_log.push(sid);
                }
                dispatched = true;
                break;
            }
            if !dispatched {
                break;
            }
        }
    }

    /// Run `jobs` for session `sid` through the shared rotation; blocks
    /// (pumping) until **all** of them complete, so the caller's stream
    /// buffer is no longer referenced whichever way this returns.
    /// Returns the merged stats, or the first error.
    fn run(&self, runtime: &IoRuntime, sid: u64, jobs: Vec<ReadJob>) -> Result<ReadStats> {
        let total = jobs.len();
        if total == 0 {
            return Ok(ReadStats::default());
        }
        let (tx, rx): (Sender<Result<ReadStats>>, Receiver<Result<ReadStats>>) = mpsc::channel();
        {
            let mut st = self.state.lock().unwrap();
            let had_work = st.queues.contains_key(&sid);
            let q = st.queues.entry(sid).or_default();
            for job in jobs {
                q.push_back(Pending { job, tx: tx.clone() });
            }
            if !had_work {
                st.order.push_back(sid);
            }
        }
        drop(tx);
        let mut stats = ReadStats::default();
        let mut first_err = None;
        let mut done = 0usize;
        while done < total {
            self.pump(runtime);
            match rx.try_recv() {
                Ok(res) => {
                    done += 1;
                    match res {
                        Ok(s) => stats.merge(&s),
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                Err(TryRecvError::Empty) => std::thread::sleep(Duration::from_micros(200)),
                Err(TryRecvError::Disconnected) => {
                    return Err(Error::Internal(
                        "restore scheduler dropped queued read jobs".into(),
                    ));
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(stats),
        }
    }
}

/// The concurrent multi-tenant restore service. Construct once around a
/// shared runtime, hand a [`RestoreSession`] to each consumer.
pub struct RestoreService {
    runtime: Arc<IoRuntime>,
    cache: Arc<SegmentCache>,
    sched: FairScheduler,
    cfg: ServeConfig,
    next_session: AtomicU64,
}

impl RestoreService {
    /// Build a service over `runtime` and register its cache for
    /// process-wide invalidation.
    pub fn new(runtime: Arc<IoRuntime>, cfg: ServeConfig) -> Arc<RestoreService> {
        let cache = Arc::new(SegmentCache::new(&cfg));
        {
            let mut reg = registry().lock().unwrap();
            reg.retain(|w| w.strong_count() > 0);
            reg.push(Arc::downgrade(&cache));
        }
        Arc::new(RestoreService {
            runtime,
            cache,
            sched: FairScheduler::new(),
            cfg,
            next_session: AtomicU64::new(0),
        })
    }

    /// A per-tenant handle. Sessions are cheap; take one per consumer
    /// thread.
    pub fn session(self: &Arc<Self>, tenant: impl Into<String>) -> RestoreSession {
        RestoreSession {
            service: Arc::clone(self),
            tenant: tenant.into(),
            id: self.next_session.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// The shared runtime restores execute on.
    pub fn runtime(&self) -> &Arc<IoRuntime> {
        &self.runtime
    }

    /// Segment-cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Session id per dispatched disk job, in dispatch order — the
    /// fairness record: within any window where several sessions had
    /// queued work, their ids interleave instead of running back to
    /// back.
    pub fn dispatch_log(&self) -> Vec<u64> {
        self.sched.state.lock().unwrap().dispatch_log.clone()
    }
}

/// Per-tenant restore handle of a [`RestoreService`].
pub struct RestoreSession {
    service: Arc<RestoreService>,
    tenant: String,
    id: u64,
}

impl RestoreSession {
    /// The tenant label this session was created with.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// The session id recorded in the service's dispatch log.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Restore the checkpoint at `dir`: segment jobs are served from
    /// the cache when possible, everything else goes to disk through
    /// the service's fair scheduler. Bit-identical to
    /// [`crate::checkpoint::load::load_checkpoint`] — same planner,
    /// same folded verification, same stream digest — whatever mix of
    /// cache and disk served the bytes.
    pub fn restore(&self, dir: &Path) -> Result<LoadedCheckpoint> {
        let svc = &self.service;
        let t0 = Instant::now();
        let manifest = CheckpointManifest::load_cached(dir)?;
        let dest = svc.runtime.alloc_stream(manifest.total_len as usize);
        let jobs = plan_restore_jobs(dir, &manifest, &dest, svc.cfg.coalesce, &svc.runtime)?;
        let mut stats = ReadStats::default();
        let mut disk = Vec::with_capacity(jobs.len());
        for job in jobs {
            // Only segment-store files are cacheable: they are immutable
            // and shared across the chain. Partition and legacy chunk
            // files restore through the disk path.
            if job.label == "segment" {
                match svc.cache.try_serve(job) {
                    Ok(s) => stats.merge(&s),
                    Err(job) => disk.push(job),
                }
            } else {
                disk.push(job);
            }
        }
        let disk_stats = svc.sched.run(&svc.runtime, self.id, disk)?;
        stats.merge(&disk_stats);
        finish_restore(dest, (*manifest).clone(), stats, t0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
    use crate::io::engine::{scratch_dir, IoConfig};
    use crate::io::read::{plan_runs, ReadPart, StreamBuffer};
    use crate::prop_assert;
    use crate::tensor::{DType, Tensor, TensorStore};
    use crate::util::rng::Rng;
    use std::collections::BTreeMap as Map;

    fn runtime() -> Arc<IoRuntime> {
        IoRuntime::shared(IoConfig::fastpersist().microbench())
    }

    fn store(seed: u64, nbytes: usize) -> TensorStore {
        let mut data = vec![0u8; nbytes];
        Rng::new(seed).fill_bytes(&mut data);
        let mut s = TensorStore::new();
        s.push(Tensor::new("payload", DType::U8, vec![nbytes], data).unwrap()).unwrap();
        s
    }

    fn mutate(s: &TensorStore, frac: f64, tag: u64) -> TensorStore {
        let t = s.get("payload").unwrap();
        let mut data = t.data.to_vec();
        let span = (data.len() as f64 * frac) as usize;
        let start = (tag as usize * 97) % data.len().saturating_sub(span.max(1)).max(1);
        for (i, b) in data[start..(start + span).min(data.len())].iter_mut().enumerate() {
            *b ^= (tag as u8).wrapping_add(i as u8) | 1;
        }
        let mut out = TensorStore::new();
        out.push(Tensor::new("payload", DType::U8, vec![data.len()], data).unwrap()).unwrap();
        out
    }

    /// Write a base + `n - 1` deltas under `parent`, returning the step
    /// dirs and the final state of each step.
    fn write_chain(
        parent: &Path,
        rt: &Arc<IoRuntime>,
        n: usize,
    ) -> (Vec<PathBuf>, Vec<TensorStore>) {
        let mut ck = DeltaCheckpointer::new(
            Arc::clone(rt),
            DeltaConfig {
                chunk_size: 4096,
                max_chain: 16,
                segment_bytes: 16 << 10,
                ..DeltaConfig::default()
            },
        );
        let mut dirs = Vec::new();
        let mut states = Vec::new();
        let mut s = store(7, 96 * 1024);
        for step in 0..n {
            if step > 0 {
                s = mutate(&s, 0.2, step as u64);
            }
            let dir = parent.join(format!("step-{:08}", step + 1));
            let mut extra = Map::new();
            extra.insert("step".to_string(), crate::util::json::Json::Int((step + 1) as i64));
            ck.write(&s, extra, &dir).unwrap();
            dirs.push(dir);
            states.push(s.clone());
        }
        (dirs, states)
    }

    #[test]
    fn serve_restores_bit_identical_and_warms_the_cache() {
        let base = scratch_dir("serve-basic").unwrap();
        let rt = runtime();
        let (dirs, states) = write_chain(&base, &rt, 3);
        let svc = RestoreService::new(Arc::clone(&rt), ServeConfig::with_cache(64 << 20));
        let session = svc.session("eval-0");
        // cold pass: all disk
        for (dir, want) in dirs.iter().zip(&states) {
            let got = session.restore(dir).unwrap();
            assert!(got.store.content_eq(want), "cold restore must be bit-identical");
        }
        let cold = svc.cache_stats();
        assert_eq!(cold.hits, 0, "first pass cannot hit");
        assert!(cold.misses > 0);
        // second + third passes: admission threshold (2) reached, hits
        for _ in 0..2 {
            for (dir, want) in dirs.iter().zip(&states) {
                let got = session.restore(dir).unwrap();
                assert!(got.store.content_eq(want), "warm restore must be bit-identical");
            }
        }
        let warm = svc.cache_stats();
        assert!(warm.hits > 0, "admitted segments must serve from cache: {warm:?}");
        assert!(warm.admitted > 0);
        assert!(warm.bytes_held <= warm.budget);
        assert_eq!(
            warm.entries,
            warm.admitted - warm.evicted - warm.invalidated,
            "entry lifecycle must reconcile: {warm:?}"
        );
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn zero_budget_disables_the_cache() {
        let base = scratch_dir("serve-nocache").unwrap();
        let rt = runtime();
        let (dirs, states) = write_chain(&base, &rt, 2);
        let svc = RestoreService::new(Arc::clone(&rt), ServeConfig::default());
        let session = svc.session("t");
        for _ in 0..3 {
            let got = session.restore(&dirs[1]).unwrap();
            assert!(got.store.content_eq(&states[1]));
        }
        let s = svc.cache_stats();
        assert_eq!((s.hits, s.admitted, s.entries, s.bytes_held), (0, 0, 0, 0), "{s:?}");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn invalidation_drops_entries_and_refetch_reverifies() {
        let base = scratch_dir("serve-invalidate").unwrap();
        let rt = runtime();
        let (dirs, states) = write_chain(&base, &rt, 2);
        let svc = RestoreService::new(
            Arc::clone(&rt),
            ServeConfig { admit_after: 1, ..ServeConfig::with_cache(64 << 20) },
        );
        let session = svc.session("t");
        session.restore(&dirs[1]).unwrap();
        let admitted = svc.cache_stats();
        assert!(admitted.entries > 0, "admit_after=1 must admit on first pass");
        // checkpoint-level invalidation drops every entry of the chain
        for dir in &dirs {
            invalidate_checkpoint(dir);
        }
        let dropped = svc.cache_stats();
        assert_eq!(dropped.entries, 0, "{dropped:?}");
        assert!(dropped.invalidated >= admitted.entries);
        // refetch after the drop: served bytes still hash-verify
        let got = session.restore(&dirs[1]).unwrap();
        assert!(got.store.content_eq(&states[1]), "refetched segments must verify");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn poisoned_cache_image_falls_back_to_disk() {
        // Corrupt the cached image (not the file): the hit must fail
        // the folded hash check, drop the entry, and the disk path must
        // serve the true bytes.
        let base = scratch_dir("serve-poison").unwrap();
        let rt = runtime();
        let (dirs, states) = write_chain(&base, &rt, 2);
        let svc = RestoreService::new(
            Arc::clone(&rt),
            ServeConfig { admit_after: 1, mmap: false, ..ServeConfig::with_cache(64 << 20) },
        );
        let session = svc.session("t");
        session.restore(&dirs[1]).unwrap();
        // poison every resident heap image in place
        {
            let mut inner = svc.cache.inner.lock().unwrap();
            for e in inner.entries.values_mut() {
                let poisoned: Vec<u8> = e
                    .bytes
                    .as_slice()
                    .iter()
                    .enumerate()
                    .map(|(i, b)| if i % 4097 == 0 { b ^ 0x55 } else { *b })
                    .collect();
                e.bytes = Arc::new(SegmentBytes::Heap(poisoned));
            }
        }
        let got = session.restore(&dirs[1]).unwrap();
        assert!(got.store.content_eq(&states[1]), "poisoned cache must not reach the caller");
        let s = svc.cache_stats();
        assert!(s.invalidated > 0, "poisoned entries must be dropped: {s:?}");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn scheduler_interleaves_tenants_round_robin() {
        let base = scratch_dir("serve-fair").unwrap();
        let rt = Arc::new(IoRuntime::new(crate::io::runtime::IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            reader_threads: 1, // serialize dispatch so the log is exact
            ..crate::io::runtime::IoRuntimeConfig::default()
        }));
        let svc = RestoreService::new(Arc::clone(&rt), ServeConfig::default());
        let sched = &svc.sched;
        // two sessions, three one-run jobs each, enqueued before any
        // pump: with one reader thread the rotation must alternate
        let payload = vec![9u8; 4096];
        let path = base.join("f.bin");
        std::fs::write(&path, &payload).unwrap();
        let mk_jobs = |n: usize, dest: &Arc<StreamBuffer>, off: usize| -> Vec<ReadJob> {
            (0..n)
                .map(|i| ReadJob {
                    path: path.clone(),
                    dest: Arc::clone(dest),
                    runs: plan_runs(
                        vec![ReadPart {
                            file_off: 0,
                            dest_off: (off + i * 4096) as u64,
                            len: 4096,
                        }],
                        true,
                    ),
                    decodes: Vec::new(),
                    checks: Vec::new(),
                    coalesced: 0,
                    expect_file_len: Some(4096),
                    prefix_check: None,
                    kind: None,
                    label: "partition",
                })
                .collect()
        };
        let dest = rt.alloc_stream(6 * 4096);
        let jobs_a = mk_jobs(3, &dest, 0);
        let jobs_b = mk_jobs(3, &dest, 3 * 4096);
        // enqueue both sessions before the first pump so the rotation
        // is fully deterministic, then drive the pump directly
        let (tx_a, rx_a) = mpsc::channel();
        let (tx_b, rx_b) = mpsc::channel();
        {
            let mut st = sched.state.lock().unwrap();
            let qa = st.queues.entry(1).or_default();
            for job in jobs_a {
                qa.push_back(Pending { job, tx: tx_a.clone() });
            }
            st.order.push_back(1);
            let qb = st.queues.entry(2).or_default();
            for job in jobs_b {
                qb.push_back(Pending { job, tx: tx_b.clone() });
            }
            st.order.push_back(2);
        }
        drop(tx_a);
        drop(tx_b);
        let mut done = 0;
        while done < 6 {
            sched.pump(&rt);
            if let Ok(res) = rx_a.try_recv() {
                res.unwrap();
                done += 1;
            }
            if let Ok(res) = rx_b.try_recv() {
                res.unwrap();
                done += 1;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        // one reader thread, one dispatch per pump: strict alternation
        assert_eq!(svc.dispatch_log(), vec![1, 2, 1, 2, 1, 2]);
        drop(dest);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn prop_cache_budget_and_pins_hold_under_access_traces() {
        // Seeded access-trace shrinker over the raw cache: random
        // lookup/admit/pin/invalidate sequences must keep (1) bytes
        // held <= budget, (2) pinned entries resident across evictions,
        // (3) the entry lifecycle counters reconciled.
        let base = scratch_dir("serve-prop").unwrap();
        // 4 segment-sized files the traces draw from
        let files: Vec<PathBuf> = (0..4)
            .map(|i| {
                let p = base.join(format!("seg-{i}.fpseg"));
                let mut data = vec![0u8; 3000 + i * 1000];
                Rng::new(i as u64).fill_bytes(&mut data);
                std::fs::write(&p, &data).unwrap();
                p
            })
            .collect();
        crate::prop::forall("segment cache invariants", 64, |g| {
            let budget = g.u64(3000, 9000);
            let cache = SegmentCache::new(&ServeConfig {
                cache_bytes: budget,
                admit_after: 1,
                mmap: false,
                coalesce: true,
            });
            let nops = g.usize(1, 40);
            let mut pins: Vec<(PathBuf, (Arc<SegmentBytes>, PinGuard<'_>))> = Vec::new();
            for _ in 0..nops {
                let f = &files[g.usize(0, files.len() - 1)];
                match g.usize(0, 3) {
                    0 => {
                        // access: hit-or-admit, pin held transiently
                        if cache.lookup(f).is_none() {
                            if let Some((bytes, mtime, len)) = cache.note_miss_and_maybe_fetch(f)
                            {
                                cache.insert(f.clone(), bytes, mtime, len);
                            }
                        }
                    }
                    1 => {
                        // pin: hold a guard across later operations
                        if let Some(hit) = cache.lookup(f) {
                            pins.push((f.clone(), hit));
                        }
                    }
                    2 => {
                        // unpin the oldest held guard
                        if !pins.is_empty() {
                            pins.remove(0);
                        }
                    }
                    _ => cache.invalidate(f),
                }
                let s = cache.stats();
                prop_assert!(
                    g,
                    s.bytes_held <= s.budget,
                    "bytes held {} over budget {}",
                    s.bytes_held,
                    s.budget
                );
                prop_assert!(
                    g,
                    s.entries == s.admitted - s.evicted - s.invalidated,
                    "lifecycle counters diverged: {s:?}"
                );
                // a pinned entry must stay resident unless explicitly
                // invalidated; eviction alone may never drop it —
                // verify by checking every held pin still resolves or
                // was invalidated (never evicted): re-lookup through
                // the map directly
                let inner = cache.inner.lock().unwrap();
                for (path, (bytes, _guard)) in &pins {
                    if let Some(e) = inner.entries.get(path) {
                        prop_assert!(g, e.pins > 0, "held guard but zero pin count");
                        prop_assert!(
                            g,
                            Arc::ptr_eq(&e.bytes, bytes),
                            "pinned entry was replaced under its guard"
                        );
                    }
                    // absent is legal only via invalidate (op 3); the
                    // eviction loop filters pins > 0, which the
                    // ptr_eq/pin checks above pin down for residents
                }
                drop(inner);
            }
            drop(pins);
            true
        });
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn prop_eviction_never_drops_a_pinned_entry() {
        // Directed shrinker: fill the cache, pin one entry, then admit
        // files that force eviction — the pinned entry must survive
        // every admission wave, and over-budget admissions must be
        // refused rather than evict it.
        let base = scratch_dir("serve-pin").unwrap();
        let files: Vec<PathBuf> = (0..6)
            .map(|i| {
                let p = base.join(format!("seg-{i}.fpseg"));
                std::fs::write(&p, vec![i as u8; 2048]).unwrap();
                p
            })
            .collect();
        crate::prop::forall("pinned entries survive eviction", 64, |g| {
            let cache = SegmentCache::new(&ServeConfig {
                cache_bytes: 4096, // room for exactly two 2048-byte files
                admit_after: 1,
                mmap: false,
                coalesce: true,
            });
            let admit = |f: &PathBuf| {
                if let Some((bytes, mtime, len)) = cache.note_miss_and_maybe_fetch(f) {
                    cache.insert(f.clone(), bytes, mtime, len);
                }
            };
            let pinned = &files[g.usize(0, files.len() - 1)];
            admit(pinned);
            let hit = cache.lookup(pinned);
            prop_assert!(g, hit.is_some(), "freshly admitted entry must hit");
            let _guard = hit;
            // admission pressure: every other file, several rounds
            for _ in 0..g.usize(2, 10) {
                let f = &files[g.usize(0, files.len() - 1)];
                if f != pinned {
                    admit(f);
                }
                let inner = cache.inner.lock().unwrap();
                prop_assert!(
                    g,
                    inner.entries.contains_key(pinned),
                    "eviction dropped a pinned entry"
                );
                prop_assert!(g, inner.bytes_held <= 4096, "budget exceeded under pressure");
                drop(inner);
            }
            true
        });
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn stale_entry_is_dropped_when_the_file_changes() {
        let base = scratch_dir("serve-stale").unwrap();
        let path = base.join("seg-0.fpseg");
        std::fs::write(&path, vec![1u8; 4096]).unwrap();
        let cache = SegmentCache::new(&ServeConfig {
            cache_bytes: 1 << 20,
            admit_after: 1,
            mmap: false,
            coalesce: true,
        });
        if let Some((bytes, mtime, len)) = cache.note_miss_and_maybe_fetch(&path) {
            cache.insert(path.clone(), bytes, mtime, len);
        }
        assert!(cache.lookup(&path).is_some());
        // rewrite with a different length: the validator must reject
        std::fs::write(&path, vec![2u8; 5000]).unwrap();
        assert!(cache.lookup(&path).is_none(), "stale image must not hit");
        let s = cache.stats();
        assert_eq!(s.entries, 0);
        assert!(s.invalidated > 0);
        std::fs::remove_dir_all(&base).unwrap();
    }
}
