//! Parallel checkpoint loading + allgather reassembly (paper §4.2).
//!
//! Loading a parallel checkpoint is the inverse of writing: each DP rank
//! reads its partition file (in parallel) from the device the manifest
//! recorded for it, then the partitions are assembled ("allgather") back
//! into the logical serialized stream, verified against the manifest's
//! stream digest, and parsed into a [`TensorStore`].
//!
//! Incremental checkpoints (manifest v3/v4 with a
//! [`crate::checkpoint::manifest::DeltaSection`]) reassemble from their
//! *chunk* table instead — one parallel reader per **segment file**
//! (v4: chunks `pread` at their recorded offsets; the file is opened
//! once however many chunks it holds) or per legacy chunk file (v3) —
//! and then flow through the same digest verification and parsing, so a
//! base + delta chain reloads bit-identically to the full snapshot it
//! represents, whichever on-disk layout wrote it. See `docs/FORMATS.md`
//! for the version matrix.

use std::path::{Path, PathBuf};

use crate::checkpoint::manifest::{CheckpointManifest, PartitionEntry};
use crate::io::device::DeviceMap;
use crate::serialize::format::{stream_digest_of, FormatHeader};
use crate::serialize::reader::parse_checkpoint;
use crate::tensor::TensorStore;
use crate::util::threadpool::parallel_map;
use crate::{Error, Result};

/// On-disk location of a partition: the manifest's recorded device
/// assignment resolved against the checkpoint directory.
pub fn partition_path(dir: &Path, entry: &PartitionEntry) -> PathBuf {
    match &entry.device {
        Some(root) => DeviceMap::resolve_in(Path::new(root), dir).join(&entry.file),
        None => dir.join(&entry.file),
    }
}

/// Load one checkpoint directory; `threads` parallel partition readers
/// (the DP ranks of the loading job).
pub fn load_checkpoint(
    dir: &Path,
    threads: usize,
) -> Result<(TensorStore, FormatHeader, CheckpointManifest)> {
    let manifest = CheckpointManifest::load(dir)?;
    let stream = if manifest.is_delta() {
        // Chunked incremental checkpoint: reassemble from the chunk
        // table (each chunk verified against its recorded hash).
        crate::checkpoint::delta::assemble_delta_stream(dir, &manifest, threads)?
    } else {
        let jobs: Vec<(std::path::PathBuf, u64)> = manifest
            .partitions
            .iter()
            .map(|p| (partition_path(dir, p), p.end - p.start))
            .collect();
        // Parallel partition reads (rank-local step of the two-step
        // load).
        let parts: Vec<Result<Vec<u8>>> = parallel_map(threads, jobs, |(path, expect)| {
            let bytes = std::fs::read(&path)
                .map_err(|e| Error::Format(format!("partition {}: {e}", path.display())))?;
            if bytes.len() as u64 != expect {
                return Err(Error::Format(format!(
                    "partition {} is {} bytes, manifest says {expect}",
                    path.display(),
                    bytes.len()
                )));
            }
            Ok(bytes)
        });
        // Allgather: concatenate in partition order.
        let mut stream = Vec::with_capacity(manifest.total_len as usize);
        for part in parts {
            stream.extend_from_slice(&part?);
        }
        stream
    };
    if stream.len() as u64 != manifest.total_len {
        return Err(Error::Format(format!(
            "assembled {} bytes, manifest says {}",
            stream.len(),
            manifest.total_len
        )));
    }
    // Composite stream digest (header ‖ data halves) — matches the
    // writer's single-pass digest, see `serialize::format`.
    let digest = stream_digest_of(&stream)?;
    if digest != manifest.digest {
        return Err(Error::Format(format!(
            "stream digest mismatch: computed {digest:#x}, manifest {:#x}",
            manifest.digest
        )));
    }
    let (store, header) = parse_checkpoint(&stream)?;
    Ok((store, header, manifest))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::engine::CheckpointEngine;
    use crate::checkpoint::strategy::WriterStrategy;
    use crate::cluster::{ClusterSpec, Parallelism, Topology};
    use crate::io::engine::scratch_dir;
    use crate::tensor::{DType, Tensor};
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn write_sample(dir: &Path, dp: usize) -> TensorStore {
        let mut rng = Rng::new(23);
        let mut store = TensorStore::new();
        let mut data = vec![0u8; 100_000];
        rng.fill_bytes(&mut data);
        store
            .push(Tensor::new("payload", DType::U8, vec![100_000], data).unwrap())
            .unwrap();
        let topo =
            Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(dp, 1, 1)).unwrap();
        CheckpointEngine::fastpersist(WriterStrategy::AllReplicas)
            .write(&store, BTreeMap::new(), dir, &topo.dp_group(0))
            .unwrap();
        store
    }

    #[test]
    fn detects_missing_partition() {
        let dir = scratch_dir("load-missing").unwrap();
        write_sample(&dir, 4);
        // remove one partition file
        let manifest = CheckpointManifest::load(&dir).unwrap();
        std::fs::remove_file(dir.join(&manifest.partitions[2].file)).unwrap();
        assert!(load_checkpoint(&dir, 2).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn detects_corrupted_partition() {
        let dir = scratch_dir("load-corrupt").unwrap();
        write_sample(&dir, 4);
        let manifest = CheckpointManifest::load(&dir).unwrap();
        let pfile = dir.join(&manifest.partitions[1].file);
        let mut bytes = std::fs::read(&pfile).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x5a;
        std::fs::write(&pfile, bytes).unwrap();
        match load_checkpoint(&dir, 2) {
            Err(Error::Format(msg)) => assert!(msg.contains("digest"), "{msg}"),
            other => panic!("expected digest error, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn detects_truncated_partition() {
        let dir = scratch_dir("load-trunc").unwrap();
        write_sample(&dir, 2);
        let manifest = CheckpointManifest::load(&dir).unwrap();
        let pfile = dir.join(&manifest.partitions[0].file);
        let bytes = std::fs::read(&pfile).unwrap();
        std::fs::write(&pfile, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load_checkpoint(&dir, 2).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn thread_count_does_not_matter() {
        let dir = scratch_dir("load-threads").unwrap();
        let store = write_sample(&dir, 8);
        for threads in [1, 2, 8] {
            let (loaded, _, _) = load_checkpoint(&dir, threads).unwrap();
            assert!(loaded.content_eq(&store));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
