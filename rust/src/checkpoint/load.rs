//! Parallel checkpoint loading + allgather reassembly (paper §4.2),
//! over the shared I/O runtime's reader pool.
//!
//! Loading a parallel checkpoint is the inverse of writing, and since
//! this module was rewired onto [`crate::io::read`] it is structured
//! like the write path too: the manifest is planned into
//! [`crate::io::ReadJob`]s — one per partition file (full checkpoints)
//! or per segment/chunk file (incremental ones) — submitted to the
//! [`IoRuntime`]'s persistent reader pool, and every job reads its
//! range **directly into its disjoint slice** of one preallocated
//! [`crate::io::StreamBuffer`] of `total_len` bytes. There are no
//! per-part vectors, no concatenation pass, and exactly one stream
//! allocation per restore (counted by
//! [`IoRuntime::stream_allocations`]).
//!
//! Incremental checkpoints (manifest v3/v4 with a
//! [`crate::checkpoint::manifest::DeltaSection`]) reassemble from their
//! *chunk* table: v4 segment files get a coalesced read plan (chunks
//! byte-adjacent in the segment and the stream merge into one large
//! `pread` — [`crate::io::read::plan_runs`]), v3 legacy chunk files one
//! job each, and chunk-hash verification is folded into the read pass.
//! The assembled stream then flows through a **single** verification +
//! parse pass ([`crate::serialize::reader::parse_verified`] folds the
//! manifest's composite stream digest into the parse's data pass), so a
//! base + delta chain reloads bit-identically to the full snapshot it
//! represents, whichever on-disk layout wrote it. See `docs/FORMATS.md`
//! for the version matrix and ARCHITECTURE.md for the read-path
//! dataflow.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::checkpoint::manifest::{CheckpointManifest, PartitionEntry};
use crate::io::device::DeviceMap;
use crate::io::read::{self, ReadJob, ReadPart, ReadStats, StreamBuffer};
use crate::io::runtime::IoRuntime;
use crate::serialize::format::FormatHeader;
use crate::serialize::reader::parse_verified;
use crate::tensor::TensorStore;
use crate::{Error, Result};

/// On-disk location of a partition: the manifest's recorded device
/// assignment resolved against the checkpoint directory.
pub fn partition_path(dir: &Path, entry: &PartitionEntry) -> PathBuf {
    match &entry.device {
        Some(root) => DeviceMap::resolve_in(Path::new(root), dir).join(&entry.file),
        None => dir.join(&entry.file),
    }
}

/// Restore tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct RestoreOptions {
    /// Merge byte-adjacent chunk reads into single preads (default).
    /// `false` issues the naive one-pread-per-chunk plan — kept for the
    /// `BENCH_load` coalesced-vs-naive comparison.
    pub coalesce: bool,
}

impl Default for RestoreOptions {
    fn default() -> Self {
        RestoreOptions { coalesce: true }
    }
}

/// A fully restored checkpoint plus the read-path accounting.
pub struct LoadedCheckpoint {
    /// The reconstructed tensor state.
    pub store: TensorStore,
    /// The parsed stream header (training extras, tensor table).
    pub header: FormatHeader,
    /// The checkpoint's manifest.
    pub manifest: CheckpointManifest,
    /// Merged counters from every read job of this restore.
    pub stats: ReadStats,
    /// Wall latency: manifest parse → store reconstructed.
    pub latency: Duration,
}

impl LoadedCheckpoint {
    /// Effective restore throughput in decimal GB/s (stream bytes over
    /// total restore wall time, verification and parse included).
    pub fn gbps(&self) -> f64 {
        crate::util::bytes::gbps(self.manifest.total_len, self.latency.as_secs_f64())
    }
}

/// Load one checkpoint directory through `runtime`'s reader pool.
pub fn load_checkpoint(
    dir: &Path,
    runtime: &IoRuntime,
) -> Result<(TensorStore, FormatHeader, CheckpointManifest)> {
    load_checkpoint_with(dir, runtime, RestoreOptions::default())
        .map(|l| (l.store, l.header, l.manifest))
}

/// Load with explicit [`RestoreOptions`], returning the read-path
/// counters alongside the state ([`LoadedCheckpoint`]).
pub fn load_checkpoint_with(
    dir: &Path,
    runtime: &IoRuntime,
    opts: RestoreOptions,
) -> Result<LoadedCheckpoint> {
    let t0 = Instant::now();
    // Parse through the process-wide manifest LRU: repeated restores of
    // one step (and the serve layer's concurrent tenants) share a
    // single parse instead of re-reading the chunk table every time.
    let manifest = CheckpointManifest::load_cached(dir)?;
    // THE stream allocation: one buffer of total_len, assembled in
    // place by the read jobs (no per-part vectors, no concat).
    let dest = runtime.alloc_stream(manifest.total_len as usize);
    let jobs = plan_restore_jobs(dir, &manifest, &dest, opts.coalesce, runtime)?;
    let stats = read::run_jobs(runtime, jobs)?;
    finish_restore(dest, (*manifest).clone(), stats, t0)
}

/// Plan the read jobs of one restore: per-segment coalesced jobs for
/// delta checkpoints, per-partition (split) jobs for full ones. Shared
/// by the direct loader above and the serve layer's scheduler
/// ([`crate::checkpoint::serve`]), which dispatches the same jobs
/// through its cache and fairness machinery.
pub(crate) fn plan_restore_jobs(
    dir: &Path,
    manifest: &CheckpointManifest,
    dest: &std::sync::Arc<StreamBuffer>,
    coalesce: bool,
    runtime: &IoRuntime,
) -> Result<Vec<ReadJob>> {
    if manifest.is_delta() {
        crate::checkpoint::delta::plan_delta_reads(dir, manifest, dest, coalesce)
    } else {
        Ok(plan_partition_reads(dir, manifest, dest, runtime.read_split_bytes()))
    }
}

/// Post-assembly half of a restore, shared with the serve layer:
/// account the assembled bytes against the manifest, unwrap the stream
/// buffer, and run the single verification + parse pass.
pub(crate) fn finish_restore(
    dest: std::sync::Arc<StreamBuffer>,
    manifest: CheckpointManifest,
    stats: ReadStats,
    t0: Instant,
) -> Result<LoadedCheckpoint> {
    if stats.bytes != manifest.total_len {
        return Err(Error::Format(format!(
            "assembled {} bytes, manifest says {}",
            stats.bytes, manifest.total_len
        )));
    }
    let stream = StreamBuffer::into_vec(dest)?;
    // Single post-assembly pass: the composite stream digest is folded
    // into the parse's data pass (matches the writer's single-pass
    // digest, see `serialize::format`).
    let (store, header) = parse_verified(&stream, manifest.digest)?;
    Ok(LoadedCheckpoint { store, header, manifest, stats, latency: t0.elapsed() })
}

/// Read plan of a full (partitioned) checkpoint: jobs per partition
/// file, reading the file's extent into the stream range the manifest
/// records for it. A partition larger than `split_bytes` is chopped
/// into several parallel jobs (intra-partition read parallelism —
/// [`crate::io::runtime::IoRuntimeConfig::read_split_bytes`]), so one huge
/// partition no longer serializes restore on a single reader thread.
/// Errors from these jobs carry the fully *resolved* path (device
/// routing applied), so a device-mapped partition whose mount or
/// symlink target is gone reports exactly which path failed instead of
/// a generic assembly error.
fn plan_partition_reads(
    dir: &Path,
    manifest: &CheckpointManifest,
    dest: &std::sync::Arc<StreamBuffer>,
    split_bytes: u64,
) -> Vec<ReadJob> {
    let split = split_bytes.max(1);
    let mut jobs = Vec::with_capacity(manifest.partitions.len());
    for p in &manifest.partitions {
        let len = p.end - p.start;
        let path = partition_path(dir, p);
        let mut off = 0u64;
        loop {
            let piece = split.min(len - off);
            jobs.push(ReadJob {
                path: path.clone(),
                dest: std::sync::Arc::clone(dest),
                runs: vec![ReadPart { file_off: off, dest_off: p.start + off, len: piece }],
                decodes: Vec::new(),
                checks: Vec::new(),
                coalesced: 0,
                expect_file_len: Some(len),
                prefix_check: None,
                kind: None,
                label: "partition",
            });
            off += piece;
            if off >= len {
                break;
            }
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::engine::CheckpointEngine;
    use crate::checkpoint::strategy::WriterStrategy;
    use crate::cluster::{ClusterSpec, Parallelism, Topology};
    use crate::io::engine::{scratch_dir, IoConfig};
    use crate::io::runtime::IoRuntimeConfig;
    use crate::tensor::{DType, Tensor};
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    fn test_runtime() -> Arc<IoRuntime> {
        IoRuntime::shared(IoConfig::default().microbench())
    }

    fn write_sample(dir: &Path, dp: usize) -> TensorStore {
        let mut rng = Rng::new(23);
        let mut store = TensorStore::new();
        let mut data = vec![0u8; 100_000];
        rng.fill_bytes(&mut data);
        store
            .push(Tensor::new("payload", DType::U8, vec![100_000], data).unwrap())
            .unwrap();
        let topo =
            Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(dp, 1, 1)).unwrap();
        CheckpointEngine::fastpersist(WriterStrategy::AllReplicas)
            .write(&store, BTreeMap::new(), dir, &topo.dp_group(0))
            .unwrap();
        store
    }

    #[test]
    fn detects_missing_partition() {
        let dir = scratch_dir("load-missing").unwrap();
        write_sample(&dir, 4);
        // remove one partition file
        let manifest = CheckpointManifest::load(&dir).unwrap();
        let removed = dir.join(&manifest.partitions[2].file);
        std::fs::remove_file(&removed).unwrap();
        match load_checkpoint(&dir, &test_runtime()) {
            Err(Error::Format(msg)) => assert!(
                msg.contains(&manifest.partitions[2].file),
                "error must name the resolved partition path: {msg}"
            ),
            other => panic!("expected partition error, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_device_mapped_partition_reports_resolved_path() {
        // A device-routed partition resolves outside the checkpoint
        // directory (root/fpck-<tag>/part-...); when that target is
        // gone the error must surface the resolved path, not a generic
        // "assembled N bytes" report.
        let base = scratch_dir("load-devmiss").unwrap();
        let dir = base.join("ckpt");
        let devices = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let runtime = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::default().microbench(),
            devices,
            ..IoRuntimeConfig::default()
        }));
        let mut store = TensorStore::new();
        store
            .push(Tensor::new("w", DType::U8, vec![50_000], vec![9u8; 50_000]).unwrap())
            .unwrap();
        let topo = Topology::new(ClusterSpec::dgx2(1), Parallelism::dense(4, 1, 1)).unwrap();
        CheckpointEngine::with_runtime(Arc::clone(&runtime), WriterStrategy::AllReplicas)
            .write(&store, BTreeMap::new(), &dir, &topo.dp_group(0))
            .unwrap();
        let manifest = CheckpointManifest::load(&dir).unwrap();
        let entry = &manifest.partitions[1];
        let resolved = partition_path(&dir, entry);
        assert!(entry.device.is_some(), "partition must be device-routed");
        std::fs::remove_file(&resolved).unwrap();
        match load_checkpoint(&dir, &runtime) {
            Err(Error::Format(msg)) => {
                assert!(
                    msg.contains(&resolved.display().to_string()),
                    "error must carry the device-resolved path {resolved:?}: {msg}"
                );
                assert!(!msg.contains("assembled"), "must not be the generic error: {msg}");
            }
            other => panic!("expected resolved-path error, got {other:?}"),
        }
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn detects_corrupted_partition() {
        let dir = scratch_dir("load-corrupt").unwrap();
        write_sample(&dir, 4);
        let manifest = CheckpointManifest::load(&dir).unwrap();
        let pfile = dir.join(&manifest.partitions[1].file);
        let mut bytes = std::fs::read(&pfile).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x5a;
        std::fs::write(&pfile, bytes).unwrap();
        match load_checkpoint(&dir, &test_runtime()) {
            Err(Error::Format(msg)) => assert!(msg.contains("digest"), "{msg}"),
            other => panic!("expected digest error, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn detects_truncated_partition() {
        let dir = scratch_dir("load-trunc").unwrap();
        write_sample(&dir, 2);
        let manifest = CheckpointManifest::load(&dir).unwrap();
        let pfile = dir.join(&manifest.partitions[0].file);
        let bytes = std::fs::read(&pfile).unwrap();
        std::fs::write(&pfile, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load_checkpoint(&dir, &test_runtime()).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reader_pool_size_does_not_matter() {
        let dir = scratch_dir("load-threads").unwrap();
        let store = write_sample(&dir, 8);
        for threads in [1, 2, 8] {
            let rt = IoRuntime::new(IoRuntimeConfig {
                io: IoConfig::default().microbench(),
                reader_threads: threads,
                ..IoRuntimeConfig::default()
            });
            let (loaded, _, _) = load_checkpoint(&dir, &rt).unwrap();
            assert!(loaded.content_eq(&store));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn huge_partition_splits_into_parallel_read_jobs() {
        // Intra-partition parallelism: a single partition above the
        // split threshold restores through several ReadJobs over
        // disjoint ranges of the same file — and still assembles
        // bit-identically through ONE stream allocation.
        let dir = scratch_dir("load-split").unwrap();
        let store = write_sample(&dir, 1); // one partition holds ~100 KB
        let rt = IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::default().microbench(),
            read_split_bytes: 16 << 10, // 16 KiB -> ~7 jobs for the payload
            ..IoRuntimeConfig::default()
        });
        let loaded = load_checkpoint_with(&dir, &rt, RestoreOptions::default()).unwrap();
        assert!(loaded.store.content_eq(&store));
        let manifest = &loaded.manifest;
        assert_eq!(manifest.partitions.len(), 1);
        let expect_jobs = manifest.total_len.div_ceil(16 << 10);
        assert_eq!(loaded.stats.jobs, expect_jobs, "split threshold must fan the read out");
        assert!(loaded.stats.jobs > 1);
        assert_eq!(loaded.stats.bytes, manifest.total_len);
        assert_eq!(rt.stream_allocations().0, 1, "split jobs share one stream buffer");
        // the default threshold leaves small partitions alone
        let rt_default = test_runtime();
        let one = load_checkpoint_with(&dir, &rt_default, RestoreOptions::default()).unwrap();
        assert_eq!(one.stats.jobs, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repeated_restores_share_one_manifest_parse() {
        // Satellite fix: load_checkpoint routes the manifest parse
        // through the process-wide LRU — a second restore of the same
        // step is a cache hit, and a re-save invalidates it.
        let dir = scratch_dir("load-manifest-cache").unwrap();
        let store = write_sample(&dir, 2);
        let rt = test_runtime();
        let first = load_checkpoint_with(&dir, &rt, RestoreOptions::default()).unwrap();
        assert!(first.store.content_eq(&store));
        let (hits0, _) = crate::checkpoint::manifest::manifest_cache_stats();
        let second = load_checkpoint_with(&dir, &rt, RestoreOptions::default()).unwrap();
        assert!(second.store.content_eq(&store));
        let (hits1, _) = crate::checkpoint::manifest::manifest_cache_stats();
        assert!(hits1 > hits0, "second restore must hit the manifest cache");
        // save-side invalidation: a re-published manifest is re-parsed
        let mut bumped = first.manifest.clone();
        bumped.step += 1;
        bumped.save(&dir).unwrap();
        let third = load_checkpoint_with(&dir, &rt, RestoreOptions::default()).unwrap();
        assert_eq!(third.manifest.step, bumped.step, "stale manifest parse served");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn restore_performs_exactly_one_stream_allocation() {
        // Buffer accounting: an 8-partition restore assembles through
        // ONE allocation of total_len bytes — no per-partition vectors.
        let dir = scratch_dir("load-onealloc").unwrap();
        let store = write_sample(&dir, 8);
        let rt = test_runtime();
        assert_eq!(rt.stream_allocations(), (0, 0));
        let loaded = load_checkpoint_with(&dir, &rt, RestoreOptions::default()).unwrap();
        assert!(loaded.store.content_eq(&store));
        assert_eq!(
            rt.stream_allocations(),
            (1, loaded.manifest.total_len),
            "one restore = one stream allocation of exactly total_len bytes"
        );
        assert_eq!(loaded.stats.jobs, 8, "one read job per partition");
        assert_eq!(loaded.stats.bytes, loaded.manifest.total_len);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
