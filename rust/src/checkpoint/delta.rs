//! Incremental (delta) checkpointing over the shared I/O runtime.
//!
//! FastPersist makes the *write path* fast; this module makes the
//! *written bytes* small, which is what per-iteration checkpointing at
//! the ROADMAP's scale ultimately needs. The idea follows Check-N-Run's
//! differential checkpointing: between consecutive checkpoints most of
//! the serialized state is unchanged, so only the changed part needs to
//! reach storage — the rest can be *referenced* from earlier
//! checkpoints.
//!
//! ## Mechanism
//!
//! The serialized stream (header ‖ tensor payloads, exactly the bytes a
//! full checkpoint would write) is cut into a fixed grid of
//! `chunk_size`-byte chunks. Each chunk is hashed in a single pass over
//! the stream ([`chunk_hashes`], reusing the streaming
//! [`Checksum64`] digest machinery). The hashes are
//! diffed against the previous checkpoint's chunk table:
//!
//! * **dirty** chunks (hash or length changed, or no predecessor) are
//!   submitted to the shared [`IoRuntime`] writer pool as one
//!   [`WriteJob`] each — striped across the runtime's
//!   [`crate::io::DeviceMap`] exactly like full-checkpoint partitions;
//! * **clean** chunks are *inherited*: the new manifest's chunk table
//!   entry points at the sibling checkpoint directory that physically
//!   holds the chunk file.
//!
//! The resulting manifest (v3, [`DeltaSection`]) is **fully resolved**:
//! loading never walks ancestor manifests, it just reads each chunk
//! from the directory its entry names, reassembles the stream, and
//! verifies the stream digest — bit-identical to loading a full
//! checkpoint of the same state. The manifest is published last
//! (atomic rename), so an interrupted delta flush leaves no manifest
//! and recovery simply falls back to the newest complete checkpoint.
//!
//! ## Chains, compaction, GC
//!
//! Deltas form a chain: `base ← Δ₁ ← Δ₂ …`. Every
//! [`DeltaConfig::max_chain`] deltas the chain is *compacted*: the next
//! checkpoint is written as a fresh base (all chunks local), breaking
//! every reference to older directories. [`prune_chain`] then garbage
//! collects: unreferenced checkpoint directories are removed outright,
//! while directories still holding chunks that live checkpoints
//! reference are demoted to chunk stores (manifest dropped) and their
//! *dead* chunk files — those no retained manifest references — are
//! deleted.
//!
//! Chain members must be sibling directories (the trainer's
//! `step-NNNNNNNN` layout); the manifest records directory *names*, not
//! paths, so a whole checkpoint tree can be relocated as long as
//! single-device layouts are used (device routing pins directories, see
//! [`crate::io::DeviceMap::checkpoint_tag`]).
//!
//! Chunk hashes are 64-bit non-cryptographic checksums: ample for
//! corruption detection and change tracking of trusted local state (a
//! colliding *and* torn update is what the stream digest still
//! catches), not a content-addressing security boundary.
//!
//! Cost notes (candidate follow-ups, tracked in ROADMAP.md):
//!
//! * a delta write makes **two** CPU passes over the state —
//!   serialization's digest pass, then the grid-hash pass. They cannot
//!   be fused under the current container format because chunk 0
//!   contains the header, and the header embeds the data digest, so
//!   grid hashing can only start after the digest pass completes.
//!   Chunking the data section separately from the header would remove
//!   the second pass.
//! * a **base** (or compaction) checkpoint writes every chunk as its
//!   own file — `total_len / chunk_size` WriteJobs, each with its own
//!   create/fsync — where the partitioned full path writes one file
//!   per DP writer. At production state sizes the every-`max_chain`-th
//!   checkpoint therefore stalls longer than a plain full snapshot;
//!   coalescing chunk runs into segment files (manifest records
//!   per-chunk offsets) would fix it without giving up chunk-level
//!   inheritance.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::checkpoint::engine::CheckpointOutcome;
use crate::checkpoint::manifest::{
    CheckpointManifest, ChunkEntry, DeltaSection, MANIFEST_FILE,
};
use crate::io::device::DeviceMap;
use crate::io::engine::WriteStats;
use crate::io::runtime::{IoRuntime, Ticket, WriteJob};
use crate::serialize::format::{checksum64_slice, Checksum64};
use crate::serialize::writer::SerializedCheckpoint;
use crate::tensor::TensorStore;
use crate::util::json::Json;
use crate::util::threadpool::parallel_map;
use crate::{Error, Result};

/// Tuning knobs for incremental checkpointing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeltaConfig {
    /// Chunk-grid size in bytes. The default (1 MiB) is a multiple of
    /// every supported I/O alignment; small sizes track changes more
    /// precisely but write more, smaller files.
    pub chunk_size: u64,
    /// Maximum deltas after a base before the chain is compacted into a
    /// fresh base (0 = every checkpoint is a base).
    pub max_chain: u64,
}

impl Default for DeltaConfig {
    fn default() -> Self {
        DeltaConfig { chunk_size: 1 << 20, max_chain: 8 }
    }
}

impl DeltaConfig {
    /// Clamp the chunk size to at least one I/O alignment unit (4 KiB)
    /// so chunk files keep the direct-write fast path.
    pub fn normalized(self) -> DeltaConfig {
        DeltaConfig { chunk_size: self.chunk_size.max(4096), ..self }
    }
}

/// Which checkpoint layout the trainer produces each iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointStrategy {
    /// Full snapshot every time: byte-partitioned parallel writes via
    /// [`crate::checkpoint::CheckpointEngine`].
    Full,
    /// Chunk-granular incremental checkpoints via [`DeltaCheckpointer`].
    Delta(DeltaConfig),
}

impl CheckpointStrategy {
    /// Short CLI name: `full`, or `delta<max_chain>`.
    pub fn name(self) -> String {
        match self {
            CheckpointStrategy::Full => "full".into(),
            CheckpointStrategy::Delta(d) => format!("delta{}", d.max_chain),
        }
    }

    /// Parse `full`, `delta`, or `delta<N>` (N = max chain length).
    pub fn parse(s: &str) -> Result<CheckpointStrategy> {
        match s {
            "full" => Ok(CheckpointStrategy::Full),
            "delta" => Ok(CheckpointStrategy::Delta(DeltaConfig::default())),
            other => {
                if let Some(n) = other.strip_prefix("delta") {
                    let max_chain: u64 = n
                        .parse()
                        .map_err(|_| Error::Config(format!("bad checkpoint strategy {other:?}")))?;
                    return Ok(CheckpointStrategy::Delta(DeltaConfig {
                        max_chain,
                        ..DeltaConfig::default()
                    }));
                }
                Err(Error::Config(format!("unknown checkpoint strategy {other:?}")))
            }
        }
    }
}

/// Hash + length of one chunk of a serialized stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkDigest {
    /// Streaming checksum of the chunk's bytes.
    pub hash: u64,
    /// Chunk length (== grid size except for the final chunk).
    pub len: u64,
}

/// Chunk-grid hashes of a serialized checkpoint, computed in **one**
/// pass over the stream (no materialization): pieces from
/// [`SerializedCheckpoint::emit_range`] are split at grid boundaries
/// and fed to a per-chunk [`Checksum64`]. Chunk `i`'s hash equals
/// `checksum64_slice` of stream bytes `[i*chunk_size, ...)`.
pub fn chunk_hashes(ser: &SerializedCheckpoint, chunk_size: u64) -> Vec<ChunkDigest> {
    assert!(chunk_size > 0, "chunk_size must be positive");
    let total = ser.total_len();
    let mut out: Vec<ChunkDigest> = Vec::with_capacity((total / chunk_size) as usize + 1);
    let mut cur = Checksum64::new();
    let mut filled = 0u64;
    ser.emit_range(0, total, &mut |piece| {
        let mut rest = piece;
        while !rest.is_empty() {
            let room = (chunk_size - filled).min(rest.len() as u64) as usize;
            cur.update(&rest[..room]);
            filled += room as u64;
            rest = &rest[room..];
            if filled == chunk_size {
                let done = std::mem::replace(&mut cur, Checksum64::new());
                out.push(ChunkDigest { hash: done.finalize(), len: chunk_size });
                filled = 0;
            }
        }
        Ok(())
    })
    .expect("in-memory chunk hashing cannot fail");
    if filled > 0 {
        out.push(ChunkDigest { hash: cur.finalize(), len: filled });
    }
    out
}

/// Result of one incremental checkpoint write.
#[derive(Debug)]
pub struct DeltaOutcome {
    /// The published (v3) manifest.
    pub manifest: CheckpointManifest,
    /// Per-dirty-chunk write stats, chunk order.
    pub stats: Vec<WriteStats>,
    /// Wall latency: serialize start → manifest durable.
    pub latency: Duration,
    /// Logical stream length (what a full checkpoint would write).
    pub total_bytes: u64,
    /// Bytes actually written (dirty chunks only).
    pub written_bytes: u64,
    /// Chunks in the stream's grid.
    pub chunks_total: usize,
    /// Dirty chunks written by this checkpoint.
    pub chunks_written: usize,
    /// True if this checkpoint is a chain base (all chunks local).
    pub is_base: bool,
}

impl DeltaOutcome {
    /// Fraction of the stream that did **not** have to be written.
    pub fn savings(&self) -> f64 {
        if self.total_bytes == 0 {
            return 0.0;
        }
        1.0 - self.written_bytes as f64 / self.total_bytes as f64
    }

    /// View as a generic [`CheckpointOutcome`] (the pipelined helper's
    /// common currency).
    pub fn into_outcome(self) -> CheckpointOutcome {
        CheckpointOutcome {
            manifest: self.manifest,
            stats: self.stats,
            latency: self.latency,
            total_bytes: self.total_bytes,
        }
    }
}

/// The previous checkpoint's resolved chunk table, kept in memory so
/// steady-state diffing costs no manifest re-parse.
struct PrevCheckpoint {
    parent: PathBuf,
    dir_name: String,
    chain_len: u64,
    chunk_size: u64,
    chunks: Vec<ResolvedChunk>,
}

#[derive(Clone)]
struct ResolvedChunk {
    hash: u64,
    len: u64,
    /// Directory name that physically holds the chunk file.
    source: String,
    device: Option<String>,
}

/// Chunk-granular incremental checkpoint writer over a shared
/// [`IoRuntime`].
///
/// Stateful: remembers the previous checkpoint's chunk table to diff
/// against (resumable from an on-disk manifest via
/// [`DeltaCheckpointer::resume_from`]). All I/O goes through the
/// runtime's persistent writer pool and device map, interleaving with
/// any other checkpoint traffic on the same runtime.
pub struct DeltaCheckpointer {
    runtime: Arc<IoRuntime>,
    cfg: DeltaConfig,
    prev: Option<PrevCheckpoint>,
}

impl DeltaCheckpointer {
    /// A delta writer submitting into `runtime`; the first write is a
    /// base checkpoint.
    pub fn new(runtime: Arc<IoRuntime>, cfg: DeltaConfig) -> DeltaCheckpointer {
        DeltaCheckpointer { runtime, cfg: cfg.normalized(), prev: None }
    }

    /// The runtime this writer submits into.
    pub fn runtime(&self) -> &Arc<IoRuntime> {
        &self.runtime
    }

    /// The (normalized) delta configuration.
    pub fn config(&self) -> DeltaConfig {
        self.cfg
    }

    /// Adopt the checkpoint at `dir` as the chain predecessor, so the
    /// next write diffs against it (crash/restart resume). Returns
    /// `true` if `dir` holds a compatible delta manifest; a full
    /// (partitioned) or differently-chunked manifest leaves the writer
    /// in base mode and returns `false`.
    pub fn resume_from(&mut self, dir: &Path) -> Result<bool> {
        let manifest = CheckpointManifest::load(dir)?;
        let Some(delta) = &manifest.delta else {
            self.prev = None;
            return Ok(false);
        };
        if delta.chunk_size != self.cfg.chunk_size {
            self.prev = None;
            return Ok(false);
        }
        let dir_name = dir_name_of(dir)?;
        let chunks = delta
            .chunks
            .iter()
            .map(|c| ResolvedChunk {
                hash: c.hash,
                len: c.len,
                source: c.source.clone().unwrap_or_else(|| dir_name.clone()),
                device: c.device.clone(),
            })
            .collect();
        self.prev = Some(PrevCheckpoint {
            parent: dir.parent().map(Path::to_path_buf).unwrap_or_default(),
            dir_name,
            chain_len: delta.chain_len,
            chunk_size: delta.chunk_size,
            chunks,
        });
        Ok(true)
    }

    /// Force the next write to be a fresh base (explicit compaction).
    pub fn compact_next(&mut self) {
        self.prev = None;
    }

    /// Deltas written since the current chain's base (None = next write
    /// is a base).
    pub fn chain_len(&self) -> Option<u64> {
        self.prev.as_ref().map(|p| p.chain_len)
    }

    /// Write an incremental checkpoint of `store` into `dir`.
    ///
    /// `dir` must be a sibling of the previous checkpoint's directory
    /// (same parent); otherwise — or when the chain has reached
    /// [`DeltaConfig::max_chain`], or no predecessor exists — a base
    /// checkpoint is written instead. Only dirty chunks are submitted
    /// to the writer pool; the manifest is published last.
    pub fn write(
        &mut self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
    ) -> Result<DeltaOutcome> {
        let start = Instant::now();
        std::fs::create_dir_all(dir)?;
        let dir_name = dir_name_of(dir)?;
        let parent = dir.parent().map(Path::to_path_buf).unwrap_or_default();
        let step = extra.get("step").and_then(|j| j.as_i64().ok()).unwrap_or(0) as u64;

        // One serialization pass (header + digest), one hashing pass
        // (chunk grid); payloads stay zero-copy Arc references.
        let ser = Arc::new(SerializedCheckpoint::new(store, extra));
        let digest = ser.stream_digest();
        let grid = chunk_hashes(&ser, self.cfg.chunk_size);

        // Delta-eligible only against a same-grid sibling predecessor
        // with chain headroom; anything else starts a fresh base. The
        // predecessor state is *taken*: if this write fails midway the
        // next attempt conservatively starts a fresh base instead of
        // diffing against a chain whose tail never committed.
        let (is_base, base_name, chain_len, prev_chunks) = match self.prev.take() {
            Some(p)
                if p.chunk_size == self.cfg.chunk_size
                    && p.parent == parent
                    && p.chain_len < self.cfg.max_chain =>
            {
                (false, Some(p.dir_name), p.chain_len + 1, p.chunks)
            }
            _ => (true, None, 0, Vec::new()),
        };

        // Diff against the predecessor grid; submit dirty chunks to the
        // persistent writer pool, inherit clean ones. The manifest's
        // chunk table and the in-memory resolved table (next diff's
        // input) are built together in this single pass.
        let mut tickets: Vec<Ticket> = Vec::new();
        let mut entries: Vec<ChunkEntry> = Vec::with_capacity(grid.len());
        let mut resolved: Vec<ResolvedChunk> = Vec::with_capacity(grid.len());
        let mut written = 0u64;
        let mut offset = 0u64;
        for (i, ch) in grid.iter().enumerate() {
            let clean = !is_base
                && prev_chunks.get(i).is_some_and(|p| p.hash == ch.hash && p.len == ch.len);
            if clean {
                let p = &prev_chunks[i];
                entries.push(ChunkEntry {
                    hash: ch.hash,
                    len: ch.len,
                    source: Some(p.source.clone()),
                    device: p.device.clone(),
                });
                resolved.push(p.clone());
            } else {
                let file = DeltaSection::chunk_file(i);
                let (chunk_dir, device) = match self.runtime.devices().partition_dir(dir, i) {
                    Some((d, root)) => (d, Some(root)),
                    None => (dir.to_path_buf(), None),
                };
                tickets.push(self.runtime.submit(WriteJob::range(
                    Arc::clone(&ser),
                    offset,
                    offset + ch.len,
                    chunk_dir.join(file),
                )));
                written += ch.len;
                resolved.push(ResolvedChunk {
                    hash: ch.hash,
                    len: ch.len,
                    source: dir_name.clone(),
                    device: device.clone(),
                });
                entries.push(ChunkEntry { hash: ch.hash, len: ch.len, source: None, device });
            }
            offset += ch.len;
        }
        let chunks_written = tickets.len();
        let stats: Vec<WriteStats> =
            tickets.into_iter().map(Ticket::wait).collect::<Result<Vec<_>>>()?;

        // All dirty chunks durable → publish the manifest. Its presence
        // is the commit point of the whole delta.
        let delta = DeltaSection {
            base: base_name,
            chain_len,
            chunk_size: self.cfg.chunk_size,
            chunks: entries,
        };
        let manifest = CheckpointManifest::from_delta(ser.total_len(), digest, step, delta);
        manifest.validate()?;
        manifest.save(dir)?;

        // Remember the resolved table for the next diff.
        self.prev = Some(PrevCheckpoint {
            parent,
            dir_name,
            chain_len,
            chunk_size: self.cfg.chunk_size,
            chunks: resolved,
        });

        Ok(DeltaOutcome {
            total_bytes: ser.total_len(),
            written_bytes: written,
            chunks_total: grid.len(),
            chunks_written,
            is_base,
            manifest,
            stats,
            latency: start.elapsed(),
        })
    }
}

fn dir_name_of(dir: &Path) -> Result<String> {
    dir.file_name()
        .and_then(|n| n.to_str())
        .map(str::to_string)
        .ok_or_else(|| {
            Error::Config(format!("checkpoint dir {} has no utf-8 name", dir.display()))
        })
}

/// On-disk location of chunk `index` of the delta checkpoint at `dir`:
/// the entry's source directory (a sibling of `dir`, or `dir` itself),
/// with the device assignment resolved against that *source* directory.
pub fn chunk_path(dir: &Path, index: usize, entry: &ChunkEntry) -> PathBuf {
    let owner = match &entry.source {
        Some(s) => dir.parent().map(Path::to_path_buf).unwrap_or_default().join(s),
        None => dir.to_path_buf(),
    };
    let file = DeltaSection::chunk_file(index);
    match &entry.device {
        Some(root) => DeviceMap::resolve_in(Path::new(root), &owner).join(file),
        None => owner.join(file),
    }
}

/// Reassemble the logical stream of the delta checkpoint at `dir`:
/// `threads` parallel chunk readers, each verifying its chunk's
/// recorded hash (precise corruption reports before the caller's
/// whole-stream digest check).
pub fn assemble_delta_stream(
    dir: &Path,
    manifest: &CheckpointManifest,
    threads: usize,
) -> Result<Vec<u8>> {
    let delta = manifest
        .delta
        .as_ref()
        .ok_or_else(|| Error::Internal("assemble_delta_stream on a full manifest".into()))?;
    let jobs: Vec<(PathBuf, u64, u64)> = delta
        .chunks
        .iter()
        .enumerate()
        .map(|(i, c)| (chunk_path(dir, i, c), c.len, c.hash))
        .collect();
    let parts: Vec<Result<Vec<u8>>> = parallel_map(threads.max(1), jobs, |(path, len, hash)| {
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::Format(format!("chunk {}: {e}", path.display())))?;
        if bytes.len() as u64 != len {
            return Err(Error::Format(format!(
                "chunk {} is {} bytes, manifest says {len}",
                path.display(),
                bytes.len()
            )));
        }
        let got = checksum64_slice(&bytes);
        if got != hash {
            return Err(Error::Format(format!(
                "chunk {} hash mismatch: computed {got:#x}, manifest {hash:#x}",
                path.display()
            )));
        }
        Ok(bytes)
    });
    let mut stream = Vec::with_capacity(manifest.total_len as usize);
    for part in parts {
        stream.extend_from_slice(&part?);
    }
    if stream.len() as u64 != manifest.total_len {
        return Err(Error::Format(format!(
            "assembled {} bytes, manifest says {}",
            stream.len(),
            manifest.total_len
        )));
    }
    Ok(stream)
}

/// What [`prune_chain`] did.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PruneStats {
    /// Checkpoint directories removed outright.
    pub removed_dirs: usize,
    /// Directories demoted to chunk stores (manifest dropped, live
    /// chunks retained because newer checkpoints reference them).
    pub demoted_dirs: usize,
    /// Dead chunk files deleted from demoted directories.
    pub removed_chunks: usize,
}

/// Chain-aware pruning + garbage collection for a directory of
/// `step-NNNNNNNN` checkpoints (the trainer layout).
///
/// Keeps the newest `keep_last` *complete* checkpoints (manifest
/// present) loadable. Older directories are:
///
/// * **removed** entirely (including device-side partition/chunk dirs)
///   when no kept checkpoint references their chunks;
/// * **demoted** to chunk stores when kept deltas still reference some
///   of their chunks: the manifest is deleted (the checkpoint is no
///   longer loadable or resumable) and every chunk file *not*
///   referenced by a kept manifest — a dead chunk — is reclaimed, on
///   the main filesystem and on every device root.
///
/// Directories newer than the newest kept manifest (e.g. an in-flight
/// pipelined write that has not published its manifest yet) are never
/// touched, and neither is the step named by `protect` — pass the step
/// just written so a run that reuses a directory containing *stale
/// higher-numbered* checkpoints can never prune its own newest work
/// (the trainer always does). `keep_last == 0` (keep everything) is a
/// no-op.
pub fn prune_chain(
    parent: &Path,
    keep_last: usize,
    devices: &DeviceMap,
    protect: Option<u64>,
) -> Result<PruneStats> {
    let mut stats = PruneStats::default();
    if keep_last == 0 {
        return Ok(stats);
    }
    // All step dirs. Manifests are parsed *lazily* (kept checkpoints
    // only): a steady-state prune on the training hot path costs at
    // most `keep_last + 1` manifest parses, not one per directory, and
    // nothing at all while fewer than keep_last checkpoints exist.
    let mut dirs: Vec<(u64, PathBuf, bool)> = Vec::new();
    let Ok(entries) = std::fs::read_dir(parent) else { return Ok(stats) };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if let Some(step) = name.strip_prefix("step-").and_then(|s| s.parse::<u64>().ok()) {
            let has_manifest = path.join(MANIFEST_FILE).exists();
            dirs.push((step, path, has_manifest));
        }
    }
    dirs.sort_by_key(|(step, _, _)| *step);
    let complete = dirs.iter().filter(|(_, _, m)| *m).count();
    if complete <= keep_last {
        return Ok(stats);
    }
    // The newest `keep_last` complete checkpoints stay loadable, plus
    // the protected (just-written) one whatever its step number.
    // Unparseable manifests are treated as incomplete (skipped here,
    // reclaimed below like any other unreferenced old directory).
    let mut kept: BTreeMap<u64, CheckpointManifest> = BTreeMap::new();
    for (step, path, has_manifest) in dirs.iter().rev() {
        if kept.len() >= keep_last {
            break;
        }
        if *has_manifest {
            if let Ok(m) = CheckpointManifest::load(path) {
                kept.insert(*step, m);
            }
        }
    }
    if let Some(p) = protect {
        if !kept.contains_key(&p) {
            if let Some((_, path, _)) = dirs.iter().find(|(s, _, h)| *s == p && *h) {
                if let Ok(m) = CheckpointManifest::load(path) {
                    kept.insert(p, m);
                }
            }
        }
    }
    let Some(max_kept) = kept.keys().next_back().copied() else { return Ok(stats) };
    // Live chunk files per directory name, from kept manifests.
    let mut live: BTreeMap<String, std::collections::BTreeSet<String>> = BTreeMap::new();
    let mut required: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    for (step, path, _) in &dirs {
        let Some(m) = kept.get(step) else { continue };
        let own = dir_name_of(path)?;
        if let Some(delta) = m.delta.as_ref() {
            for (i, c) in delta.chunks.iter().enumerate() {
                let owner = c.source.clone().unwrap_or_else(|| own.clone());
                if c.source.is_some() {
                    required.insert(owner.clone());
                }
                live.entry(owner).or_default().insert(DeltaSection::chunk_file(i));
            }
        }
    }
    for (step, path, _) in &dirs {
        if kept.contains_key(step) || *step > max_kept || Some(*step) == protect {
            continue; // kept, protected, or possibly still being written
        }
        let name = dir_name_of(path)?;
        if required.contains(&name) {
            // Demote: no longer loadable, but its live chunks feed
            // newer deltas. Reclaim the dead ones everywhere.
            let _ = std::fs::remove_file(path.join(MANIFEST_FILE));
            let live_here = live.get(&name);
            stats.removed_chunks += gc_chunk_files(path, live_here);
            for root in devices.roots() {
                stats.removed_chunks +=
                    gc_chunk_files(&DeviceMap::resolve_in(root, path), live_here);
            }
            stats.demoted_dirs += 1;
        } else {
            devices.remove_checkpoint(path);
            let _ = std::fs::remove_dir_all(path);
            stats.removed_dirs += 1;
        }
    }
    Ok(stats)
}

/// Delete `chunk-*.fpck` files in `dir` that are not in `live`.
fn gc_chunk_files(
    dir: &Path,
    live: Option<&std::collections::BTreeSet<String>>,
) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    let mut removed = 0;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let dead = name.starts_with("chunk-")
            && name.ends_with(".fpck")
            && live.map_or(true, |set| !set.contains(name));
        if dead && std::fs::remove_file(entry.path()).is_ok() {
            removed += 1;
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::load::load_checkpoint;
    use crate::io::engine::{scratch_dir, IoConfig};
    use crate::io::runtime::IoRuntimeConfig;
    use crate::tensor::{DType, Tensor};
    use crate::util::rng::Rng;

    const CS: u64 = 4096;

    fn runtime() -> Arc<IoRuntime> {
        Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            ..IoRuntimeConfig::default()
        }))
    }

    fn ckpt(runtime: Arc<IoRuntime>, max_chain: u64) -> DeltaCheckpointer {
        DeltaCheckpointer::new(runtime, DeltaConfig { chunk_size: CS, max_chain })
    }

    fn store(seed: u64, nbytes: usize) -> TensorStore {
        let mut rng = Rng::new(seed);
        let mut s = TensorStore::new();
        let mut data = vec![0u8; nbytes];
        rng.fill_bytes(&mut data);
        s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
        s
    }

    /// Mutate `frac` of the tensor, contiguous, starting mid-way.
    fn mutate(s: &mut TensorStore, frac: f64, tag: u8) {
        let t = s.get("w").unwrap();
        let mut data = t.data.as_slice().to_vec();
        let n = (data.len() as f64 * frac) as usize;
        let start = data.len() / 3;
        for b in &mut data[start..start + n] {
            *b ^= tag | 1;
        }
        s.update("w", data).unwrap();
    }

    fn extra(step: i64) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("step".to_string(), Json::Int(step));
        m
    }

    #[test]
    fn chunk_hashes_match_slice_checksums() {
        let s = store(1, 3 * CS as usize + 123);
        let ser = SerializedCheckpoint::new(&s, extra(0));
        let bytes = ser.to_bytes();
        let grid = chunk_hashes(&ser, CS);
        assert_eq!(grid.len(), bytes.len().div_ceil(CS as usize));
        let mut off = 0usize;
        for (i, ch) in grid.iter().enumerate() {
            let end = off + ch.len as usize;
            assert_eq!(ch.hash, checksum64_slice(&bytes[off..end]), "chunk {i}");
            off = end;
        }
        assert_eq!(off, bytes.len());
        // grid size 1 byte and giant grid both tile correctly
        let one = chunk_hashes(&ser, 1);
        assert_eq!(one.len(), bytes.len());
        let giant = chunk_hashes(&ser, 1 << 30);
        assert_eq!(giant.len(), 1);
        assert_eq!(giant[0].hash, checksum64_slice(&bytes));
    }

    #[test]
    fn base_then_delta_reloads_bit_identically() {
        let dir = scratch_dir("delta-chain").unwrap();
        let rt = runtime();
        let mut ck = ckpt(rt, 8);
        let mut s = store(7, 40 * CS as usize);
        let base = ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        assert!(base.is_base);
        assert_eq!(base.written_bytes, base.total_bytes);

        mutate(&mut s, 0.04, 0x10);
        let d1 = ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
        assert!(!d1.is_base);
        assert!(
            d1.written_bytes * 5 < d1.total_bytes,
            "4% mutation must write a small fraction ({} of {})",
            d1.written_bytes,
            d1.total_bytes
        );
        let snap2 = s.snapshot();

        mutate(&mut s, 0.02, 0x20);
        let d2 = ck.write(&s, extra(3), &dir.join("step-00000003")).unwrap();
        assert!(!d2.is_base);
        assert_eq!(d2.manifest.delta.as_ref().unwrap().chain_len, 2);

        // every link of the chain loads bit-identically
        let (l1, h1, m1) = load_checkpoint(&dir.join("step-00000002"), 3).unwrap();
        assert!(l1.content_eq(&snap2));
        assert_eq!(h1.extra["step"], Json::Int(2));
        assert!(m1.is_delta());
        let (l2, _, _) = load_checkpoint(&dir.join("step-00000003"), 3).unwrap();
        assert!(l2.content_eq(&s));
        let (l0, _, _) = load_checkpoint(&dir.join("step-00000001"), 3).unwrap();
        assert!(l0.content_eq(&store(7, 40 * CS as usize)));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unchanged_state_writes_zero_chunks() {
        let dir = scratch_dir("delta-zero").unwrap();
        let rt = runtime();
        let mut ck = ckpt(rt, 8);
        let s = store(3, 10 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        // same state, same extras -> identical stream -> nothing dirty
        let d = ck.write(&s, extra(1), &dir.join("step-00000002")).unwrap();
        assert_eq!(d.chunks_written, 0);
        assert_eq!(d.written_bytes, 0);
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), 2).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn chain_compacts_after_max_chain() {
        let dir = scratch_dir("delta-compact").unwrap();
        let rt = runtime();
        let mut ck = ckpt(rt, 2);
        let mut s = store(9, 8 * CS as usize);
        for step in 1..=5u64 {
            let out = ck.write(&s, extra(step as i64), &dir.join(format!("step-{step:08}"))).unwrap();
            // chain: base(1), d(2), d(3), base(4), d(5)
            let expect_base = step == 1 || step == 4;
            assert_eq!(out.is_base, expect_base, "step {step}");
            mutate(&mut s, 0.1, step as u8);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_from_manifest_continues_chain() {
        let dir = scratch_dir("delta-resume").unwrap();
        let rt = runtime();
        let mut ck = ckpt(Arc::clone(&rt), 8);
        let mut s = store(11, 12 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        mutate(&mut s, 0.05, 1);
        ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();

        // "restart": a fresh writer resumes from the on-disk manifest
        let mut ck2 = ckpt(rt, 8);
        assert!(ck2.resume_from(&dir.join("step-00000002")).unwrap());
        assert_eq!(ck2.chain_len(), Some(1));
        mutate(&mut s, 0.05, 2);
        let d = ck2.write(&s, extra(3), &dir.join("step-00000003")).unwrap();
        assert!(!d.is_base, "resumed writer must continue the chain");
        assert!(d.written_bytes < d.total_bytes / 2);
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000003"), 2).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_demotes_required_dirs_and_reclaims_dead_chunks() {
        let dir = scratch_dir("delta-prune").unwrap();
        let devices = DeviceMap::single();
        let rt = runtime();
        let mut ck = ckpt(rt, 8);
        let mut s = store(5, 10 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        mutate(&mut s, 0.08, 1); // dirties a few chunks
        ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();

        let base_dir = dir.join("step-00000001");
        let chunks_before = std::fs::read_dir(&base_dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with("chunk-"))
            .count();

        let stats = prune_chain(&dir, 1, &devices, Some(2)).unwrap();
        assert_eq!(stats.removed_dirs, 0);
        assert_eq!(stats.demoted_dirs, 1, "base still referenced -> demoted, not removed");
        assert!(stats.removed_chunks > 0, "chunks rewritten by the delta are dead in the base");
        assert!(!base_dir.join(MANIFEST_FILE).exists(), "demoted dir loses its manifest");
        let chunks_after = std::fs::read_dir(&base_dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with("chunk-"))
            .count();
        assert_eq!(chunks_before, chunks_after + stats.removed_chunks);

        // the kept delta still reloads bit-identically from the store
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), 2).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_removes_unreferenced_dirs_after_compaction() {
        let dir = scratch_dir("delta-prune-gc").unwrap();
        let devices = DeviceMap::single();
        let rt = runtime();
        let mut ck = ckpt(rt, 8);
        let mut s = store(6, 6 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        mutate(&mut s, 0.1, 1);
        ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
        // compaction: step 3 is a fresh base, chain references die
        ck.compact_next();
        let out = ck.write(&s, extra(3), &dir.join("step-00000003")).unwrap();
        assert!(out.is_base);

        let stats = prune_chain(&dir, 1, &devices, Some(3)).unwrap();
        assert_eq!(stats.removed_dirs, 2, "pre-compaction chain is unreferenced");
        assert_eq!(stats.demoted_dirs, 0);
        assert!(!dir.join("step-00000001").exists());
        assert!(!dir.join("step-00000002").exists());
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000003"), 2).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_never_touches_the_protected_step_even_if_stale_steps_are_newer() {
        // A fresh run reusing a directory that still holds higher-
        // numbered checkpoints from a previous run must not have its
        // just-written checkpoint pruned out from under it.
        let dir = scratch_dir("delta-prune-stale").unwrap();
        let devices = DeviceMap::single();
        let rt = runtime();
        // stale previous run: steps 8 and 9
        let mut old = ckpt(Arc::clone(&rt), 8);
        let s_old = store(21, 6 * CS as usize);
        old.write(&s_old, extra(8), &dir.join("step-00000008")).unwrap();
        old.write(&s_old, extra(9), &dir.join("step-00000009")).unwrap();
        // fresh run writes step 1 and prunes with keep_last=1
        let mut fresh = ckpt(rt, 8);
        let s_new = store(22, 6 * CS as usize);
        fresh.write(&s_new, extra(1), &dir.join("step-00000001")).unwrap();
        prune_chain(&dir, 1, &devices, Some(1)).unwrap();
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000001"), 2).unwrap();
        assert!(loaded.content_eq(&s_new), "protected checkpoint must survive pruning");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn strategy_parse_roundtrip() {
        assert_eq!(CheckpointStrategy::parse("full").unwrap(), CheckpointStrategy::Full);
        let CheckpointStrategy::Delta(d) = CheckpointStrategy::parse("delta").unwrap() else {
            panic!("delta parses to Delta");
        };
        assert_eq!(d, DeltaConfig::default());
        let CheckpointStrategy::Delta(d) = CheckpointStrategy::parse("delta4").unwrap() else {
            panic!("delta4 parses to Delta");
        };
        assert_eq!(d.max_chain, 4);
        assert!(CheckpointStrategy::parse("bogus").is_err());
        assert!(CheckpointStrategy::parse("deltaX").is_err());
        assert_eq!(CheckpointStrategy::Delta(DeltaConfig::default()).name(), "delta8");
    }

    #[test]
    fn multi_device_delta_routes_and_reloads() {
        let base = scratch_dir("delta-devmap").unwrap();
        let devices = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            devices: devices.clone(),
            ..IoRuntimeConfig::default()
        }));
        let mut ck = DeltaCheckpointer::new(rt, DeltaConfig { chunk_size: CS, max_chain: 8 });
        let mut s = store(13, 9 * CS as usize);
        let dir = base.join("ckpts");
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        mutate(&mut s, 0.3, 1);
        let d = ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
        assert!(d.manifest.devices().len() >= 2, "chunks must stripe across devices");
        // no chunk file lands in the checkpoint dir itself
        let local = std::fs::read_dir(dir.join("step-00000002"))
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with("chunk-"))
            .count();
        assert_eq!(local, 0);
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), 2).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn prop_dirty_detection_never_misses_changes() {
        crate::prop::forall("delta reload equals live state", 12, |g| {
            let dir = scratch_dir("delta-prop").unwrap();
            let rt = runtime();
            let mut ck = ckpt(rt, 8);
            let nbytes = g.usize(1, 6 * CS as usize);
            let mut s = store(g.u64(0, u64::MAX), nbytes);
            ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
            // random point mutations
            let t = s.get("w").unwrap();
            let mut data = t.data.as_slice().to_vec();
            for _ in 0..g.usize(0, 8) {
                let i = g.usize(0, data.len() - 1);
                data[i] ^= 0x5a;
            }
            s.update("w", data).unwrap();
            ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
            let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), 2).unwrap();
            let ok = loaded.content_eq(&s);
            std::fs::remove_dir_all(&dir).unwrap();
            ok
        });
    }
}
