//! Incremental (delta) checkpointing over the shared I/O runtime, with
//! segment-file chunk stores.
//!
//! FastPersist makes the *write path* fast; this module makes the
//! *written bytes* small, which is what per-iteration checkpointing at
//! the ROADMAP's scale ultimately needs. The idea follows Check-N-Run's
//! differential checkpointing: between consecutive checkpoints most of
//! the serialized state is unchanged, so only the changed part needs to
//! reach storage — the rest can be *referenced* from earlier
//! checkpoints.
//!
//! ## Mechanism
//!
//! The serialized stream (header ‖ tensor payloads, exactly the bytes a
//! full checkpoint would write) is cut into a *header-split* chunk
//! grid: chunk 0 is the whole encoded header, chunks 1.. tile the data
//! section in [`DeltaConfig::chunk_size`] steps. The grid hashes are
//! computed **inside** the single serialization pass
//! ([`crate::serialize::writer::SerializedCheckpoint::new_chunked`]
//! feeds a [`crate::serialize::format::ChunkedChecksum`]), so delta
//! creation makes exactly one CPU pass over the state bytes — there is
//! no separate grid-hash pass. The hashes are diffed against the
//! previous checkpoint's chunk table:
//!
//! * **dirty** chunks (hash or length changed, or no predecessor) are
//!   packed into a bounded number of large **segment files** — one
//!   [`WriteJob`] and one fsync per *segment*, not per chunk — striped
//!   across the runtime's [`crate::io::DeviceMap`] exactly like
//!   full-checkpoint partitions. This is §4.1's aligned-batched-writes
//!   discipline applied to the base/compaction path: a base of N chunks
//!   used to cost N small files + N fsyncs, now it costs
//!   `⌈bytes / segment_bytes⌉` (at least one per device) large
//!   sequential writes;
//! * **clean** chunks are *inherited*: the new manifest's chunk table
//!   entry points at the `(sibling directory, segment, offset)` that
//!   physically holds the chunk's bytes.
//!
//! The same manifest-published-last discipline is what makes the
//! [`crate::checkpoint::lazy`] flush path crash-safe: a lazy generation
//! that dies between capture and manifest publish leaves segment bytes
//! but no manifest, so it is invisible to recovery, and — because a
//! skipped generation never executes [`DeltaCheckpointer::write`] — the
//! writer's chunk table still describes the last *published* delta.
//! The chain therefore stays consistent: the next flush diffs against
//! durable state, never against a generation that was lost in flight.
//!
//! Between serialization and segment packing sits the optional
//! **codec stage** ([`crate::checkpoint::codec`], [`DeltaConfig::codec`]):
//! each dirty chunk is independently encoded (`lz4` block compression,
//! or `qdelta` quantized diffs against the chunk's last raw-stored
//! bytes), stored raw whenever encoding does not shrink it, and
//! recorded in the manifest chunk table with its codec id, encoded
//! length, and (for qdelta) base extent. The WritePlan/drain-lane/ring
//! mechanics below stay byte-oriented and codec-oblivious; decoding
//! happens inside the read job, before the same folded raw-hash chunk
//! checks. Base and compaction writes always store exact raw bytes, so
//! quantized chains can never accumulate error past one compaction
//! interval.
//!
//! The resulting manifest (v4,
//! [`crate::checkpoint::manifest::DeltaSection`]) is **fully
//! resolved**: loading never walks ancestor manifests, it reads each
//! chunk from the segment its entry addresses, reassembles the stream,
//! and verifies the stream digest — bit-identical to loading a full
//! checkpoint of the same state. The manifest is published last (atomic
//! rename), so an interrupted delta flush leaves no manifest and
//! recovery simply falls back to the newest complete checkpoint.
//! Checkpoints written by the previous per-chunk-file layout (manifest
//! v3) remain loadable; see `docs/FORMATS.md` for the on-disk format
//! reference.
//!
//! ## Chains, compaction, GC
//!
//! Deltas form a chain: `base ← Δ₁ ← Δ₂ …`. Every
//! [`DeltaConfig::max_chain`] deltas the chain is *compacted*: the next
//! checkpoint is written as a fresh base (all chunks local), breaking
//! every reference to older directories. [`prune_chain`] then garbage
//! collects: unreferenced checkpoint directories are removed outright,
//! while directories still holding chunks that live checkpoints
//! reference are demoted to chunk stores (manifest dropped). GC is
//! **segment-granular** with live-bytes accounting: a demoted
//! directory's segment file is deleted when no kept manifest references
//! any chunk in it, and *sparsely rewritten* — live byte ranges copied
//! to identical offsets in a fresh file, dead ranges left as holes —
//! when its live-byte occupancy drops below [`GcPolicy::occupancy`].
//! Rewriting preserves every chunk's `(segment, offset)` address, so
//! kept manifests and in-flight writer state stay valid without being
//! touched. Kept manifests are re-examined every prune; a small
//! process-wide LRU (`CheckpointManifest::load_cached`, keyed by path +
//! mtime) makes the steady-state re-parses free.
//!
//! Chain members must be sibling directories (the trainer's
//! `step-NNNNNNNN` layout); the manifest records directory *names*, not
//! paths, so a whole checkpoint tree can be relocated as long as
//! single-device layouts are used (device routing pins directories, see
//! [`crate::io::DeviceMap::checkpoint_tag`]).
//!
//! Chunk hashes are 64-bit non-cryptographic checksums: ample for
//! corruption detection and change tracking of trusted local state (a
//! colliding *and* torn update is what the stream digest still
//! catches), not a content-addressing security boundary.
//!
//! # Examples
//!
//! A base checkpoint packs its chunks into segment files; a subsequent
//! delta writes only what changed, and both reload bit-identically:
//!
//! ```
//! use std::collections::BTreeMap;
//! use std::sync::Arc;
//! use fastpersist::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
//! use fastpersist::checkpoint::load::load_checkpoint;
//! use fastpersist::io::engine::{scratch_dir, IoConfig};
//! use fastpersist::io::runtime::{IoRuntime, IoRuntimeConfig};
//! use fastpersist::tensor::{DType, Tensor, TensorStore};
//!
//! let dir = scratch_dir("doc-delta").unwrap();
//! let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
//!     io: IoConfig::fastpersist().microbench(),
//!     ..IoRuntimeConfig::default()
//! }));
//! let cfg = DeltaConfig { chunk_size: 4096, ..DeltaConfig::default() };
//! let mut ck = DeltaCheckpointer::new(Arc::clone(&rt), cfg);
//!
//! let mut store = TensorStore::new();
//! store.push(Tensor::new("w", DType::U8, vec![32768], vec![1u8; 32768]).unwrap()).unwrap();
//! let base = ck.write(&store, BTreeMap::new(), &dir.join("step-00000001")).unwrap();
//! assert!(base.is_base);
//! // many chunks coalesce into few segment files (one WriteJob each)
//! assert!(base.segments_written < base.chunks_total);
//!
//! let mut mutated = vec![1u8; 32768];
//! mutated[9000] = 2;
//! store.update("w", mutated).unwrap();
//! let delta = ck.write(&store, BTreeMap::new(), &dir.join("step-00000002")).unwrap();
//! assert!(!delta.is_base);
//! assert!(delta.written_bytes < delta.total_bytes / 2);
//!
//! let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), &rt).unwrap();
//! assert!(loaded.content_eq(&store));
//! std::fs::remove_dir_all(&dir).unwrap();
//! ```

use std::collections::BTreeMap;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::checkpoint::codec::{encode_chunk, CodecKind};
use crate::checkpoint::engine::CheckpointOutcome;
use crate::checkpoint::manifest::{
    CheckpointManifest, ChunkBaseRef, ChunkEntry, DeltaSection, SegmentRef, MANIFEST_FILE,
};
use crate::io::device::DeviceMap;
use crate::io::engine::WriteStats;
use crate::io::read::{
    plan_runs, ChunkCheck, DecodeBase, DecodeSpec, PrefixCheck, ReadJob, ReadPart, StreamBuffer,
};
use crate::io::runtime::{IoRuntime, SegPart, Ticket, WriteJob};
use crate::serialize::writer::SerializedCheckpoint;
use crate::tensor::TensorStore;
use crate::util::json::Json;
use crate::{Error, Result};

pub use crate::serialize::format::ChunkDigest;

/// Magic bytes opening every segment store file.
pub const SEGMENT_MAGIC: [u8; 4] = *b"FPSG";

/// Segment container version.
pub const SEGMENT_VERSION: u32 = 1;

/// Fixed on-disk length of the segment header: one I/O alignment unit,
/// so packed **data** chunks start 4 KiB-aligned whenever `chunk_size`
/// is a multiple of 4 KiB (the stream's header chunk — a 256-byte
/// multiple — is packed *last* in its segment precisely so it cannot
/// shift the data chunks off alignment).
pub const SEGMENT_HEADER_LEN: usize = 4096;

/// Byte offset inside the segment header of the `compacted_live`
/// GC-bookkeeping field: the live-byte count the last sparse rewrite
/// compacted against (0 = never compacted). Lets segment GC skip
/// segments where nothing further died since the last rewrite, on any
/// filesystem, without guessing allocation granularity.
pub const SEGMENT_COMPACTED_OFFSET: usize = 24;

/// Encode a segment header: magic ‖ version ‖ segment index ‖ chunk
/// count ‖ payload length ‖ compacted_live (0 at write time),
/// zero-padded to [`SEGMENT_HEADER_LEN`].
pub fn encode_segment_header(index: u32, chunks: u32, payload_len: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(SEGMENT_HEADER_LEN);
    out.extend_from_slice(&SEGMENT_MAGIC);
    out.extend_from_slice(&SEGMENT_VERSION.to_le_bytes());
    out.extend_from_slice(&index.to_le_bytes());
    out.extend_from_slice(&chunks.to_le_bytes());
    out.extend_from_slice(&payload_len.to_le_bytes());
    out.resize(SEGMENT_HEADER_LEN, 0);
    out
}

/// Validate the fixed prefix (magic + version) of a segment header.
pub fn check_segment_header(bytes: &[u8]) -> Result<()> {
    if bytes.len() < 8 {
        return Err(Error::Format("truncated segment header".into()));
    }
    if bytes[..4] != SEGMENT_MAGIC {
        return Err(Error::Format(format!("bad segment magic {:?}", &bytes[..4])));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != SEGMENT_VERSION {
        return Err(Error::Format(format!("unsupported segment version {version}")));
    }
    Ok(())
}

/// Tuning knobs for incremental checkpointing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeltaConfig {
    /// Chunk-grid size in bytes. The default (1 MiB) is a multiple of
    /// every supported I/O alignment; small sizes track changes more
    /// precisely but inflate the chunk table.
    pub chunk_size: u64,
    /// Maximum deltas after a base before the chain is compacted into a
    /// fresh base (0 = every checkpoint is a base).
    pub max_chain: u64,
    /// Target payload bytes per segment file. A checkpoint's dirty
    /// chunks are packed into `⌈dirty_bytes / segment_bytes⌉` segments
    /// (at least one per device of the runtime's map, never more than
    /// one per dirty chunk) — each segment is one WriteJob and one
    /// fsync.
    pub segment_bytes: u64,
    /// Per-chunk codec applied between serialization and segment
    /// packing ([`crate::checkpoint::codec`]). Chunks whose encoding
    /// does not shrink them are stored raw (the benefit gate), so a
    /// codec never inflates the stored payload. `QuantDelta` encodes
    /// dirty chunks as quantized diffs against their last raw-stored
    /// bytes; base/compaction writes always store exact raw bytes, so
    /// quantization error can never accumulate across chains.
    pub codec: CodecKind,
}

impl Default for DeltaConfig {
    fn default() -> Self {
        DeltaConfig {
            chunk_size: 1 << 20,
            max_chain: 8,
            segment_bytes: 64 << 20,
            codec: CodecKind::None,
        }
    }
}

impl DeltaConfig {
    /// Clamp the knobs to coherent values: chunk size at least one I/O
    /// alignment unit (4 KiB) so packed chunks keep the direct-write
    /// fast path, segment size at least one chunk.
    pub fn normalized(self) -> DeltaConfig {
        let chunk_size = self.chunk_size.max(4096);
        DeltaConfig {
            chunk_size,
            segment_bytes: self.segment_bytes.max(chunk_size),
            ..self
        }
    }
}

/// Segment garbage-collection policy for [`prune_chain_with`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GcPolicy {
    /// Live-byte occupancy threshold below which a demoted directory's
    /// segment file is sparsely rewritten (dead ranges punched out,
    /// live chunks kept at identical offsets). `0.0` never rewrites;
    /// `1.0` rewrites whenever any chunk in the segment is dead.
    pub occupancy: f64,
}

impl Default for GcPolicy {
    fn default() -> Self {
        GcPolicy { occupancy: 0.5 }
    }
}

/// Which checkpoint layout the trainer produces each iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointStrategy {
    /// Full snapshot every time: byte-partitioned parallel writes via
    /// [`crate::checkpoint::CheckpointEngine`].
    Full,
    /// Chunk-granular incremental checkpoints via [`DeltaCheckpointer`].
    Delta(DeltaConfig),
}

impl CheckpointStrategy {
    /// Short CLI name: `full`, or `delta<max_chain>`.
    pub fn name(self) -> String {
        match self {
            CheckpointStrategy::Full => "full".into(),
            CheckpointStrategy::Delta(d) => format!("delta{}", d.max_chain),
        }
    }

    /// Parse `full`, `delta`, or `delta<N>` (N = max chain length).
    pub fn parse(s: &str) -> Result<CheckpointStrategy> {
        match s {
            "full" => Ok(CheckpointStrategy::Full),
            "delta" => Ok(CheckpointStrategy::Delta(DeltaConfig::default())),
            other => {
                if let Some(n) = other.strip_prefix("delta") {
                    let max_chain: u64 = n
                        .parse()
                        .map_err(|_| Error::Config(format!("bad checkpoint strategy {other:?}")))?;
                    return Ok(CheckpointStrategy::Delta(DeltaConfig {
                        max_chain,
                        ..DeltaConfig::default()
                    }));
                }
                Err(Error::Config(format!("unknown checkpoint strategy {other:?}")))
            }
        }
    }
}

/// Result of one incremental checkpoint write.
#[derive(Debug)]
pub struct DeltaOutcome {
    /// The published (v4) manifest.
    pub manifest: CheckpointManifest,
    /// Per-**segment** write stats, segment order (one WriteJob each).
    pub stats: Vec<WriteStats>,
    /// Wall latency: serialize start → manifest durable.
    pub latency: Duration,
    /// Logical stream length (what a full checkpoint would write).
    pub total_bytes: u64,
    /// Bytes actually written (dirty chunks only, excluding segment
    /// headers).
    pub written_bytes: u64,
    /// Chunks in the stream's grid (header chunk included).
    pub chunks_total: usize,
    /// Dirty chunks written by this checkpoint.
    pub chunks_written: usize,
    /// Segment files (= WriteJobs) this checkpoint issued.
    pub segments_written: usize,
    /// fsync/fdatasync calls issued across all segment writes (0 when
    /// durability is disabled). The coalescing invariant: equals
    /// `segments_written` under durable configs, never
    /// `chunks_written`.
    pub fsyncs: u64,
    /// True if this checkpoint is a chain base (all chunks local).
    pub is_base: bool,
    /// Raw bytes of the dirty chunks — what an uncompressed write of
    /// the same dirty set would have stored.
    pub bytes_raw: u64,
    /// Stored payload bytes after the codec stage (==
    /// `written_bytes`; explicit so `bytes_encoded / bytes_raw` reads
    /// as the codec ratio).
    pub bytes_encoded: u64,
    /// CPU time spent encoding dirty chunks (zero under
    /// [`CodecKind::None`], which keeps the zero-copy write path).
    pub encode: Duration,
}

impl DeltaOutcome {
    /// Fraction of the stream that did **not** have to be written.
    pub fn savings(&self) -> f64 {
        if self.total_bytes == 0 {
            return 0.0;
        }
        1.0 - self.written_bytes as f64 / self.total_bytes as f64
    }

    /// Mean payload bytes per WriteJob (0 when nothing was written) —
    /// the coalescing metric the delta bench reports.
    pub fn bytes_per_job(&self) -> u64 {
        if self.segments_written == 0 {
            0
        } else {
            self.written_bytes / self.segments_written as u64
        }
    }

    /// Aligned extents drained through an O_DIRECT descriptor, summed
    /// over every segment write (0 under a probed fallback).
    pub fn direct_extents(&self) -> u64 {
        self.stats.iter().map(|s| s.direct_extents).sum()
    }

    /// Sub-alignment bytes routed through zeroed bounce buffers, summed
    /// over every segment write.
    pub fn bounce_bytes(&self) -> u64 {
        self.stats.iter().map(|s| s.bounce_bytes).sum()
    }

    /// Batched ring submission syscalls, summed over every segment
    /// write (0 end to end on the sync backend).
    pub fn batched_submissions(&self) -> u64 {
        self.stats.iter().map(|s| s.batched_submissions).sum()
    }

    /// High-water count of sqes handed to the kernel in one submission
    /// syscall, across every segment write.
    pub fn sqes_per_submit_max(&self) -> u64 {
        self.stats.iter().map(|s| s.sqes_per_submit_max).max().unwrap_or(0)
    }

    /// Ring completions reaped, summed over every segment write.
    pub fn completions_reaped(&self) -> u64 {
        self.stats.iter().map(|s| s.completions_reaped).sum()
    }

    /// View as a generic [`CheckpointOutcome`] (the pipelined helper's
    /// common currency).
    pub fn into_outcome(self) -> CheckpointOutcome {
        CheckpointOutcome {
            manifest: self.manifest,
            stats: self.stats,
            latency: self.latency,
            total_bytes: self.total_bytes,
            written_bytes: self.written_bytes,
            bytes_raw: self.bytes_raw,
            bytes_encoded: self.bytes_encoded,
            encode: self.encode,
        }
    }
}

/// The previous checkpoint's resolved chunk table, kept in memory so
/// steady-state diffing costs no manifest re-parse.
struct PrevCheckpoint {
    parent: PathBuf,
    dir_name: String,
    chain_len: u64,
    chunk_size: u64,
    chunks: Vec<ResolvedChunk>,
}

#[derive(Clone)]
struct ResolvedChunk {
    hash: u64,
    len: u64,
    /// Directory name that physically holds the chunk's segment.
    source: String,
    device: Option<String>,
    seg: SegmentRef,
    codec: CodecKind,
    enc_len: u64,
    base: Option<ChunkBaseRef>,
}

/// One segment of a checkpoint's write plan: an ordered mix of merged
/// raw stream ranges and codec-encoded chunk payloads, plus accounting
/// (`payload` counts *stored* bytes).
#[derive(Default)]
struct SegPlan {
    parts: Vec<SegPart>,
    chunks: u32,
    payload: u64,
}

/// Raw-byte reference a future [`CodecKind::QuantDelta`] encode diffs
/// against: the chunk's last raw-stored bytes and the durable segment
/// extent that holds them (what the manifest's [`ChunkBaseRef`] will
/// point the decoder at).
struct QdRef {
    bytes: Vec<u8>,
    source: String,
    device: Option<String>,
    seg: SegmentRef,
}

/// Chunk-granular incremental checkpoint writer over a shared
/// [`IoRuntime`].
///
/// Stateful: remembers the previous checkpoint's chunk table to diff
/// against (resumable from an on-disk manifest via
/// [`DeltaCheckpointer::resume_from`]). All I/O goes through the
/// runtime's persistent writer pool and device map, interleaving with
/// any other checkpoint traffic on the same runtime.
pub struct DeltaCheckpointer {
    runtime: Arc<IoRuntime>,
    cfg: DeltaConfig,
    prev: Option<PrevCheckpoint>,
    /// Per-chunk-index raw reference bytes for qdelta encoding (empty
    /// unless the config codec is [`CodecKind::QuantDelta`]). Rebuilt
    /// whenever a chunk stores raw bytes; cleared by resume (no raw
    /// bytes survive a restart, so the next write re-seeds them).
    qd_refs: BTreeMap<usize, QdRef>,
}

impl DeltaCheckpointer {
    /// A delta writer submitting into `runtime`; the first write is a
    /// base checkpoint.
    pub fn new(runtime: Arc<IoRuntime>, cfg: DeltaConfig) -> DeltaCheckpointer {
        DeltaCheckpointer {
            runtime,
            cfg: cfg.normalized(),
            prev: None,
            qd_refs: BTreeMap::new(),
        }
    }

    /// The runtime this writer submits into.
    pub fn runtime(&self) -> &Arc<IoRuntime> {
        &self.runtime
    }

    /// The (normalized) delta configuration.
    pub fn config(&self) -> DeltaConfig {
        self.cfg
    }

    /// Adopt the checkpoint at `dir` as the chain predecessor, so the
    /// next write diffs against it (crash/restart resume). Returns
    /// `true` if `dir` holds a compatible delta manifest; a full
    /// (partitioned) manifest, a differently-chunked one, or a legacy
    /// per-chunk-file (v3) one leaves the writer in base mode and
    /// returns `false`.
    pub fn resume_from(&mut self, dir: &Path) -> Result<bool> {
        // In-memory qdelta references never survive a restart; the next
        // write stores its dirty chunks raw and re-seeds them (graceful
        // degradation, never a correctness issue).
        self.qd_refs.clear();
        let manifest = CheckpointManifest::load(dir)?;
        let Some(delta) = &manifest.delta else {
            self.prev = None;
            return Ok(false);
        };
        // A v3 manifest (header_len == 0) uses the uniform whole-stream
        // grid and per-chunk files: its table cannot seed the
        // header-split segment diff, so the next write starts a base.
        if delta.chunk_size != self.cfg.chunk_size || delta.header_len == 0 {
            self.prev = None;
            return Ok(false);
        }
        let dir_name = dir_name_of(dir)?;
        let mut chunks = Vec::with_capacity(delta.chunks.len());
        for c in &delta.chunks {
            let Some(seg) = c.seg else {
                self.prev = None;
                return Ok(false);
            };
            chunks.push(ResolvedChunk {
                hash: c.hash,
                len: c.len,
                source: c.source.clone().unwrap_or_else(|| dir_name.clone()),
                device: c.device.clone(),
                seg,
                codec: c.codec,
                enc_len: c.enc_len,
                base: c.base.clone(),
            });
        }
        self.prev = Some(PrevCheckpoint {
            parent: dir.parent().map(Path::to_path_buf).unwrap_or_default(),
            dir_name,
            chain_len: delta.chain_len,
            chunk_size: delta.chunk_size,
            chunks,
        });
        Ok(true)
    }

    /// Force the next write to be a fresh base (explicit compaction).
    pub fn compact_next(&mut self) {
        self.prev = None;
    }

    /// Deltas written since the current chain's base (None = next write
    /// is a base).
    pub fn chain_len(&self) -> Option<u64> {
        self.prev.as_ref().map(|p| p.chain_len)
    }

    /// Write an incremental checkpoint of `store` into `dir`.
    ///
    /// `dir` must be a sibling of the previous checkpoint's directory
    /// (same parent); otherwise — or when the chain has reached
    /// [`DeltaConfig::max_chain`], or no predecessor exists — a base
    /// checkpoint is written instead. Dirty chunks are packed into
    /// segment files (one WriteJob + one fsync each, device-striped);
    /// the manifest is published last.
    pub fn write(
        &mut self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: &Path,
    ) -> Result<DeltaOutcome> {
        let start = Instant::now();
        std::fs::create_dir_all(dir)?;
        let dir_name = dir_name_of(dir)?;
        let parent = dir.parent().map(Path::to_path_buf).unwrap_or_default();
        let step = extra.get("step").and_then(|j| j.as_i64().ok()).unwrap_or(0) as u64;

        // Exactly ONE CPU pass over the state bytes: serialization
        // computes the data digest and the header-split chunk grid
        // together; payloads stay zero-copy Arc references.
        let ser = Arc::new(SerializedCheckpoint::new_chunked(store, extra, self.cfg.chunk_size));
        let digest = ser.stream_digest();
        let (_, grid) = ser.chunk_grid().expect("new_chunked always carries a grid");

        // Delta-eligible only against a same-grid sibling predecessor
        // with chain headroom; anything else starts a fresh base. The
        // predecessor state is *taken*: if this write fails midway the
        // next attempt conservatively starts a fresh base instead of
        // diffing against a chain whose tail never committed.
        let (is_base, base_name, chain_len, prev_chunks) = match self.prev.take() {
            Some(p)
                if p.chunk_size == self.cfg.chunk_size
                    && p.parent == parent
                    && p.chain_len < self.cfg.max_chain =>
            {
                (false, Some(p.dir_name), p.chain_len + 1, p.chunks)
            }
            _ => (true, None, 0, Vec::new()),
        };

        // Diff against the predecessor grid: inherit clean chunks,
        // collect dirty ones for segment packing. Because the grid is
        // data-relative (chunk 0 = header), data chunks line up across
        // checkpoints even if the header length changes.
        let mut entries: Vec<Option<ChunkEntry>> = vec![None; grid.len()];
        let mut resolved: Vec<Option<ResolvedChunk>> = vec![None; grid.len()];
        let mut offsets: Vec<u64> = Vec::with_capacity(grid.len());
        let mut dirty: Vec<usize> = Vec::new();
        let mut written = 0u64;
        let mut off = 0u64;
        for (i, ch) in grid.iter().enumerate() {
            offsets.push(off);
            let clean = !is_base
                && prev_chunks.get(i).is_some_and(|p| p.hash == ch.hash && p.len == ch.len);
            if clean {
                // Inherited entries carry the codec fields of wherever
                // the bytes physically live — a clean chunk that was
                // stored lz4/qdelta stays encoded on disk.
                let p = &prev_chunks[i];
                entries[i] = Some(ChunkEntry {
                    hash: ch.hash,
                    len: ch.len,
                    source: Some(p.source.clone()),
                    device: p.device.clone(),
                    seg: Some(p.seg),
                    codec: p.codec,
                    enc_len: p.enc_len,
                    base: p.base.clone(),
                });
                resolved[i] = Some(p.clone());
            } else {
                dirty.push(i);
                written += ch.len;
            }
            off += ch.len;
        }

        // Codec stage (between serialization and segment packing):
        // dirty chunks are encoded independently; an encoding that
        // does not shrink its chunk is discarded and the chunk stores
        // raw (the benefit gate), so the stored payload never exceeds
        // the raw dirty bytes. CodecKind::None skips materialization
        // entirely and keeps the zero-copy Range path.
        let codec = self.cfg.codec;
        if codec == CodecKind::QuantDelta {
            // Stale references must not outlive the grid; a base write
            // rewrites every chunk raw and re-seeds from scratch.
            if is_base {
                self.qd_refs.clear();
            } else {
                let n = grid.len();
                self.qd_refs.retain(|&i, _| i < n);
            }
        } else {
            self.qd_refs.clear();
        }
        let bytes_raw = written;
        let mut encode = Duration::ZERO;
        // Encoded payload (+ qdelta base ref) by chunk index; chunks
        // absent here store raw bytes.
        let mut enc_chunks: BTreeMap<usize, (Vec<u8>, Option<ChunkBaseRef>)> = BTreeMap::new();
        // Raw bytes of qdelta-config dirty chunks that store raw: they
        // re-seed the quantization references once routing is known.
        let mut raw_dirty: BTreeMap<usize, Vec<u8>> = BTreeMap::new();
        if codec != CodecKind::None {
            let t_enc = Instant::now();
            for &i in &dirty {
                let (s, e) = (offsets[i], offsets[i] + grid[i].len);
                let mut raw = Vec::with_capacity(grid[i].len as usize);
                ser.emit_range(s, e, &mut |piece| {
                    raw.extend_from_slice(piece);
                    Ok(())
                })?;
                let encoded = match codec {
                    CodecKind::None => None,
                    CodecKind::Lz4 => Some((encode_chunk(codec, &raw, None)?, None)),
                    // Quantized diffs only against a chunk whose exact
                    // raw bytes are durably stored — never against an
                    // encoded base, so quantization error cannot chain
                    // — and never on a base/compaction write (those
                    // store exact bytes by contract).
                    CodecKind::QuantDelta => match self.qd_refs.get(&i) {
                        Some(r) if !is_base && r.bytes.len() as u64 == grid[i].len => {
                            let base = ChunkBaseRef {
                                source: Some(r.source.clone()),
                                device: r.device.clone(),
                                seg: r.seg,
                                len: grid[i].len,
                            };
                            Some((encode_chunk(codec, &raw, Some(&r.bytes))?, Some(base)))
                        }
                        _ => None,
                    },
                };
                match encoded {
                    Some((enc, base)) if (enc.len() as u64) < grid[i].len => {
                        enc_chunks.insert(i, (enc, base));
                    }
                    _ => {
                        if codec == CodecKind::QuantDelta {
                            raw_dirty.insert(i, raw);
                        }
                    }
                }
            }
            encode = t_enc.elapsed();
        }
        let stored: u64 = dirty
            .iter()
            .map(|&i| match enc_chunks.get(&i) {
                Some((enc, _)) => enc.len() as u64,
                None => grid[i].len,
            })
            .sum();
        // Manifest codec fields by chunk index, recorded as encoded
        // payloads move into their segment parts.
        let mut enc_meta: BTreeMap<usize, (u64, Option<ChunkBaseRef>)> = BTreeMap::new();

        // Segment plan: enough segments to respect the size cap and to
        // keep every device writing, never more than one per dirty
        // chunk. Consecutive raw dirty chunks merge into single stream
        // ranges, so an uncoded base stays a handful of large
        // sequential zero-copy writes; encoded chunks travel as owned
        // buffers in the same segment order. Packing targets count
        // *stored* bytes.
        let devices = self.runtime.devices();
        let mut segs: Vec<SegPlan> = Vec::new();
        let mut seg_ref: BTreeMap<usize, SegmentRef> = BTreeMap::new();
        if !dirty.is_empty() {
            let by_size = stored.div_ceil(self.cfg.segment_bytes).max(1) as usize;
            let min_parallel = if devices.is_empty() { 1 } else { devices.len() };
            let n_segs = by_size.max(min_parallel).min(dirty.len());
            let target = stored.div_ceil(n_segs as u64).max(1);
            // Data chunks pack in stream order; the header chunk — whose
            // length is a 256-byte (not 4 KiB) multiple — packs LAST in
            // its segment, so data-chunk offsets stay 4 KiB-aligned for
            // 4 KiB-multiple grids (and segment GC's hole punching can
            // free whole blocks under dead data chunks).
            let order = dirty
                .iter()
                .copied()
                .filter(|&i| i != 0)
                .chain(dirty.iter().copied().filter(|&i| i == 0));
            let mut cur = SegPlan::default();
            for (k, i) in order.enumerate() {
                // Close the open segment when it reached its byte
                // target, or when every remaining chunk is needed to
                // give each remaining segment at least one chunk (the
                // one-segment-per-device floor).
                let must_split = dirty.len() - k <= n_segs - segs.len() - 1;
                if cur.chunks > 0
                    && (cur.payload >= target || must_split)
                    && segs.len() + 1 < n_segs
                {
                    segs.push(std::mem::take(&mut cur));
                }
                seg_ref.insert(i, SegmentRef {
                    seg: segs.len() as u32,
                    offset: SEGMENT_HEADER_LEN as u64 + cur.payload,
                });
                let this_len = match enc_chunks.remove(&i) {
                    Some((enc, base)) => {
                        let n = enc.len() as u64;
                        enc_meta.insert(i, (n, base));
                        cur.parts.push(SegPart::Owned(enc));
                        n
                    }
                    None => {
                        let (s, e) = (offsets[i], offsets[i] + grid[i].len);
                        match cur.parts.last_mut() {
                            Some(SegPart::Raw { end, .. }) if *end == s => *end = e,
                            _ => cur.parts.push(SegPart::Raw { start: s, end: e }),
                        }
                        grid[i].len
                    }
                };
                cur.chunks += 1;
                cur.payload += this_len;
            }
            if cur.chunks > 0 {
                segs.push(cur);
            }
        }

        // One WriteJob per segment through the persistent writer pool,
        // striped across the device map by segment index. All-raw
        // segments keep the pre-codec zero-copy chunks path
        // (byte-identical layout); segments holding encoded chunks go
        // through the mixed parts path.
        let n_segments = segs.len();
        let mut tickets: Vec<Ticket> = Vec::with_capacity(n_segments);
        let mut seg_devices: Vec<Option<String>> = Vec::with_capacity(n_segments);
        for (si, seg) in segs.into_iter().enumerate() {
            let file = DeltaSection::segment_file(si);
            let (seg_dir, device) = match devices.partition_dir(dir, si) {
                Some((d, root)) => (d, Some(root)),
                None => (dir.to_path_buf(), None),
            };
            let header = encode_segment_header(si as u32, seg.chunks, seg.payload);
            let path = seg_dir.join(file);
            let all_raw = seg.parts.iter().all(|p| matches!(p, SegPart::Raw { .. }));
            let job = if all_raw {
                let ranges = seg
                    .parts
                    .iter()
                    .map(|p| match p {
                        SegPart::Raw { start, end } => (*start, *end),
                        SegPart::Owned(_) => unreachable!("all parts raw"),
                    })
                    .collect();
                WriteJob::chunks(Arc::clone(&ser), header, ranges, path)
            } else {
                WriteJob::parts(Arc::clone(&ser), header, seg.parts, path)
            };
            tickets.push(self.runtime.submit(job));
            seg_devices.push(device);
        }

        // Fill the dirty entries now that segment routing is known.
        for &i in &dirty {
            let r = seg_ref[&i];
            let device = seg_devices[r.seg as usize].clone();
            let (ck, enc_len, base) = match enc_meta.remove(&i) {
                Some((n, base)) => (codec, n, base),
                None => (CodecKind::None, grid[i].len, None),
            };
            entries[i] = Some(ChunkEntry {
                hash: grid[i].hash,
                len: grid[i].len,
                source: None,
                device: device.clone(),
                seg: Some(r),
                codec: ck,
                enc_len,
                base: base.clone(),
            });
            resolved[i] = Some(ResolvedChunk {
                hash: grid[i].hash,
                len: grid[i].len,
                source: dir_name.clone(),
                device: device.clone(),
                seg: r,
                codec: ck,
                enc_len,
                base,
            });
            // A chunk stored raw re-seeds the reference the next
            // qdelta encode diffs against (and the durable base extent
            // its manifest entry will point the decoder at).
            if codec == CodecKind::QuantDelta && ck == CodecKind::None {
                if let Some(bytes) = raw_dirty.remove(&i) {
                    self.qd_refs
                        .insert(i, QdRef { bytes, source: dir_name.clone(), device, seg: r });
                }
            }
        }

        let stats: Vec<WriteStats> =
            tickets.into_iter().map(Ticket::wait).collect::<Result<Vec<_>>>()?;
        let fsyncs = stats.iter().map(|s| s.fsyncs).sum();

        // All segments durable → publish the manifest. Its presence is
        // the commit point of the whole delta.
        let delta = DeltaSection {
            base: base_name,
            chain_len,
            chunk_size: self.cfg.chunk_size,
            header_len: ser.header_len(),
            chunks: entries
                .into_iter()
                .map(|e| e.expect("every chunk entry filled"))
                .collect(),
        };
        let manifest = CheckpointManifest::from_delta(ser.total_len(), digest, step, delta)
            .with_io_backend(self.runtime.submit_backend_name(dir));
        manifest.validate()?;
        manifest.save_with(dir, self.runtime.io_config().fault.as_ref())?;

        // Remember the resolved table for the next diff.
        self.prev = Some(PrevCheckpoint {
            parent,
            dir_name,
            chain_len,
            chunk_size: self.cfg.chunk_size,
            chunks: resolved
                .into_iter()
                .map(|r| r.expect("every chunk resolved"))
                .collect(),
        });

        Ok(DeltaOutcome {
            total_bytes: ser.total_len(),
            written_bytes: stored,
            chunks_total: grid.len(),
            chunks_written: dirty.len(),
            segments_written: n_segments,
            fsyncs,
            is_base,
            bytes_raw,
            bytes_encoded: stored,
            encode,
            manifest,
            stats,
            latency: start.elapsed(),
        })
    }
}

fn dir_name_of(dir: &Path) -> Result<String> {
    dir.file_name()
        .and_then(|n| n.to_str())
        .map(str::to_string)
        .ok_or_else(|| {
            Error::Config(format!("checkpoint dir {} has no utf-8 name", dir.display()))
        })
}

/// Directory that physically holds chunk-store files of `entry` for the
/// delta checkpoint at `dir`: the entry's source directory (a sibling
/// of `dir`, or `dir` itself), with the device assignment resolved
/// against that *source* directory.
fn owner_dir(dir: &Path, entry: &ChunkEntry) -> PathBuf {
    let owner = match &entry.source {
        Some(s) => dir.parent().map(Path::to_path_buf).unwrap_or_default().join(s),
        None => dir.to_path_buf(),
    };
    match &entry.device {
        Some(root) => DeviceMap::resolve_in(Path::new(root), &owner),
        None => owner,
    }
}

/// On-disk location of chunk `index` of a **legacy (v3)** delta
/// checkpoint at `dir`: one `chunk-NNNNNN.fpck` file per chunk in the
/// entry's source directory.
pub fn chunk_path(dir: &Path, index: usize, entry: &ChunkEntry) -> PathBuf {
    owner_dir(dir, entry).join(DeltaSection::chunk_file(index))
}

/// On-disk location of the segment file holding `entry`'s bytes (v4
/// layout) for the delta checkpoint at `dir`.
pub fn segment_path(dir: &Path, entry: &ChunkEntry, seg: SegmentRef) -> PathBuf {
    owner_dir(dir, entry).join(DeltaSection::segment_file(seg.seg as usize))
}

/// On-disk location of the segment file holding the raw base bytes a
/// qdelta-encoded chunk diffs against, for the delta checkpoint at
/// `dir`. Same sibling-directory + device resolution as chunk owners.
pub fn base_segment_path(dir: &Path, base: &ChunkBaseRef) -> PathBuf {
    let owner = match &base.source {
        Some(s) => dir.parent().map(Path::to_path_buf).unwrap_or_default().join(s),
        None => dir.to_path_buf(),
    };
    let owner = match &base.device {
        Some(root) => DeviceMap::resolve_in(Path::new(root), &owner),
        None => owner,
    };
    owner.join(DeltaSection::segment_file(base.seg.seg as usize))
}

/// Plan the read jobs that reassemble the delta checkpoint at `dir`
/// into `dest` (one job per segment file, with byte-adjacent chunks
/// coalesced into single-pread runs when `coalesce` is set, plus one
/// job per legacy v3 chunk file). Each chunk's recorded hash is
/// verified **inside** its read job, right after the bytes land —
/// precise corruption reports before the caller's stream-digest check,
/// with no extra pass.
///
/// The destination offsets are planned from a *validated* chunk table
/// (it tiles `[0, total_len)` exactly), which is what makes the jobs'
/// concurrent writes into `dest` disjoint. The manifest is re-validated
/// here so a caller holding a hand-built table gets an error, not
/// overlapping writes.
pub(crate) fn plan_delta_reads(
    dir: &Path,
    manifest: &CheckpointManifest,
    dest: &Arc<StreamBuffer>,
    coalesce: bool,
) -> Result<Vec<ReadJob>> {
    let delta = manifest
        .delta
        .as_ref()
        .ok_or_else(|| Error::Internal("plan_delta_reads on a full manifest".into()))?;
    manifest.validate()?;
    #[derive(Default)]
    struct SegJobAcc {
        path: PathBuf,
        parts: Vec<(ReadPart, ChunkCheck)>,
        decodes: Vec<DecodeSpec>,
        dec_checks: Vec<ChunkCheck>,
    }
    let mut seg_jobs: BTreeMap<(String, u32), SegJobAcc> = BTreeMap::new();
    let mut jobs: Vec<ReadJob> = Vec::new();
    let mut pos = 0u64;
    for (i, c) in delta.chunks.iter().enumerate() {
        match c.seg {
            Some(r) => {
                let key = (c.source.clone().unwrap_or_default(), r.seg);
                let acc = seg_jobs.entry(key).or_insert_with(|| SegJobAcc {
                    path: segment_path(dir, c, r),
                    ..SegJobAcc::default()
                });
                let check = ChunkCheck { index: i, dest_off: pos, len: c.len, hash: c.hash };
                if c.codec == CodecKind::None {
                    acc.parts
                        .push((ReadPart { file_off: r.offset, dest_off: pos, len: c.len }, check));
                } else {
                    // Encoded chunk: decoded inside the read job, then
                    // hash-verified by the same folded raw-hash check
                    // as an uncoded chunk. A qdelta base always reads
                    // from its own (possibly different) segment file
                    // via a plain side pread.
                    acc.decodes.push(DecodeSpec {
                        index: i,
                        file_off: r.offset,
                        enc_len: c.enc_len,
                        dest_off: pos,
                        raw_len: c.len,
                        codec: c.codec,
                        base: c.base.as_ref().map(|b| DecodeBase {
                            path: base_segment_path(dir, b),
                            file_off: b.seg.offset,
                            len: b.len,
                        }),
                    });
                    acc.dec_checks.push(check);
                }
            }
            None => jobs.push(ReadJob {
                path: chunk_path(dir, i, c),
                dest: Arc::clone(dest),
                runs: vec![ReadPart { file_off: 0, dest_off: pos, len: c.len }],
                decodes: Vec::new(),
                checks: vec![ChunkCheck { index: i, dest_off: pos, len: c.len, hash: c.hash }],
                coalesced: 0,
                expect_file_len: Some(c.len),
                prefix_check: None,
                kind: None,
                label: "chunk",
            }),
        }
        pos += c.len;
    }
    for acc in seg_jobs.into_values() {
        let n_parts = acc.parts.len();
        let (ranges, mut checks): (Vec<ReadPart>, Vec<ChunkCheck>) =
            acc.parts.into_iter().unzip();
        checks.extend(acc.dec_checks);
        let runs = plan_runs(ranges, coalesce);
        jobs.push(ReadJob {
            path: acc.path,
            dest: Arc::clone(dest),
            coalesced: (n_parts - runs.len()) as u64,
            runs,
            decodes: acc.decodes,
            checks,
            expect_file_len: None, // segments outlive any one checkpoint's view
            prefix_check: Some(PrefixCheck { len: 8, check: check_segment_header }),
            kind: None,
            label: "segment",
        });
    }
    Ok(jobs)
}

/// Reassemble the logical stream of the delta checkpoint at `dir`
/// through `runtime`'s reader pool: coalesced segment reads into one
/// single-copy stream buffer, chunk hashes verified inside the read
/// pass. The full restore path
/// ([`crate::checkpoint::load::load_checkpoint`]) uses the same
/// per-segment planner and additionally keeps the
/// [`crate::io::ReadStats`].
pub fn assemble_delta_stream(
    dir: &Path,
    manifest: &CheckpointManifest,
    runtime: &IoRuntime,
) -> Result<Vec<u8>> {
    let dest = runtime.alloc_stream(manifest.total_len as usize);
    let jobs = plan_delta_reads(dir, manifest, &dest, true)?;
    let stats = crate::io::read::run_jobs(runtime, jobs)?;
    if stats.bytes != manifest.total_len {
        return Err(Error::Format(format!(
            "assembled {} bytes, manifest says {}",
            stats.bytes, manifest.total_len
        )));
    }
    StreamBuffer::into_vec(dest)
}

/// What [`prune_chain`] did.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PruneStats {
    /// Checkpoint directories removed outright.
    pub removed_dirs: usize,
    /// Directories demoted to chunk stores (manifest dropped, live
    /// chunks retained because newer checkpoints reference them).
    pub demoted_dirs: usize,
    /// Dead legacy (v3) chunk files deleted from demoted directories.
    pub removed_chunks: usize,
    /// Segment files deleted from demoted directories (no kept manifest
    /// references any chunk in them).
    pub removed_segments: usize,
    /// Segment files sparsely rewritten because live-byte occupancy
    /// fell below [`GcPolicy::occupancy`] (chunk offsets preserved).
    pub rewritten_segments: usize,
    /// Dead payload bytes reclaimed from removed + rewritten segments.
    pub reclaimed_bytes: u64,
}

/// Chain-aware pruning + garbage collection with the default
/// [`GcPolicy`]. See [`prune_chain_with`].
pub fn prune_chain(
    parent: &Path,
    keep_last: usize,
    devices: &DeviceMap,
    protect: Option<u64>,
) -> Result<PruneStats> {
    prune_chain_with(parent, keep_last, devices, protect, GcPolicy::default())
}

/// Chain-aware pruning + garbage collection for a directory of
/// `step-NNNNNNNN` checkpoints (the trainer layout).
///
/// Keeps the newest `keep_last` *complete* checkpoints (manifest
/// present) loadable. Older directories are:
///
/// * **removed** entirely (including device-side partition/segment
///   dirs) when no kept checkpoint references their chunks;
/// * **demoted** to chunk stores when kept deltas still reference some
///   of their chunks: the manifest is deleted (the checkpoint is no
///   longer loadable or resumable) and GC runs **segment-granular**
///   with live-bytes accounting — segment files with no live chunks are
///   deleted, segments whose live occupancy is below
///   [`GcPolicy::occupancy`] are sparsely rewritten (live ranges copied
///   to identical offsets, dead ranges become holes, atomic rename), so
///   every surviving chunk's recorded `(segment, offset)` stays valid.
///   Legacy (v3) per-chunk files are still reclaimed file-by-file.
///
/// Directories newer than the newest kept manifest (e.g. an in-flight
/// pipelined write that has not published its manifest yet) are never
/// touched, and neither is the step named by `protect` — pass the step
/// just written so a run that reuses a directory containing *stale
/// higher-numbered* checkpoints can never prune its own newest work
/// (the trainer always does). `keep_last == 0` (keep everything) is a
/// no-op.
///
/// Kept manifests are parsed through the process-wide LRU
/// (`CheckpointManifest::load_cached`), so a steady-state prune on the
/// training hot path re-parses nothing.
pub fn prune_chain_with(
    parent: &Path,
    keep_last: usize,
    devices: &DeviceMap,
    protect: Option<u64>,
    policy: GcPolicy,
) -> Result<PruneStats> {
    prune_chain_injected(parent, keep_last, devices, protect, policy, None)
}

/// [`prune_chain_with`] with a fault-injection hook on the segment-GC
/// copy loop ([`crate::io::fault::FaultSite::GcCopy`] — one boundary per
/// coalesced copy run of a sparse rewrite). An injected crash mid-copy
/// surfaces [`crate::Error::FaultTripped`] and leaves the half-built
/// `.fpseg.gc` temp in place (as a real crash would); the original
/// segment is untouched — the rename never happened — and the next
/// prune's orphan sweep reclaims the temp before retrying.
pub fn prune_chain_injected(
    parent: &Path,
    keep_last: usize,
    devices: &DeviceMap,
    protect: Option<u64>,
    policy: GcPolicy,
    fault: Option<&crate::io::fault::FaultPlan>,
) -> Result<PruneStats> {
    let mut stats = PruneStats::default();
    if keep_last == 0 {
        return Ok(stats);
    }
    // All step dirs. Manifests are parsed *lazily* (kept checkpoints
    // only) and through the LRU cache: a steady-state prune costs at
    // most `keep_last + 1` cache probes, and nothing at all while fewer
    // than keep_last checkpoints exist.
    let mut dirs: Vec<(u64, PathBuf, bool)> = Vec::new();
    let Ok(entries) = std::fs::read_dir(parent) else { return Ok(stats) };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if let Some(step) = name.strip_prefix("step-").and_then(|s| s.parse::<u64>().ok()) {
            let has_manifest = path.join(MANIFEST_FILE).exists();
            dirs.push((step, path, has_manifest));
        }
    }
    dirs.sort_by_key(|(step, _, _)| *step);
    let complete = dirs.iter().filter(|(_, _, m)| *m).count();
    if complete <= keep_last {
        return Ok(stats);
    }
    // The newest `keep_last` complete checkpoints stay loadable, plus
    // the protected (just-written) one whatever its step number.
    // Unparseable manifests are treated as incomplete (skipped here,
    // reclaimed below like any other unreferenced old directory).
    let mut kept: BTreeMap<u64, Arc<CheckpointManifest>> = BTreeMap::new();
    for (step, path, has_manifest) in dirs.iter().rev() {
        if kept.len() >= keep_last {
            break;
        }
        if *has_manifest {
            if let Ok(m) = CheckpointManifest::load_cached(path) {
                kept.insert(*step, m);
            }
        }
    }
    if let Some(p) = protect {
        if !kept.contains_key(&p) {
            if let Some((_, path, _)) = dirs.iter().find(|(s, _, h)| *s == p && *h) {
                if let Ok(m) = CheckpointManifest::load_cached(path) {
                    kept.insert(p, m);
                }
            }
        }
    }
    let Some(max_kept) = kept.keys().next_back().copied() else { return Ok(stats) };
    // Live-byte accounting from kept manifests, per owner directory:
    // legacy chunk-file names, and per-segment live ranges.
    let mut live: BTreeMap<String, std::collections::BTreeSet<String>> = BTreeMap::new();
    let mut live_segs: BTreeMap<String, BTreeMap<u32, SegmentLive>> = BTreeMap::new();
    let mut required: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    for (step, path, _) in &dirs {
        let Some(m) = kept.get(step) else { continue };
        let own = dir_name_of(path)?;
        if let Some(delta) = m.delta.as_ref() {
            for (i, c) in delta.chunks.iter().enumerate() {
                let owner = c.source.clone().unwrap_or_else(|| own.clone());
                if c.source.is_some() {
                    required.insert(owner.clone());
                }
                match c.seg {
                    Some(r) => {
                        let seg = live_segs
                            .entry(owner)
                            .or_default()
                            .entry(r.seg)
                            .or_default();
                        // several kept manifests may inherit the same
                        // chunk; count each live range once. Encoded
                        // chunks occupy their *stored* (encoded)
                        // extent, not their raw length.
                        if seg.ranges.insert((r.offset, c.stored_len())) {
                            seg.bytes += c.stored_len();
                        }
                    }
                    None => {
                        live.entry(owner).or_default().insert(DeltaSection::chunk_file(i));
                    }
                }
                // A qdelta chunk's raw base extent must outlive GC too:
                // decoding reads those bytes from wherever they live.
                if let Some(b) = &c.base {
                    let bowner = match &b.source {
                        Some(s) => {
                            required.insert(s.clone());
                            s.clone()
                        }
                        None => own.clone(),
                    };
                    let seg = live_segs
                        .entry(bowner)
                        .or_default()
                        .entry(b.seg.seg)
                        .or_default();
                    if seg.ranges.insert((b.seg.offset, b.len)) {
                        seg.bytes += b.len;
                    }
                }
            }
        }
    }
    for (step, path, _) in &dirs {
        if kept.contains_key(step) || *step > max_kept || Some(*step) == protect {
            continue; // kept, protected, or possibly still being written
        }
        let name = dir_name_of(path)?;
        // Whether demoted or removed, this checkpoint's manifest is
        // gone — drop its parsed chunk table from the LRU too.
        crate::checkpoint::manifest::evict_cached(path);
        if required.contains(&name) {
            // Demote: no longer loadable, but its live chunks feed
            // newer deltas. Reclaim the dead ones everywhere.
            let _ = std::fs::remove_file(path.join(MANIFEST_FILE));
            let live_here = live.get(&name);
            let segs_here = live_segs.get(&name);
            stats.removed_chunks += gc_chunk_files(path, live_here);
            gc_segments(path, segs_here, policy, fault, &mut stats)?;
            for root in devices.roots() {
                let dev_dir = DeviceMap::resolve_in(root, path);
                stats.removed_chunks += gc_chunk_files(&dev_dir, live_here);
                gc_segments(&dev_dir, segs_here, policy, fault, &mut stats)?;
            }
            stats.demoted_dirs += 1;
        } else {
            // drop cached segment images before the files go away (the
            // tag must be computed while the dir still canonicalizes)
            crate::checkpoint::serve::invalidate_checkpoint(path);
            devices.remove_checkpoint(path);
            let _ = std::fs::remove_dir_all(path);
            stats.removed_dirs += 1;
        }
    }
    Ok(stats)
}

/// Live ranges of one segment file, from kept manifests.
#[derive(Default)]
struct SegmentLive {
    /// `(file offset, length)` of each live chunk, deduplicated (the
    /// same chunk may be inherited by several kept manifests).
    ranges: std::collections::BTreeSet<(u64, u64)>,
    /// Total live payload bytes (each range counted once).
    bytes: u64,
}

/// Delete `chunk-*.fpck` files in `dir` that are not in `live`
/// (legacy v3 chunk stores).
fn gc_chunk_files(
    dir: &Path,
    live: Option<&std::collections::BTreeSet<String>>,
) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    let mut removed = 0;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let dead = name.starts_with("chunk-")
            && name.ends_with(".fpck")
            && live.map_or(true, |set| !set.contains(name));
        if dead && std::fs::remove_file(entry.path()).is_ok() {
            removed += 1;
        }
    }
    removed
}

/// Segment-granular GC over `seg-*.fpseg` files in `dir`: delete fully
/// dead segments, sparsely rewrite under-occupied ones (live chunk
/// offsets preserved).
fn gc_segments(
    dir: &Path,
    live: Option<&BTreeMap<u32, SegmentLive>>,
    policy: GcPolicy,
    fault: Option<&crate::io::fault::FaultPlan>,
    stats: &mut PruneStats,
) -> Result<()> {
    let Ok(entries) = std::fs::read_dir(dir) else { return Ok(()) };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        // A crash mid-rewrite can orphan a temp copy; it is never
        // referenced (renames are atomic), so reclaim it here.
        if name.starts_with("seg-") && name.ends_with(".fpseg.gc") {
            let _ = std::fs::remove_file(entry.path());
            continue;
        }
        let Some(idx) = name
            .strip_prefix("seg-")
            .and_then(|s| s.strip_suffix(".fpseg"))
            .and_then(|s| s.parse::<u32>().ok())
        else {
            continue;
        };
        let path = entry.path();
        // Occupancy is measured against *allocated* payload bytes
        // (st_blocks), not the apparent size: a sparse rewrite keeps the
        // apparent size (offsets must not move) but frees dead blocks,
        // so an already-compacted segment reads as (nearly) fully
        // occupied on the next prune.
        let (apparent, allocated) = entry
            .metadata()
            .map(|m| {
                use std::os::unix::fs::MetadataExt;
                (m.len(), (m.blocks() * 512).min(m.len()))
            })
            .unwrap_or((0, 0));
        let payload = allocated.saturating_sub(SEGMENT_HEADER_LEN as u64);
        match live.and_then(|m| m.get(&idx)) {
            None => {
                if std::fs::remove_file(&path).is_ok() {
                    crate::checkpoint::serve::invalidate_path(&path);
                    stats.removed_segments += 1;
                    stats.reclaimed_bytes += payload;
                }
            }
            Some(l) => {
                let occupancy =
                    if payload == 0 { 1.0 } else { (l.bytes as f64 / payload as f64).min(1.0) };
                // Convergence guards: skip unless whole 4 KiB blocks are
                // dead (holes can't be finer), and unless something died
                // since the last rewrite (the header's compacted_live
                // latch — filesystem-independent, so the rewrite never
                // repeats every prune even where hole granularity is
                // coarser than 4 KiB).
                let reclaimable = dead_block_bytes(&l.ranges, apparent) > 0;
                let latched = segment_compacted_live(&path) == Some(l.bytes);
                if occupancy < policy.occupancy && reclaimable && !latched {
                    match rewrite_segment_sparse(&path, &l.ranges, l.bytes, fault) {
                        Ok(()) => {
                            stats.rewritten_segments += 1;
                            // account what the rewrite *actually* freed
                            let after = std::fs::metadata(&path)
                                .map(|m| {
                                    use std::os::unix::fs::MetadataExt;
                                    (m.blocks() * 512).min(m.len())
                                })
                                .unwrap_or(allocated);
                            stats.reclaimed_bytes += allocated.saturating_sub(after);
                        }
                        // An injected crash surfaces (the "process" is
                        // dead); ordinary rewrite failures stay best-
                        // effort — the original segment is intact either
                        // way.
                        Err(e @ Error::FaultTripped(_)) => return Err(e),
                        Err(_) => {}
                    }
                }
            }
        }
    }
    Ok(())
}

/// Bytes in whole 4 KiB filesystem blocks of `[0, apparent)` covered by
/// no live range and not by the segment header — the most a sparse
/// rewrite of this segment can actually free (hole punching is
/// block-granular).
fn dead_block_bytes(
    live: &std::collections::BTreeSet<(u64, u64)>,
    apparent: u64,
) -> u64 {
    const BLK: u64 = 4096;
    let full_blocks = |start: u64, end: u64| -> u64 {
        let a = start.next_multiple_of(BLK);
        let b = end / BLK * BLK;
        if b > a { b - a } else { 0 }
    };
    let mut dead = 0u64;
    let mut cursor = SEGMENT_HEADER_LEN as u64;
    for &(off, len) in live.iter() {
        if off > cursor {
            dead += full_blocks(cursor, off);
        }
        cursor = cursor.max(off + len);
    }
    if apparent > cursor {
        dead += full_blocks(cursor, apparent);
    }
    dead
}

/// Rewrite a segment file keeping only `live` `(offset, len)` ranges
/// (sorted, deduplicated), each at its **original** offset; dead ranges
/// become filesystem holes (sparse file). The apparent size is
/// unchanged and the rewrite is atomic (temp file + rename), so
/// concurrent readers and recorded manifest offsets stay valid
/// throughout.
/// The `compacted_live` latch recorded by the last sparse rewrite of
/// the segment at `path` (None on read failure or a pre-latch file).
fn segment_compacted_live(path: &Path) -> Option<u64> {
    let file = std::fs::File::open(path).ok()?;
    let mut buf = [0u8; 8];
    file.read_exact_at(&mut buf, SEGMENT_COMPACTED_OFFSET as u64).ok()?;
    match u64::from_le_bytes(buf) {
        0 => None,
        v => Some(v),
    }
}

fn rewrite_segment_sparse(
    path: &Path,
    live: &std::collections::BTreeSet<(u64, u64)>,
    live_bytes: u64,
    fault: Option<&crate::io::fault::FaultPlan>,
) -> Result<()> {
    let tmp = path.with_extension("fpseg.gc");
    let result = (|| -> Result<()> {
        let src = std::fs::File::open(path)?;
        let total = src.metadata()?.len();
        let dst = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&tmp)?;
        // The segment header is always live; stamp the compacted_live
        // latch so the next prune knows this layout is already compact.
        let hdr_len = (SEGMENT_HEADER_LEN as u64).min(total) as usize;
        let mut hdr = vec![0u8; hdr_len];
        src.read_exact_at(&mut hdr, 0)?;
        if hdr_len >= SEGMENT_COMPACTED_OFFSET + 8 {
            hdr[SEGMENT_COMPACTED_OFFSET..SEGMENT_COMPACTED_OFFSET + 8]
                .copy_from_slice(&live_bytes.to_le_bytes());
        }
        dst.write_all_at(&hdr, 0)?;
        // Byte-adjacent live chunks coalesce into single read+write
        // runs (same planner as the restore path; copies are in-place,
        // so file offset == destination offset).
        let runs = plan_runs(
            live.iter()
                .map(|&(off, len)| ReadPart { file_off: off, dest_off: off, len })
                .collect(),
            true,
        );
        let mut buf = vec![0u8; 1 << 20];
        for run in runs {
            // GcCopy op boundary: one coalesced copy run is about to
            // land in the temp file. A torn fault copies only a prefix
            // of the run before the "process dies"; abort dies before
            // copying anything.
            let torn = match fault {
                Some(f) => {
                    f.on_gc_copy()? == crate::io::fault::DrainDecision::Torn
                }
                None => false,
            };
            let limit = if torn { run.len / 2 } else { run.len };
            let mut done = 0u64;
            while done < limit {
                let n = (buf.len() as u64).min(limit - done) as usize;
                src.read_exact_at(&mut buf[..n], run.file_off + done)?;
                dst.write_all_at(&buf[..n], run.file_off + done)?;
                done += n as u64;
            }
            if torn {
                return Err(fault.expect("torn implies a plan").error(
                    crate::io::fault::FaultSite::GcCopy,
                ));
            }
        }
        dst.set_len(total)?;
        // The original segment was written durably; the replacement must
        // be too *before* it takes the original's place, or a crash
        // after the rename could lose live chunks that kept checkpoints
        // reference.
        dst.sync_data()?;
        std::fs::rename(&tmp, path)?;
        // the compacted file replaced the original in place: any cached
        // image of the old layout is now stale
        crate::checkpoint::serve::invalidate_path(path);
        Ok(())
    })();
    match &result {
        // A simulated crash leaves the half-built temp behind — that is
        // the orphan the next prune's sweep must reclaim.
        Err(Error::FaultTripped(_)) => {}
        Err(_) => {
            // don't leave a dead copy of the live bytes behind
            // (gc_segments also sweeps stale *.fpseg.gc orphans from
            // crashes)
            let _ = std::fs::remove_file(&tmp);
        }
        Ok(()) => {}
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::load::load_checkpoint;
    use crate::io::engine::{scratch_dir, IoConfig};
    use crate::io::runtime::IoRuntimeConfig;
    use crate::tensor::{DType, Tensor};
    use crate::util::rng::Rng;

    const CS: u64 = 4096;

    fn runtime() -> Arc<IoRuntime> {
        Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            ..IoRuntimeConfig::default()
        }))
    }

    fn cfg(max_chain: u64) -> DeltaConfig {
        DeltaConfig { chunk_size: CS, max_chain, ..DeltaConfig::default() }
    }

    fn ckpt(runtime: Arc<IoRuntime>, max_chain: u64) -> DeltaCheckpointer {
        DeltaCheckpointer::new(runtime, cfg(max_chain))
    }

    fn store(seed: u64, nbytes: usize) -> TensorStore {
        let mut rng = Rng::new(seed);
        let mut s = TensorStore::new();
        let mut data = vec![0u8; nbytes];
        rng.fill_bytes(&mut data);
        s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
        s
    }

    /// Mutate `frac` of the tensor, contiguous, starting mid-way.
    fn mutate(s: &mut TensorStore, frac: f64, tag: u8) {
        let t = s.get("w").unwrap();
        let mut data = t.data.as_slice().to_vec();
        let n = (data.len() as f64 * frac) as usize;
        let start = data.len() / 3;
        for b in &mut data[start..start + n] {
            *b ^= tag | 1;
        }
        s.update("w", data).unwrap();
    }

    fn extra(step: i64) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("step".to_string(), Json::Int(step));
        m
    }

    fn seg_files(dir: &Path) -> usize {
        std::fs::read_dir(dir)
            .map(|rd| {
                rd.flatten()
                    .filter(|e| {
                        let n = e.file_name();
                        let n = n.to_string_lossy().into_owned();
                        n.starts_with("seg-") && n.ends_with(".fpseg")
                    })
                    .count()
            })
            .unwrap_or(0)
    }

    #[test]
    fn segment_header_roundtrip_and_rejection() {
        let h = encode_segment_header(3, 17, 123456);
        assert_eq!(h.len(), SEGMENT_HEADER_LEN);
        check_segment_header(&h).unwrap();
        let mut bad = h.clone();
        bad[0] = b'X';
        assert!(check_segment_header(&bad).is_err());
        let mut bad = h.clone();
        bad[4] = 99;
        assert!(check_segment_header(&bad).is_err());
        assert!(check_segment_header(&h[..4]).is_err());
    }

    #[test]
    fn base_then_delta_reloads_bit_identically() {
        let dir = scratch_dir("delta-chain").unwrap();
        let rt = runtime();
        let mut ck = ckpt(rt, 8);
        let mut s = store(7, 40 * CS as usize);
        let base = ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        assert!(base.is_base);
        assert_eq!(base.written_bytes, base.total_bytes);
        // the base's many chunks coalesce into few segment WriteJobs
        assert!(base.chunks_total > 40);
        assert!(base.segments_written <= 2, "segments = {}", base.segments_written);
        assert_eq!(base.stats.len(), base.segments_written);

        mutate(&mut s, 0.04, 0x10);
        let d1 = ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
        assert!(!d1.is_base);
        assert!(
            d1.written_bytes * 5 < d1.total_bytes,
            "4% mutation must write a small fraction ({} of {})",
            d1.written_bytes,
            d1.total_bytes
        );
        let snap2 = s.snapshot();

        mutate(&mut s, 0.02, 0x20);
        let d2 = ck.write(&s, extra(3), &dir.join("step-00000003")).unwrap();
        assert!(!d2.is_base);
        assert_eq!(d2.manifest.delta.as_ref().unwrap().chain_len, 2);

        // every link of the chain loads bit-identically
        let (l1, h1, m1) = load_checkpoint(&dir.join("step-00000002"), ck.runtime()).unwrap();
        assert!(l1.content_eq(&snap2));
        assert_eq!(h1.extra["step"], Json::Int(2));
        assert!(m1.is_delta());
        let (l2, _, _) = load_checkpoint(&dir.join("step-00000003"), ck.runtime()).unwrap();
        assert!(l2.content_eq(&s));
        let (l0, _, _) = load_checkpoint(&dir.join("step-00000001"), ck.runtime()).unwrap();
        assert!(l0.content_eq(&store(7, 40 * CS as usize)));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn base_of_n_chunks_issues_bounded_jobs_and_fsyncs() {
        // The coalescing acceptance test: a DURABLE base of N chunks
        // over D devices issues one WriteJob + one fsync per segment —
        // bounded by D * segments-per-device — not one per chunk.
        let base = scratch_dir("delta-fsync").unwrap();
        const D: usize = 2;
        let devices = DeviceMap::simulated(D, &base.join("devices")).unwrap();
        let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
            // durable: fsync on finish (tmpfs-friendly; O_DIRECT falls
            // back to aligned pwrite where unsupported)
            io: IoConfig::fastpersist(),
            devices: devices.clone(),
            ..IoRuntimeConfig::default()
        }));
        // small segments force several per device
        let mut ck = DeltaCheckpointer::new(
            rt,
            DeltaConfig { chunk_size: CS, max_chain: 8, segment_bytes: 32 * CS, ..cfg(8) },
        );
        let n_chunks = 64usize;
        let s = store(31, n_chunks * CS as usize);
        let out = ck.write(&s, extra(1), &base.join("ckpts").join("step-00000001")).unwrap();
        assert!(out.is_base);
        assert_eq!(out.chunks_total, n_chunks + 1, "data chunks + header chunk");

        // expected ceiling: ceil(bytes / segment_bytes) rounded up to a
        // multiple of D, far below one-per-chunk
        let by_size = out.written_bytes.div_ceil(32 * CS) as usize;
        let max_segments = by_size.max(D);
        let segments_per_device = max_segments.div_ceil(D);
        assert!(out.segments_written <= D * segments_per_device);
        assert!(out.segments_written < n_chunks / 8, "must coalesce, not one job per chunk");
        assert_eq!(out.stats.len(), out.segments_written, "one WriteJob per segment");
        assert_eq!(
            out.fsyncs, out.segments_written as u64,
            "durable base must fsync once per segment, not per chunk"
        );
        // on disk: only segment files, no per-chunk files, striped over
        // both devices
        let ckdir = base.join("ckpts").join("step-00000001");
        assert_eq!(seg_files(&ckdir), 0, "multi-device layout keeps the ckpt dir clean");
        let on_devices: usize = devices
            .roots()
            .iter()
            .map(|r| seg_files(&DeviceMap::resolve_in(r, &ckdir)))
            .sum();
        assert_eq!(on_devices, out.segments_written);
        let (loaded, _, _) = load_checkpoint(&ckdir, ck.runtime()).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn adjacent_chunks_restore_with_one_pread_per_contiguous_run() {
        use crate::checkpoint::load::{load_checkpoint_with, RestoreOptions};
        // Acceptance: a v4 checkpoint whose chunks sit byte-adjacent in
        // one segment restores with ONE pread per contiguous run —
        // counter-verified via ReadStats — while the naive plan pays
        // one pread per chunk.
        let dir = scratch_dir("delta-coalesce").unwrap();
        let rt = runtime();
        let mut ck = ckpt(Arc::clone(&rt), 8);
        let n_chunks = 32usize;
        let s = store(17, n_chunks * CS as usize);
        let base = ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        assert!(base.is_base);

        let coalesced =
            load_checkpoint_with(&dir.join("step-00000001"), &rt, RestoreOptions::default())
                .unwrap();
        assert!(coalesced.store.content_eq(&s));
        assert_eq!(coalesced.stats.jobs as usize, base.segments_written);
        // data chunks pack adjacently (header chunk last): per segment,
        // at most two runs (data run + header run), each one pread
        assert_eq!(coalesced.stats.preads, coalesced.stats.runs, "one pread per run");
        assert!(
            coalesced.stats.runs <= 2 * base.segments_written as u64,
            "adjacent chunks must merge: {} runs over {} segments",
            coalesced.stats.runs,
            base.segments_written
        );
        assert_eq!(
            coalesced.stats.coalesced + coalesced.stats.runs,
            base.chunks_total as u64,
            "every chunk is either a run head or merged into one"
        );
        assert_eq!(coalesced.stats.chunks_verified, base.chunks_total as u64);

        // the naive plan reads chunk by chunk
        let naive = load_checkpoint_with(
            &dir.join("step-00000001"),
            &rt,
            RestoreOptions { coalesce: false },
        )
        .unwrap();
        assert!(naive.store.content_eq(&s));
        assert_eq!(naive.stats.coalesced, 0);
        assert_eq!(naive.stats.preads, base.chunks_total as u64, "naive = one pread per chunk");
        assert!(coalesced.stats.preads < naive.stats.preads);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unchanged_state_writes_zero_chunks() {
        let dir = scratch_dir("delta-zero").unwrap();
        let rt = runtime();
        let mut ck = ckpt(rt, 8);
        let s = store(3, 10 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        // same state, same extras -> identical stream -> nothing dirty
        let d = ck.write(&s, extra(1), &dir.join("step-00000002")).unwrap();
        assert_eq!(d.chunks_written, 0);
        assert_eq!(d.written_bytes, 0);
        assert_eq!(d.segments_written, 0);
        assert_eq!(d.fsyncs, 0);
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), ck.runtime()).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn chain_compacts_after_max_chain() {
        let dir = scratch_dir("delta-compact").unwrap();
        let rt = runtime();
        let mut ck = ckpt(rt, 2);
        let mut s = store(9, 8 * CS as usize);
        for step in 1..=5u64 {
            let out =
                ck.write(&s, extra(step as i64), &dir.join(format!("step-{step:08}"))).unwrap();
            // chain: base(1), d(2), d(3), base(4), d(5)
            let expect_base = step == 1 || step == 4;
            assert_eq!(out.is_base, expect_base, "step {step}");
            mutate(&mut s, 0.1, step as u8);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_from_manifest_continues_chain() {
        let dir = scratch_dir("delta-resume").unwrap();
        let rt = runtime();
        let mut ck = ckpt(Arc::clone(&rt), 8);
        let mut s = store(11, 12 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        mutate(&mut s, 0.05, 1);
        ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();

        // "restart": a fresh writer resumes from the on-disk manifest
        let mut ck2 = ckpt(rt, 8);
        assert!(ck2.resume_from(&dir.join("step-00000002")).unwrap());
        assert_eq!(ck2.chain_len(), Some(1));
        mutate(&mut s, 0.05, 2);
        let d = ck2.write(&s, extra(3), &dir.join("step-00000003")).unwrap();
        assert!(!d.is_base, "resumed writer must continue the chain");
        assert!(d.written_bytes < d.total_bytes / 2);
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000003"), ck.runtime()).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_demotes_required_dirs_and_rewrites_underoccupied_segments() {
        let dir = scratch_dir("delta-prune").unwrap();
        let devices = DeviceMap::single();
        // durable runtime: fsync forces block allocation, so the
        // GC's st_blocks-based occupancy sees the real layout even on
        // filesystems with delayed allocation
        let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist(),
            ..IoRuntimeConfig::default()
        }));
        let mut ck = ckpt(rt, 8);
        let mut s = store(5, 10 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        mutate(&mut s, 0.30, 1); // dirties several chunks
        ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();

        let base_dir = dir.join("step-00000001");
        let seg0 = base_dir.join(DeltaSection::segment_file(0));
        let size_before = std::fs::metadata(&seg0).unwrap().len();

        // occupancy 1.0: any dead chunk triggers the sparse rewrite
        let stats = prune_chain_with(&dir, 1, &devices, Some(2), GcPolicy { occupancy: 1.0 })
            .unwrap();
        assert_eq!(stats.removed_dirs, 0);
        assert_eq!(stats.demoted_dirs, 1, "base still referenced -> demoted, not removed");
        assert_eq!(stats.rewritten_segments, 1, "under-occupied segment must be rewritten");
        assert!(stats.reclaimed_bytes > 0, "chunks rewritten by the delta are dead in the base");
        assert!(!base_dir.join(MANIFEST_FILE).exists(), "demoted dir loses its manifest");
        // rewrite preserves the apparent size (offsets must stay valid)
        assert_eq!(std::fs::metadata(&seg0).unwrap().len(), size_before);

        // the kept delta still reloads bit-identically from the
        // rewritten store
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), ck.runtime()).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_removes_unreferenced_dirs_after_compaction() {
        let dir = scratch_dir("delta-prune-gc").unwrap();
        let devices = DeviceMap::single();
        let rt = runtime();
        let mut ck = ckpt(rt, 8);
        let mut s = store(6, 6 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        mutate(&mut s, 0.1, 1);
        ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
        // compaction: step 3 is a fresh base, chain references die
        ck.compact_next();
        let out = ck.write(&s, extra(3), &dir.join("step-00000003")).unwrap();
        assert!(out.is_base);

        let stats = prune_chain(&dir, 1, &devices, Some(3)).unwrap();
        assert_eq!(stats.removed_dirs, 2, "pre-compaction chain is unreferenced");
        assert_eq!(stats.demoted_dirs, 0);
        assert!(!dir.join("step-00000001").exists());
        assert!(!dir.join("step-00000002").exists());
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000003"), ck.runtime()).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_never_touches_the_protected_step_even_if_stale_steps_are_newer() {
        // A fresh run reusing a directory that still holds higher-
        // numbered checkpoints from a previous run must not have its
        // just-written checkpoint pruned out from under it.
        let dir = scratch_dir("delta-prune-stale").unwrap();
        let devices = DeviceMap::single();
        let rt = runtime();
        // stale previous run: steps 8 and 9
        let mut old = ckpt(Arc::clone(&rt), 8);
        let s_old = store(21, 6 * CS as usize);
        old.write(&s_old, extra(8), &dir.join("step-00000008")).unwrap();
        old.write(&s_old, extra(9), &dir.join("step-00000009")).unwrap();
        // fresh run writes step 1 and prunes with keep_last=1
        let mut fresh = ckpt(rt, 8);
        let s_new = store(22, 6 * CS as usize);
        fresh.write(&s_new, extra(1), &dir.join("step-00000001")).unwrap();
        prune_chain(&dir, 1, &devices, Some(1)).unwrap();
        let (loaded, _, _) =
            load_checkpoint(&dir.join("step-00000001"), fresh.runtime()).unwrap();
        assert!(loaded.content_eq(&s_new), "protected checkpoint must survive pruning");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn strategy_parse_roundtrip() {
        assert_eq!(CheckpointStrategy::parse("full").unwrap(), CheckpointStrategy::Full);
        let CheckpointStrategy::Delta(d) = CheckpointStrategy::parse("delta").unwrap() else {
            panic!("delta parses to Delta");
        };
        assert_eq!(d, DeltaConfig::default());
        let CheckpointStrategy::Delta(d) = CheckpointStrategy::parse("delta4").unwrap() else {
            panic!("delta4 parses to Delta");
        };
        assert_eq!(d.max_chain, 4);
        assert!(CheckpointStrategy::parse("bogus").is_err());
        assert!(CheckpointStrategy::parse("deltaX").is_err());
        assert_eq!(CheckpointStrategy::Delta(DeltaConfig::default()).name(), "delta8");
    }

    #[test]
    fn multi_device_delta_routes_and_reloads() {
        let base = scratch_dir("delta-devmap").unwrap();
        let devices = DeviceMap::simulated(2, &base.join("devices")).unwrap();
        let rt = Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            devices: devices.clone(),
            ..IoRuntimeConfig::default()
        }));
        let mut ck = DeltaCheckpointer::new(rt, cfg(8));
        let mut s = store(13, 9 * CS as usize);
        let dir = base.join("ckpts");
        let out = ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        assert!(out.segments_written >= 2, "a base must stripe over both devices");
        mutate(&mut s, 0.3, 1);
        let d = ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
        assert!(d.manifest.devices().len() >= 2, "chunks must stripe across devices");
        // no segment file lands in the checkpoint dir itself
        assert_eq!(seg_files(&dir.join("step-00000002")), 0);
        let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), ck.runtime()).unwrap();
        assert!(loaded.content_eq(&s));
        std::fs::remove_dir_all(&base).unwrap();
    }

    /// Structured (compressible) payload: long runs + a slow ramp, the
    /// kind of byte texture lz4 actually shrinks.
    fn compressible_store(nbytes: usize) -> TensorStore {
        let mut data = vec![0u8; nbytes];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i / 512) as u8;
        }
        let mut s = TensorStore::new();
        s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
        s
    }

    /// Small-magnitude scatter mutation: add 1 (wrapping) to one byte
    /// every `stride` bytes — dirties many chunks, each with a tiny
    /// diff (what qdelta is built for).
    fn scatter_mutate(s: &mut TensorStore, stride: usize) {
        let t = s.get("w").unwrap();
        let mut data = t.data.as_slice().to_vec();
        let mut i = stride / 2;
        while i < data.len() {
            data[i] = data[i].wrapping_add(1);
            i += stride;
        }
        s.update("w", data).unwrap();
    }

    fn cfg_codec(max_chain: u64, codec: CodecKind) -> DeltaConfig {
        DeltaConfig { codec, ..cfg(max_chain) }
    }

    #[test]
    fn lz4_chain_shrinks_and_reloads_bit_identically() {
        let dir = scratch_dir("delta-lz4").unwrap();
        let rt = runtime();
        let mut ck = DeltaCheckpointer::new(Arc::clone(&rt), cfg_codec(8, CodecKind::Lz4));
        let mut s = compressible_store(24 * CS as usize);
        let base = ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        assert!(base.is_base);
        // lz4 applies on bases too: structured payload must shrink
        assert_eq!(base.bytes_raw, base.total_bytes);
        assert!(
            base.bytes_encoded * 2 < base.bytes_raw,
            "lz4 must halve a structured base ({} of {})",
            base.bytes_encoded,
            base.bytes_raw
        );
        assert_eq!(base.written_bytes, base.bytes_encoded);
        assert!(base.encode > Duration::ZERO);
        let m = base.manifest.delta.as_ref().unwrap();
        assert!(
            m.chunks.iter().any(|c| c.codec == CodecKind::Lz4 && c.enc_len < c.len),
            "some chunk must be stored lz4-encoded"
        );
        let (l0, _, _) = load_checkpoint(&dir.join("step-00000001"), &rt).unwrap();
        assert!(l0.content_eq(&s));

        mutate(&mut s, 0.1, 0x30);
        let d1 = ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
        assert!(!d1.is_base);
        assert!(d1.bytes_raw < d1.total_bytes, "delta writes only dirty chunks");
        let (l1, _, _) = load_checkpoint(&dir.join("step-00000002"), &rt).unwrap();
        assert!(l1.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lz4_restore_is_byte_identical_to_uncompressed_restore() {
        // Bit-identity across codecs: the decoded restore of an lz4
        // checkpoint equals the restore of a codec-less checkpoint of
        // the same state, byte for byte.
        let dir = scratch_dir("delta-codec-eq").unwrap();
        let rt = runtime();
        let s = compressible_store(12 * CS as usize);
        let mut plain = DeltaCheckpointer::new(Arc::clone(&rt), cfg(8));
        let mut coded = DeltaCheckpointer::new(Arc::clone(&rt), cfg_codec(8, CodecKind::Lz4));
        plain.write(&s, extra(1), &dir.join("plain").join("step-00000001")).unwrap();
        coded.write(&s, extra(1), &dir.join("coded").join("step-00000001")).unwrap();
        let mp = CheckpointManifest::load(&dir.join("plain").join("step-00000001")).unwrap();
        let mc = CheckpointManifest::load(&dir.join("coded").join("step-00000001")).unwrap();
        let sp =
            assemble_delta_stream(&dir.join("plain").join("step-00000001"), &mp, &rt).unwrap();
        let sc =
            assemble_delta_stream(&dir.join("coded").join("step-00000001"), &mc, &rt).unwrap();
        assert_eq!(sp, sc, "decoded stream must be byte-identical to the uncompressed one");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn qdelta_chain_reloads_bit_identically_and_compacts_exact() {
        let dir = scratch_dir("delta-qd").unwrap();
        let rt = runtime();
        let mut ck = DeltaCheckpointer::new(Arc::clone(&rt), cfg_codec(3, CodecKind::QuantDelta));
        let mut s = store(23, 16 * CS as usize);
        let base = ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        assert!(base.is_base);
        // a base stores exact raw bytes — qdelta never applies to it
        assert_eq!(base.bytes_encoded, base.bytes_raw);
        assert!(base
            .manifest
            .delta
            .as_ref()
            .unwrap()
            .chunks
            .iter()
            .all(|c| c.codec == CodecKind::None));

        let mut snaps = Vec::new();
        for step in 2..=4i64 {
            scatter_mutate(&mut s, 3 * CS as usize);
            let d = ck
                .write(&s, extra(step), &dir.join(format!("step-0000000{step}")))
                .unwrap();
            assert!(!d.is_base);
            // tiny scattered diffs must crush: quantized runs, not raw
            assert!(
                d.bytes_encoded * 2 < d.bytes_raw,
                "step {step}: qdelta must shrink scattered point mutations ({} of {})",
                d.bytes_encoded,
                d.bytes_raw
            );
            let m = d.manifest.delta.as_ref().unwrap();
            assert!(m
                .chunks
                .iter()
                .filter(|c| c.codec == CodecKind::QuantDelta)
                .all(|c| c.base.is_some() && c.enc_len < c.len));
            snaps.push((step, s.snapshot()));
        }
        // every link decodes bit-identically
        for (step, snap) in &snaps {
            let (l, _, _) =
                load_checkpoint(&dir.join(format!("step-0000000{step}")), &rt).unwrap();
            assert!(l.content_eq(snap), "step {step} must reload bit-identically");
        }
        // chain is full (max_chain = 3): the next write compacts into a
        // fresh base that stores exact raw bytes again
        scatter_mutate(&mut s, 3 * CS as usize);
        let compacted = ck.write(&s, extra(5), &dir.join("step-00000005")).unwrap();
        assert!(compacted.is_base, "chain at max_chain must compact");
        assert_eq!(compacted.bytes_encoded, compacted.bytes_raw);
        assert!(compacted
            .manifest
            .delta
            .as_ref()
            .unwrap()
            .chunks
            .iter()
            .all(|c| c.codec == CodecKind::None && c.base.is_none()));
        let (l, _, _) = load_checkpoint(&dir.join("step-00000005"), &rt).unwrap();
        assert!(l.content_eq(&s));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn qdelta_base_extents_survive_prune_gc() {
        // A kept manifest's qdelta chunks reference raw base bytes in an
        // OLDER directory; prune must keep those extents alive through
        // demotion + sparse segment rewrite, or decode breaks.
        let dir = scratch_dir("delta-qd-prune").unwrap();
        let rt = runtime();
        let mut ck = DeltaCheckpointer::new(Arc::clone(&rt), cfg_codec(8, CodecKind::QuantDelta));
        let mut s = store(29, 12 * CS as usize);
        ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
        for step in 2..=5i64 {
            scatter_mutate(&mut s, 2 * CS as usize);
            ck.write(&s, extra(step), &dir.join(format!("step-0000000{step}"))).unwrap();
        }
        let stats = prune_chain(&dir, 2, rt.devices(), Some(5)).unwrap();
        assert!(stats.removed_dirs + stats.demoted_dirs > 0, "prune must reclaim something");
        // the base directory holding the raw reference bytes was
        // demoted, not removed
        assert!(!dir.join("step-00000001").join(MANIFEST_FILE).exists());
        assert!(dir.join("step-00000001").exists(), "base extents are still referenced");
        for step in 4..=5 {
            let (l, _, _) =
                load_checkpoint(&dir.join(format!("step-0000000{step}")), &rt).unwrap();
            if step == 5 {
                assert!(l.content_eq(&s), "newest checkpoint must decode after GC");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_dirty_detection_never_misses_changes() {
        crate::prop::forall("delta reload equals live state", 12, |g| {
            let dir = scratch_dir("delta-prop").unwrap();
            let rt = runtime();
            let mut ck = ckpt(rt, 8);
            let nbytes = g.usize(1, 6 * CS as usize);
            let mut s = store(g.u64(0, u64::MAX), nbytes);
            ck.write(&s, extra(1), &dir.join("step-00000001")).unwrap();
            // random point mutations
            let t = s.get("w").unwrap();
            let mut data = t.data.as_slice().to_vec();
            for _ in 0..g.usize(0, 8) {
                let i = g.usize(0, data.len() - 1);
                data[i] ^= 0x5a;
            }
            s.update("w", data).unwrap();
            ck.write(&s, extra(2), &dir.join("step-00000002")).unwrap();
            let (loaded, _, _) = load_checkpoint(&dir.join("step-00000002"), ck.runtime()).unwrap();
            let ok = loaded.content_eq(&s);
            std::fs::remove_dir_all(&dir).unwrap();
            ok
        });
    }
}
