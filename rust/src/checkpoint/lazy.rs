//! Lazy asynchronous checkpointing — the capture/flush split.
//!
//! The eager pipelined path ([`crate::checkpoint::pipeline`]) keeps the
//! paper's strict *O_{i+1} ← C_i* dependency: the trainer blocks at the
//! next step boundary until the previous checkpoint is durable. This
//! module deliberately relaxes that dependency (the DataStates-LLM
//! refinement of FastPersist pillar (iii)): training state is *captured*
//! the instant the optimizer step ends — a bounded memcpy into pooled
//! staging buffers, nothing else on the training thread — and a
//! dedicated flush scheduler drains captured generations to durable
//! storage across the following iterations.
//!
//! ```text
//! trainer thread                     flush scheduler
//! ──────────────                     ───────────────
//! O_i
//! capture(gen i)  ── memcpy ──────►  (queued)
//! F_{i+1}, B_{i+1}, O_{i+1}          reassemble gen i, write via
//! capture(gen i+1) ───────────────►    engine/delta chain, publish
//! F_{i+2} ...                          manifest LAST, recycle buffers
//! ```
//!
//! Each capture is tagged with a monotonically increasing **generation**
//! number; generations flush strictly in order (FIFO channel, single
//! scheduler thread), so the delta chain on the scheduler advances
//! exactly as in the eager path and every published checkpoint keeps the
//! manifest-publish-last commit point.
//!
//! **Backpressure state machine** (per generation):
//!
//! ```text
//! capture ──► staged ──► draining ──► durable
//!    │           (holds staging buffers until durable)
//!    └─ stalls the trainer, measured, when either
//!       (a) max_generations captures are not yet durable, or
//!       (b) the staging budget is exhausted (acquire blocks).
//! ```
//!
//! The price of the relaxed dependency is a bounded durability lag: on a
//! crash, up to [`LazyConfig::max_generations`] of the newest steps may
//! be lost, and recovery lands on the newest *published* generation
//! (crash drill: `tests/lazy_async.rs`). The trainer-side cost is
//! reported honestly as per-step `stall_s` (backpressure + capture
//! memcpy); the overlapped flush work is reported separately as
//! `drain_s` — the two columns `BENCH_fig11.json` compares eager vs
//! lazy.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::checkpoint::delta::DeltaCheckpointer;
use crate::checkpoint::engine::{CheckpointEngine, CheckpointOutcome};
use crate::checkpoint::pipeline::HelperWriter;
use crate::cluster::topology::RankPlacement;
use crate::io::buffer::{AlignedBuf, BufferPool};
use crate::tensor::{DType, Tensor, TensorStore};
use crate::util::json::Json;
use crate::{Error, Result};

/// Tuning knobs for the lazy capture/flush split.
#[derive(Debug, Clone, Copy)]
pub struct LazyConfig {
    /// Staging budget in bytes for captured-but-not-yet-durable state.
    /// Buffers return to the pool only when their generation is durable,
    /// so this bounds the real memory cost of the durability lag.
    pub staging_bytes: u64,
    /// Granularity of the capture staging buffers (one pool buffer).
    pub buf_size: usize,
    /// Maximum generations captured but not yet durable before
    /// [`LazyCheckpointer::capture`] stalls (measured). `1` restores the
    /// eager pipelined durability semantics.
    pub max_generations: usize,
}

impl Default for LazyConfig {
    fn default() -> Self {
        LazyConfig { staging_bytes: 256 << 20, buf_size: 32 << 20, max_generations: 2 }
    }
}

impl LazyConfig {
    fn normalized(mut self) -> LazyConfig {
        self.buf_size = self.buf_size.max(4096);
        self.staging_bytes = self.staging_bytes.max(self.buf_size as u64);
        self.max_generations = self.max_generations.max(1);
        self
    }
}

/// Shape/dtype record for one captured tensor (payload lives in the
/// staging buffers, concatenated in capture order).
struct CapturedTensor {
    name: String,
    dtype: DType,
    shape: Vec<usize>,
    len: usize,
}

/// One generation-tagged snapshot: raw tensor payloads packed into
/// staging buffers plus the metadata to reassemble them.
struct Generation {
    number: u64,
    tensors: Vec<CapturedTensor>,
    bufs: Vec<AlignedBuf>,
    extra: BTreeMap<String, Json>,
    dir: PathBuf,
}

impl Generation {
    /// Rebuild the captured [`TensorStore`] from the packed buffers.
    fn reassemble(&self) -> Result<TensorStore> {
        let mut store = TensorStore::new();
        let mut buf_idx = 0usize;
        let mut pos = 0usize;
        for t in &self.tensors {
            let mut data = Vec::with_capacity(t.len);
            while data.len() < t.len {
                let buf = self.bufs.get(buf_idx).ok_or_else(|| {
                    Error::Internal(format!(
                        "generation {}: capture layout exhausted at tensor {:?}",
                        self.number, t.name
                    ))
                })?;
                let take = (buf.len - pos).min(t.len - data.len());
                data.extend_from_slice(&buf.filled()[pos..pos + take]);
                pos += take;
                if pos == buf.len {
                    buf_idx += 1;
                    pos = 0;
                }
            }
            store.push(Tensor::new(&t.name, t.dtype, t.shape.clone(), data)?)?;
        }
        Ok(store)
    }
}

/// Trainer-side accounting of one [`LazyCheckpointer::capture`] call.
#[derive(Debug, Clone, Copy)]
pub struct CaptureStats {
    /// Generation number assigned to this snapshot.
    pub generation: u64,
    /// Payload bytes captured.
    pub bytes: u64,
    /// Staging buffers holding the snapshot until it is durable.
    pub buffers: usize,
    /// Time blocked on backpressure (generation cap + staging budget) —
    /// the only way the flush path ever stalls the trainer.
    pub stall: Duration,
    /// Time spent memcpy-ing state into the staging buffers.
    pub copy: Duration,
}

/// One durable lazy generation (scheduler-side accounting).
pub struct LazyOutcome {
    /// Generation number (capture order == publish order).
    pub generation: u64,
    /// The published checkpoint's outcome (manifest, write stats, ...).
    pub outcome: CheckpointOutcome,
    /// Flush-scheduler wall time for this generation (reassembly +
    /// write + publish) — work overlapped with training, not stalled on.
    pub drain: Duration,
}

/// Lazy asynchronous checkpoint executor: generation-tagged capture on
/// the trainer thread, ordered flush on a dedicated scheduler thread,
/// staged backpressure in between.
pub struct LazyCheckpointer {
    cfg: LazyConfig,
    staging: BufferPool,
    req_tx: Option<Sender<Generation>>,
    done_rx: Receiver<Result<LazyOutcome>>,
    helper: Option<JoinHandle<()>>,
    inflight: usize,
    next_generation: u64,
    /// Cumulative time the trainer spent blocked on backpressure (and in
    /// [`LazyCheckpointer::wait_all`]) — the lazy path's measured stall.
    pub stall: Duration,
    /// Outcomes of every durable generation, in generation order.
    pub completed: Vec<LazyOutcome>,
}

impl LazyCheckpointer {
    /// Lazy captures flushed as full parallel checkpoints over a fixed
    /// DP writer `group`.
    pub fn full(
        engine: CheckpointEngine,
        group: Vec<RankPlacement>,
        cfg: LazyConfig,
    ) -> LazyCheckpointer {
        Self::with_writer(HelperWriter::Full { engine, group }, cfg)
    }

    /// Lazy captures flushed as incremental delta checkpoints; the chain
    /// diff state lives on the flush scheduler, and because generations
    /// flush strictly in order the chain advances exactly as it would
    /// eagerly.
    pub fn delta(ckpt: DeltaCheckpointer, cfg: LazyConfig) -> LazyCheckpointer {
        Self::with_writer(HelperWriter::Delta(ckpt), cfg)
    }

    fn with_writer(mut writer: HelperWriter, cfg: LazyConfig) -> LazyCheckpointer {
        let cfg = cfg.normalized();
        let count = (cfg.staging_bytes / cfg.buf_size as u64).max(1) as usize;
        // A dedicated capture pool, separate from the runtime's staging
        // pool: flush-side WriteJobs acquire runtime buffers while a
        // generation still holds its capture buffers, so sharing one
        // pool could deadlock under budget pressure.
        let staging = BufferPool::new(count, cfg.buf_size);
        let (req_tx, req_rx) = mpsc::channel::<Generation>();
        let (done_tx, done_rx) = mpsc::channel();
        let pool = staging.clone();
        let helper = std::thread::Builder::new()
            .name("ckpt-lazy-flush".into())
            .spawn(move || {
                for generation in req_rx {
                    let t0 = Instant::now();
                    let number = generation.number;
                    let result = flush_generation(&mut writer, generation, &pool);
                    let drain = t0.elapsed();
                    let msg = result.map(|outcome| LazyOutcome { generation: number, outcome, drain });
                    if done_tx.send(msg).is_err() {
                        break; // trainer side gone
                    }
                }
            })
            .expect("spawn lazy flush scheduler");
        LazyCheckpointer {
            cfg,
            staging,
            req_tx: Some(req_tx),
            done_rx,
            helper: Some(helper),
            inflight: 0,
            next_generation: 0,
            stall: Duration::ZERO,
            completed: Vec::new(),
        }
    }

    /// Snapshot `store` into staging buffers and queue it for flushing.
    /// Call **after** the optimizer step. The only blocking is staged
    /// backpressure, returned (and accumulated in
    /// [`LazyCheckpointer::stall`]) as [`CaptureStats::stall`].
    pub fn capture(
        &mut self,
        store: &TensorStore,
        extra: BTreeMap<String, Json>,
        dir: PathBuf,
    ) -> Result<CaptureStats> {
        let bytes = store.total_bytes();
        let needed = ((bytes as usize).div_ceil(self.staging.buf_size())).max(1);
        if needed > self.staging.count() {
            return Err(Error::Config(format!(
                "lazy staging budget too small for one generation: {} bytes of state need {} \
                 buffers but the budget holds {} x {} bytes — raise the staging budget or the \
                 buffer size",
                bytes,
                needed,
                self.staging.count(),
                self.staging.buf_size()
            )));
        }
        let mut stall = Duration::ZERO;
        // Backpressure (a): bounded durability lag. Drain completions of
        // the oldest generations until fewer than max_generations are in
        // flight; the wait is the trainer's measured stall.
        while self.inflight >= self.cfg.max_generations {
            let t0 = Instant::now();
            let r = self.recv_one();
            stall += t0.elapsed();
            if let Err(e) = r {
                self.stall += stall;
                return Err(e);
            }
        }
        // Capture: pure memcpy into pooled buffers, packed back to back.
        // Backpressure (b): when every budget buffer is still held by a
        // draining generation, acquire() blocks — also measured stall.
        let mut bufs: Vec<AlignedBuf> = Vec::with_capacity(needed);
        let mut tensors = Vec::with_capacity(store.len());
        let mut copy = Duration::ZERO;
        let mut current: Option<AlignedBuf> = None;
        for t in store.iter() {
            tensors.push(CapturedTensor {
                name: t.name.clone(),
                dtype: t.dtype,
                shape: t.shape.clone(),
                len: t.data.len(),
            });
            let mut src: &[u8] = t.data.as_slice();
            while !src.is_empty() {
                if current.as_ref().map_or(true, |b| b.remaining() == 0) {
                    if let Some(full) = current.take() {
                        bufs.push(full);
                    }
                    let t0 = Instant::now();
                    current = Some(self.staging.acquire());
                    stall += t0.elapsed();
                }
                let buf = current.as_mut().expect("staging buffer just acquired");
                let t0 = Instant::now();
                let n = buf.stage(src);
                copy += t0.elapsed();
                src = &src[n..];
            }
        }
        if let Some(tail) = current.take() {
            bufs.push(tail);
        }
        let generation = self.next_generation;
        self.next_generation += 1;
        let buffers = bufs.len();
        self.req_tx
            .as_ref()
            .expect("lazy checkpointer finished")
            .send(Generation { number: generation, tensors, bufs, extra, dir })
            .map_err(|_| Error::Internal("lazy flush scheduler died".into()))?;
        self.inflight += 1;
        self.stall += stall;
        Ok(CaptureStats { generation, bytes, buffers, stall, copy })
    }

    /// Harvest every already-finished generation without blocking.
    /// Returns how many completed. Call once per training step so
    /// `drain_s` accounting stays current.
    pub fn poll_completed(&mut self) -> Result<usize> {
        let mut n = 0usize;
        loop {
            match self.done_rx.try_recv() {
                Ok(msg) => {
                    self.inflight -= 1;
                    self.completed.push(msg?);
                    n += 1;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if self.inflight > 0 {
                        return Err(Error::Internal("lazy flush scheduler died".into()));
                    }
                    break;
                }
            }
        }
        Ok(n)
    }

    /// Block until every captured generation is durable (end of the
    /// run, or a hard synchronization point). The wait is accumulated
    /// into [`LazyCheckpointer::stall`].
    pub fn wait_all(&mut self) -> Result<()> {
        while self.inflight > 0 {
            let t0 = Instant::now();
            let r = self.recv_one();
            self.stall += t0.elapsed();
            r?;
        }
        Ok(())
    }

    fn recv_one(&mut self) -> Result<()> {
        let msg = self
            .done_rx
            .recv()
            .map_err(|_| Error::Internal("lazy flush scheduler died".into()))?;
        self.inflight -= 1;
        self.completed.push(msg?);
        Ok(())
    }

    /// Generations captured but not yet durable.
    pub fn in_flight(&self) -> usize {
        self.inflight
    }

    /// The capture staging pool (budget introspection).
    pub fn staging(&self) -> &BufferPool {
        &self.staging
    }

    /// The normalized configuration in effect.
    pub fn config(&self) -> &LazyConfig {
        &self.cfg
    }

    /// Drain every outstanding generation and shut the scheduler down;
    /// returns all completed outcomes.
    pub fn finish(mut self) -> Result<Vec<LazyOutcome>> {
        self.wait_all()?;
        drop(self.req_tx.take());
        if let Some(h) = self.helper.take() {
            h.join().map_err(|_| Error::Internal("lazy flush scheduler panicked".into()))?;
        }
        Ok(std::mem::take(&mut self.completed))
    }
}

impl Drop for LazyCheckpointer {
    fn drop(&mut self) {
        drop(self.req_tx.take());
        if let Some(h) = self.helper.take() {
            let _ = h.join();
        }
    }
}

/// Reassemble one generation and write it; staging buffers return to
/// the capture pool only after the write attempt finishes, so the
/// budget honestly bounds captured-but-not-durable bytes.
fn flush_generation(
    writer: &mut HelperWriter,
    generation: Generation,
    pool: &BufferPool,
) -> Result<CheckpointOutcome> {
    let result = generation
        .reassemble()
        .and_then(|snapshot| writer.write(&snapshot, generation.extra, &generation.dir));
    for buf in generation.bufs {
        pool.release(buf);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::delta::{DeltaCheckpointer, DeltaConfig};
    use crate::checkpoint::load::load_checkpoint;
    use crate::checkpoint::strategy::WriterStrategy;
    use crate::io::engine::{scratch_dir, IoConfig};
    use crate::io::runtime::{IoRuntime, IoRuntimeConfig};
    use crate::util::rng::Rng;

    fn solo_group() -> Vec<RankPlacement> {
        vec![RankPlacement { rank: 0, node: 0, socket: 0, local_gpu: 0 }]
    }

    fn small_cfg() -> LazyConfig {
        LazyConfig { staging_bytes: 4 << 20, buf_size: 64 << 10, max_generations: 2 }
    }

    fn store_with(step: u8, nbytes: usize) -> TensorStore {
        let mut s = TensorStore::new();
        let mut data = vec![step; nbytes];
        Rng::new(step as u64).fill_bytes(&mut data[..nbytes / 2]);
        s.push(Tensor::new("w", DType::U8, vec![nbytes], data).unwrap()).unwrap();
        s
    }

    fn extra(step: i64) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("step".into(), Json::Int(step));
        m
    }

    #[test]
    fn every_captured_generation_becomes_durable_in_order() {
        let dir = scratch_dir("lazy-every").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let rt = std::sync::Arc::clone(engine.runtime());
        let mut lazy = LazyCheckpointer::full(engine, solo_group(), small_cfg());
        let iters = 5i64;
        for i in 0..iters {
            let store = store_with(i as u8, 200_000);
            let stats = lazy.capture(&store, extra(i), dir.join(format!("step{i}"))).unwrap();
            assert_eq!(stats.generation, i as u64);
            assert_eq!(stats.bytes, 200_000);
            assert!(lazy.in_flight() <= 2, "generation cap violated");
        }
        let outcomes = lazy.finish().unwrap();
        assert_eq!(outcomes.len(), iters as usize);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.generation, i as u64, "generations must publish in order");
        }
        for i in 0..iters {
            let (loaded, header, _) = load_checkpoint(&dir.join(format!("step{i}")), &rt).unwrap();
            assert_eq!(header.extra["step"], Json::Int(i));
            assert!(loaded.content_eq(&store_with(i as u8, 200_000)));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn capture_isolates_from_subsequent_mutation() {
        // The checkpoint of generation i must contain the state at
        // capture time even though the trainer mutates the live store
        // immediately (the whole point of the memcpy capture).
        let dir = scratch_dir("lazy-iso").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let rt = std::sync::Arc::clone(engine.runtime());
        let mut lazy = LazyCheckpointer::full(engine, solo_group(), small_cfg());
        let mut store = store_with(1, 500_000);
        lazy.capture(&store, extra(1), dir.join("c1")).unwrap();
        store.update("w", vec![99u8; 500_000]).unwrap();
        lazy.wait_all().unwrap();
        let (loaded, _, _) = load_checkpoint(&dir.join("c1"), &rt).unwrap();
        assert!(loaded.content_eq(&store_with(1, 500_000)));
        drop(lazy);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn generation_cap_of_one_restores_eager_semantics() {
        let dir = scratch_dir("lazy-cap1").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let mut lazy = LazyCheckpointer::full(
            engine,
            solo_group(),
            LazyConfig { max_generations: 1, ..small_cfg() },
        );
        for i in 0..4i64 {
            let store = store_with(i as u8, 300_000);
            lazy.capture(&store, extra(i), dir.join(format!("s{i}"))).unwrap();
            assert!(lazy.in_flight() <= 1);
        }
        // With cap 1, the 4th capture must have waited on gen 3's flush.
        assert!(lazy.completed.len() >= 3, "completed={}", lazy.completed.len());
        lazy.wait_all().unwrap();
        assert_eq!(lazy.completed.len(), 4);
        drop(lazy);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn too_small_staging_budget_is_a_config_error_not_a_deadlock() {
        let dir = scratch_dir("lazy-budget").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let mut lazy = LazyCheckpointer::full(
            engine,
            solo_group(),
            LazyConfig { staging_bytes: 8192, buf_size: 4096, max_generations: 2 },
        );
        let store = store_with(0, 100_000); // needs 25 buffers, budget has 2
        let err = lazy.capture(&store, extra(0), dir.join("c")).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "got {err}");
        assert!(err.to_string().contains("staging budget"), "got {err}");
        drop(lazy);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn steady_state_capture_never_allocates_past_the_budget() {
        let dir = scratch_dir("lazy-alloc").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let cfg = LazyConfig { staging_bytes: 512 << 10, buf_size: 64 << 10, max_generations: 2 };
        let mut lazy = LazyCheckpointer::full(engine, solo_group(), cfg);
        for i in 0..10i64 {
            let store = store_with(i as u8, 150_000);
            lazy.capture(&store, extra(i), dir.join(format!("s{i}"))).unwrap();
        }
        lazy.wait_all().unwrap();
        let pool = lazy.staging();
        assert!(
            pool.allocations() <= pool.count() as u64,
            "capture pool must never allocate past its cap ({} > {})",
            pool.allocations(),
            pool.count()
        );
        assert!(pool.acquires() > pool.allocations(), "buffers must be recycled across captures");
        drop(lazy);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn multi_tensor_store_reassembles_across_buffer_boundaries() {
        // Tensors larger and smaller than one staging buffer, packed
        // back to back, must reassemble bit-identically.
        let dir = scratch_dir("lazy-multi").unwrap();
        let engine = CheckpointEngine::fastpersist(WriterStrategy::AllReplicas);
        let rt = std::sync::Arc::clone(engine.runtime());
        let mut lazy = LazyCheckpointer::full(
            engine,
            solo_group(),
            LazyConfig { staging_bytes: 1 << 20, buf_size: 8 << 10, max_generations: 2 },
        );
        let mut store = TensorStore::new();
        let mut rng = Rng::new(7);
        for (i, n) in [3usize, 20_000, 8192, 5, 70_001].iter().enumerate() {
            let mut data = vec![0u8; *n];
            rng.fill_bytes(&mut data);
            store
                .push(Tensor::new(&format!("t{i}"), DType::U8, vec![*n], data).unwrap())
                .unwrap();
        }
        let stats = lazy.capture(&store, extra(0), dir.join("c")).unwrap();
        assert!(stats.buffers > 1, "test must span multiple buffers");
        lazy.wait_all().unwrap();
        let (loaded, _, _) = load_checkpoint(&dir.join("c"), &rt).unwrap();
        assert!(loaded.content_eq(&store));
        drop(lazy);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lazy_delta_chain_matches_eager_chain_content() {
        let dir = scratch_dir("lazy-delta-chain").unwrap();
        let rt = std::sync::Arc::new(IoRuntime::new(IoRuntimeConfig {
            io: IoConfig::fastpersist().microbench(),
            ..IoRuntimeConfig::default()
        }));
        let ckpt = DeltaCheckpointer::new(
            std::sync::Arc::clone(&rt),
            DeltaConfig { chunk_size: 4096, max_chain: 8, ..DeltaConfig::default() },
        );
        let mut lazy = LazyCheckpointer::delta(ckpt, small_cfg());
        for i in 0..4i64 {
            let store = store_with(i as u8, 120_000);
            lazy.capture(&store, extra(i), dir.join(format!("step-{i:08}"))).unwrap();
        }
        let outcomes = lazy.finish().unwrap();
        assert_eq!(outcomes.len(), 4);
        assert!(outcomes[1].outcome.manifest.is_delta());
        assert_eq!(outcomes[1].outcome.manifest.delta.as_ref().unwrap().chain_len, 1);
        for i in 0..4i64 {
            let (loaded, header, _) =
                load_checkpoint(&dir.join(format!("step-{i:08}")), &rt).unwrap();
            assert_eq!(header.extra["step"], Json::Int(i));
            assert!(loaded.content_eq(&store_with(i as u8, 120_000)));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
