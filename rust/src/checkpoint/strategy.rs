//! Writer-subset selection (paper §4.2, "hardware efficiency").
//!
//! Using *all* DP ranks as checkpoint writers can be sub-optimal: tiny
//! per-rank partitions write inefficiently, and many writers per node
//! contend for the shared RAID volume / PCIe. FastPersist therefore
//! supports writing with a subset of the DP ranks — but not an arbitrary
//! subset: the chosen ranks must maximize I/O-hardware coverage (spread
//! over nodes, then over CPU sockets) while minimizing per-device
//! contention. Two ranks on one node while another node sits idle is the
//! pathology the paper calls out (Fig. 6).

use crate::cluster::topology::RankPlacement;
use crate::{Error, Result};

/// How to pick checkpoint writers from a DP group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriterStrategy {
    /// Only the group's first rank writes (the torch.save baseline,
    /// Fig. 6a).
    Rank0,
    /// Every DP replica writes a partition ("Replica" in §5.3.2,
    /// Fig. 6b).
    AllReplicas,
    /// One writer per occupied CPU socket ("Socket" in §5.3.2) — higher
    /// per-writer volume, minimal PCIe/DRAM contention.
    PerSocket,
    /// One writer per occupied node.
    PerNode,
    /// Exactly `n` writers, spread round-robin across nodes then sockets
    /// (Fig. 6c's "subset" with the paper's coverage rule).
    FixedCount(usize),
}

impl WriterStrategy {
    /// Stable CLI/report name.
    pub fn name(self) -> String {
        match self {
            WriterStrategy::Rank0 => "rank0".into(),
            WriterStrategy::AllReplicas => "replica".into(),
            WriterStrategy::PerSocket => "socket".into(),
            WriterStrategy::PerNode => "node".into(),
            WriterStrategy::FixedCount(n) => format!("fixed{n}"),
        }
    }

    /// Parse a CLI strategy name (`rank0`, `replica`, `fixedN`, ...).
    pub fn parse(s: &str) -> Result<WriterStrategy> {
        match s {
            "rank0" | "baseline" => Ok(WriterStrategy::Rank0),
            "replica" | "all" => Ok(WriterStrategy::AllReplicas),
            "socket" => Ok(WriterStrategy::PerSocket),
            "node" => Ok(WriterStrategy::PerNode),
            other => {
                if let Some(n) = other.strip_prefix("fixed") {
                    let n: usize = n
                        .parse()
                        .map_err(|_| Error::Config(format!("bad strategy {other:?}")))?;
                    return Ok(WriterStrategy::FixedCount(n));
                }
                Err(Error::Config(format!("unknown strategy {other:?}")))
            }
        }
    }

    /// Select writers from a DP group (ranks holding identical state).
    ///
    /// Selection is deterministic and depends only on (group, strategy),
    /// satisfying §4.2's setup-time partitioning: every rank computes the
    /// same selection without communication.
    pub fn select(
        self,
        group: &[RankPlacement],
        _sockets_per_node: usize,
    ) -> Result<Vec<RankPlacement>> {
        if group.is_empty() {
            return Err(Error::Config("empty DP group".into()));
        }
        let picked = match self {
            WriterStrategy::Rank0 => vec![group[0]],
            WriterStrategy::AllReplicas => group.to_vec(),
            WriterStrategy::PerSocket => {
                let mut seen = std::collections::BTreeSet::new();
                group
                    .iter()
                    .filter(|p| seen.insert((p.node, p.socket)))
                    .copied()
                    .collect()
            }
            WriterStrategy::PerNode => {
                let mut seen = std::collections::BTreeSet::new();
                group.iter().filter(|p| seen.insert(p.node)).copied().collect()
            }
            WriterStrategy::FixedCount(n) => {
                if n == 0 {
                    return Err(Error::Config("fixed0 selects no writers".into()));
                }
                spread_select(group, n.min(group.len()))
            }
        };
        Ok(picked)
    }
}

/// Pick `n` ranks maximizing hardware coverage: iterate rounds, each
/// round taking at most one new rank per node (cycling sockets within
/// the node), until `n` are chosen. This realizes the paper's rule —
/// spread over I/O hardware first, stack writers per device last.
fn spread_select(group: &[RankPlacement], n: usize) -> Vec<RankPlacement> {
    use std::collections::BTreeMap;
    // node -> ranks (in group order), grouped
    let mut by_node: BTreeMap<usize, Vec<RankPlacement>> = BTreeMap::new();
    for p in group {
        by_node.entry(p.node).or_default().push(*p);
    }
    // within each node, order by socket-alternation to cover sockets
    // early: sort by (socket, local_gpu) then interleave sockets.
    for ranks in by_node.values_mut() {
        ranks.sort_by_key(|p| (p.socket, p.local_gpu));
        let mut by_socket: BTreeMap<usize, Vec<RankPlacement>> = BTreeMap::new();
        for p in ranks.drain(..) {
            by_socket.entry(p.socket).or_default().push(p);
        }
        let mut interleaved = Vec::new();
        let mut queues: Vec<_> = by_socket.into_values().collect();
        let nqueues = queues.len();
        let mut idx = 0;
        while queues.iter().any(|q| !q.is_empty()) {
            let q = &mut queues[idx % nqueues];
            if !q.is_empty() {
                interleaved.push(q.remove(0));
            }
            idx += 1;
        }
        *ranks = interleaved;
    }
    let mut picked = Vec::with_capacity(n);
    let mut round = 0;
    while picked.len() < n {
        let mut advanced = false;
        for ranks in by_node.values() {
            if picked.len() == n {
                break;
            }
            if let Some(p) = ranks.get(round) {
                picked.push(*p);
                advanced = true;
            }
        }
        if !advanced {
            break; // group exhausted
        }
        round += 1;
    }
    picked.sort_by_key(|p| p.rank);
    picked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, Parallelism, Topology};

    fn group(nodes: usize, dp: usize, mp: usize, slice: usize) -> Vec<RankPlacement> {
        let t = Topology::new(
            ClusterSpec::dgx2(nodes),
            Parallelism { dp, tp: mp, pp: 1, ep: 1 },
        )
        .unwrap();
        t.dp_group(slice)
    }

    #[test]
    fn rank0_selects_first() {
        let g = group(2, 4, 8, 3);
        let w = WriterStrategy::Rank0.select(&g, 2).unwrap();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].rank, 3);
    }

    #[test]
    fn all_replicas_selects_all() {
        let g = group(2, 4, 8, 0);
        let w = WriterStrategy::AllReplicas.select(&g, 2).unwrap();
        assert_eq!(w.len(), 4);
    }

    #[test]
    fn per_socket_covers_each_socket_once() {
        // dp=16, mp=1 on one node: 16 ranks over 2 sockets → 2 writers
        let g = group(1, 16, 1, 0);
        let w = WriterStrategy::PerSocket.select(&g, 2).unwrap();
        assert_eq!(w.len(), 2);
        assert_ne!(w[0].socket, w[1].socket);
    }

    #[test]
    fn per_node_covers_each_node_once() {
        // dp=8, mp=16 on 8 nodes: one replica/node → 8 ranks on 8 nodes
        let g = group(8, 8, 16, 5);
        let w = WriterStrategy::PerNode.select(&g, 2).unwrap();
        assert_eq!(w.len(), 8);
        let nodes: std::collections::BTreeSet<_> = w.iter().map(|p| p.node).collect();
        assert_eq!(nodes.len(), 8);
    }

    #[test]
    fn fixed_count_spreads_across_nodes_first() {
        // dp=32, mp=1 on 2 nodes (16 ranks/node). Picking 4 writers must
        // use both nodes (2+2), not stack 4 on node 0 (paper Fig. 6c).
        let g = group(2, 32, 1, 0);
        let w = WriterStrategy::FixedCount(4).select(&g, 2).unwrap();
        assert_eq!(w.len(), 4);
        let per_node = [0, 1].map(|n| w.iter().filter(|p| p.node == n).count());
        assert_eq!(per_node, [2, 2]);
        // and within a node, sockets covered before doubling up
        for n in 0..2 {
            let sockets: std::collections::BTreeSet<_> =
                w.iter().filter(|p| p.node == n).map(|p| p.socket).collect();
            assert_eq!(sockets.len(), 2);
        }
    }

    #[test]
    fn fixed_count_caps_at_group_size() {
        let g = group(1, 4, 1, 0);
        let w = WriterStrategy::FixedCount(100).select(&g, 2).unwrap();
        assert_eq!(w.len(), 4);
    }

    #[test]
    fn selection_is_deterministic() {
        let g = group(4, 16, 4, 2);
        for strat in [
            WriterStrategy::AllReplicas,
            WriterStrategy::PerSocket,
            WriterStrategy::PerNode,
            WriterStrategy::FixedCount(6),
        ] {
            assert_eq!(strat.select(&g, 2).unwrap(), strat.select(&g, 2).unwrap());
        }
    }

    #[test]
    fn parse_roundtrip() {
        for (s, want) in [
            ("rank0", WriterStrategy::Rank0),
            ("replica", WriterStrategy::AllReplicas),
            ("socket", WriterStrategy::PerSocket),
            ("node", WriterStrategy::PerNode),
            ("fixed8", WriterStrategy::FixedCount(8)),
        ] {
            assert_eq!(WriterStrategy::parse(s).unwrap(), want);
        }
        assert!(WriterStrategy::parse("bogus").is_err());
        assert!(WriterStrategy::FixedCount(0).select(&group(1, 2, 1, 0), 2).is_err());
    }

    #[test]
    fn prop_selection_subset_and_coverage() {
        crate::prop::forall("writer selection invariants", 64, |g| {
            let nodes = 1 << g.usize(0, 3);
            let dp = 1 << g.usize(0, 4);
            let mp = 1 << g.usize(0, 3);
            let spec = ClusterSpec::dgx2(nodes);
            if dp * mp > spec.total_gpus() {
                return true; // skip invalid combos
            }
            let topo = Topology::new(spec, Parallelism { dp, tp: mp, pp: 1, ep: 1 }).unwrap();
            let grp = topo.dp_group(g.usize(0, mp - 1));
            let n = g.usize(1, dp);
            let sel = WriterStrategy::FixedCount(n).select(&grp, 2).unwrap();
            // subset of group, no duplicates, exactly min(n, dp) writers
            let ranks: std::collections::BTreeSet<_> = sel.iter().map(|p| p.rank).collect();
            let group_ranks: std::collections::BTreeSet<_> =
                grp.iter().map(|p| p.rank).collect();
            ranks.len() == sel.len()
                && sel.len() == n.min(dp)
                && ranks.is_subset(&group_ranks)
        });
    }
}
